"""Mesh-sharded serving engine: DP/TP parity, per-shard accounting and
the MeshPlan surface.

Runs in-process under the conftest multi-device harness (8 virtual CPU
devices by default via REPRO_FORCE_DEVICES).  Parity contract
(distributed/serve_mesh.py):

  * pure DP (``dx1``): per-row arithmetic is untouched, so greedy
    streams are BIT-IDENTICAL to the single-device engine;
  * TP (``model > 1``): splitting the down-projection contraction
    reorders the fp32 reduction, so streams are argmax-equivalent --
    same lengths, same content unless an argmax tie flips on a ~1 ulp
    logit perturbation.  The smoke configs have no such ties, so we
    assert exact equality there too, but the *guaranteed* contract is
    per-token plausibility, which test_tp_logits_close pins directly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.distributed import serve_mesh
from repro.models import lm
from repro.serving.engine import COMPLETED, ServingEngine
from repro.serving.scheduler import ShardStats

pytestmark = pytest.mark.slow


def _need_devices(n: int):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices (REPRO_FORCE_DEVICES)")


@pytest.fixture(scope="module")
def setup():
    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _submit_all(eng, cfg, n, seed=7, max_new=8, temperature=0.0,
                **kw):
    rng = np.random.RandomState(seed)
    for _ in range(n):
        p = rng.randint(1, cfg.vocab_size,
                        size=rng.randint(3, 12)).tolist()
        eng.submit(p, max_new=max_new, temperature=temperature, **kw)


def _run(cfg, params, mesh, n_req=9, **ekw):
    eng = ServingEngine(cfg, params, max_batch=4, max_len=96,
                        decode_block=4, mesh=mesh, **ekw)
    _submit_all(eng, cfg, n_req)
    return eng.run_to_completion(), eng


# ---------------------------------------------------------------------------
# MeshPlan surface
# ---------------------------------------------------------------------------

def test_mesh_plan_parse():
    assert serve_mesh.MeshPlan.parse(None) is None
    p = serve_mesh.MeshPlan.parse("4x2")
    assert (p.data, p.model, p.size, str(p)) == (4, 2, 8, "4x2")
    assert serve_mesh.MeshPlan.parse(p) is p
    for bad in ("4", "x2", "2x2x2", "ax1", "2*2", ""):
        with pytest.raises(ValueError):
            serve_mesh.MeshPlan.parse(bad)
    with pytest.raises(ValueError):
        serve_mesh.MeshPlan(0, 1)


def test_mesh_plan_build_too_many_devices_actionable():
    plan = serve_mesh.MeshPlan(1024, 1)
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        plan.build()


def test_engine_validates_mesh(setup):
    cfg, params = setup
    _need_devices(2)
    with pytest.raises(ValueError, match="divide over the data"):
        ServingEngine(cfg, params, max_batch=3, mesh="2x1")
    # d_hidden = 128 on the smoke config: model=3 does not divide it
    with pytest.raises(ValueError, match="does not divide"):
        ServingEngine(cfg, params, max_batch=3, mesh="1x3")


# ---------------------------------------------------------------------------
# DP parity: bit-exact greedy streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", ["2x1", "4x1"])
def test_dp_greedy_bit_exact(setup, mesh):
    cfg, params = setup
    _need_devices(serve_mesh.MeshPlan.parse(mesh).size)
    ref, _ = _run(cfg, params, None)
    out, eng = _run(cfg, params, mesh)
    assert out == ref
    assert eng.stats.completed == len(ref)
    assert eng.stats.shard_identities_ok()


def test_dp_speculative_bit_exact(setup):
    """Drafting under a DP mesh never changes content -- streams match
    the plain single-device engine bit for bit, and drafts are actually
    accepted (the spec path really ran)."""
    cfg, params = setup
    _need_devices(2)
    ref, _ = _run(cfg, params, None)
    out, eng = _run(cfg, params, "2x1", speculative="ngram")
    assert out == ref
    assert eng.stats.draft_accepted > 0
    assert eng.stats.shard_identities_ok()


def test_dp_sampled_determinism_and_single_row_parity(setup):
    """Sampling keys are per-ROW, so multi-request sampled streams are
    placement-dependent (the shard-aware stager may balance requests
    onto different rows than the meshless ``(eta, row)`` order) -- but a
    run is deterministic given (mesh, seed), and a single request lands
    on row 0 under every shape, where parity is exact."""
    cfg, params = setup
    _need_devices(4)

    def sampled(mesh, n):
        eng = ServingEngine(cfg, params, max_batch=4, max_len=96,
                            decode_block=4, mesh=mesh, seed=11)
        _submit_all(eng, cfg, n, max_new=10, temperature=0.8)
        return eng.run_to_completion()

    assert sampled("2x1", 6) == sampled("2x1", 6)
    assert sampled(None, 1) == sampled("2x1", 1) == sampled("4x1", 1)


# ---------------------------------------------------------------------------
# TP parity: argmax-equivalent streams, close logits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", ["1x2", "2x2"])
def test_tp_greedy_streams(setup, mesh):
    cfg, params = setup
    _need_devices(serve_mesh.MeshPlan.parse(mesh).size)
    ref, _ = _run(cfg, params, None, n_req=6)
    out, eng = _run(cfg, params, mesh, n_req=6)
    assert set(out) == set(ref)
    for rid in ref:
        assert len(out[rid]) == len(ref[rid]), rid
        assert out[rid] == ref[rid], \
            f"rid {rid}: TP stream diverged beyond an argmax tie"
    assert eng.stats.shard_identities_ok()


def test_tp_logits_close(setup):
    """The guaranteed TP contract, pinned below the argmax: one sharded
    decode step reproduces single-device logits to fp32 reduction-order
    tolerance."""
    cfg, params = setup
    _need_devices(2)
    from jax.sharding import PartitionSpec as P
    from repro.distributed import context as mesh_ctx

    plan = serve_mesh.MeshPlan(1, 2)
    mesh = plan.build()
    cache = lm.init_cache(cfg, 2, 32)
    toks = jnp.asarray([3, 5], jnp.int32)
    ref, _ = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))(
        params, toks, cache)

    pspecs = serve_mesh.serve_params_pspecs(params, cfg, plan, mesh)
    cspecs = serve_mesh._cache_pspecs(cache, True)

    def body(p, c):
        with mesh_ctx.serving_tp("model"):
            return lm.decode_step(p, cfg, toks, c)

    fn = mesh_ctx.shard_map(body, mesh=mesh, in_specs=(pspecs, cspecs),
                            out_specs=(P(), cspecs), check_vma=False)
    out, _ = jax.jit(fn)(params, cache)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Per-shard accounting
# ---------------------------------------------------------------------------

def test_shard_stats_identity_and_aggregation(setup):
    cfg, params = setup
    _need_devices(4)
    out, eng = _run(cfg, params, "4x1")
    st = eng.stats
    assert len(st.shards) == 4
    # per-shard identity AND the cross-shard sums reproduce the globals
    assert st.shard_identities_ok()
    assert sum(s.slot_steps for s in st.shards) == st.slot_steps
    assert sum(s.decode_tokens for s in st.shards) == st.decode_tokens
    assert sum(s.prefill_rounds for s in st.shards) == st.prefill_rounds
    assert sum(s.wasted_slot_steps for s in st.shards) \
        == st.wasted_slot_steps
    assert sum(s.non_spec_tokens for s in st.shards) == st.non_spec_tokens
    snap = st.snapshot()
    assert snap["n_shards"] == 4
    assert snap["shard_identities_ok"]
    assert len(snap["shards"]) == 4


def test_wasted_slot_steps_land_on_the_idle_shard(setup):
    """One long request pins shard 0 while shard 1 sits empty: the idle
    shard accrues the wasted slot-steps, the busy one the work."""
    cfg, params = setup
    _need_devices(2)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=96,
                        decode_block=4, mesh="2x1")
    eng.submit([5, 6, 7], max_new=12)
    eng.run_to_completion()
    s0, s1 = eng.stats.shards
    assert s0.decode_tokens == 12 and s1.decode_tokens == 0
    # shard 1 never armed anything: every one of its slot-steps is waste
    assert s1.wasted_slot_steps == s1.slot_steps
    assert s0.wasted_slot_steps < s0.slot_steps
    assert eng.stats.shard_identities_ok()


def test_stager_balances_shards(setup):
    """Two concurrent requests must land on DIFFERENT shards (the
    least-loaded placement), not both on shard 0."""
    cfg, params = setup
    _need_devices(2)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=96,
                        decode_block=2, mesh="2x1")
    eng.submit([5, 6, 7], max_new=6)
    eng.submit([8, 9], max_new=6)
    eng.run_to_completion()
    s0, s1 = eng.stats.shards
    assert s0.decode_tokens == 6 and s1.decode_tokens == 6


def test_cancel_and_deadline_on_nonzero_shard(setup):
    """Lifecycle machinery is shard-agnostic: kill an in-flight request
    running on shard 1 (cancel) and time one out there; partial output
    survives and the identities still hold."""
    cfg, params = setup
    _need_devices(2)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=96,
                        decode_block=2, mesh="2x1")
    r0 = eng.submit([5, 6, 7], max_new=40)
    r1 = eng.submit([8, 9, 10], max_new=40)            # -> shard 1
    eng.step()
    assert eng.requests[r1].slot >= eng._rows_per_shard
    while not eng.requests[r1].out:
        eng.step()
    assert eng.cancel(r1)
    out = eng.run_to_completion()
    assert eng.finished[r1].status == "CANCELLED"
    assert 0 < len(out[r1]) < 40                       # partial preserved
    assert eng.finished[r0].status == COMPLETED
    assert eng.stats.shard_identities_ok()

    eng2 = ServingEngine(cfg, params, max_batch=4, max_len=96,
                         decode_block=2, mesh="2x1")
    # both carry deadlines so EDF keeps submission order (a lone
    # deadline would jump the queue and land on shard 0)
    d0 = eng2.submit([5, 6, 7], max_new=40, deadline=500)
    d1 = eng2.submit([8, 9, 10], max_new=40, deadline=512)
    eng2.step()
    assert eng2.requests[d1].slot >= eng2._rows_per_shard   # on shard 1
    # the capacity estimate admits the feasible deadline; simulate it
    # having been wrong by tightening post-admission (test_faults idiom)
    eng2.requests[d1].deadline = eng2.stats.decode_steps
    eng2.run_to_completion()
    assert eng2.requests[d1].slot is None
    assert eng2.finished[d1].status == "TIMED_OUT"
    assert eng2.finished[d0].status == COMPLETED
    assert eng2.stats.shard_identities_ok()


def test_shard_stats_identity_definition():
    """The identity itself, on hand-built numbers (doc for the field
    semantics: every slot-step is prefill, emitted decode, first-token
    overlap, waste or a health-guard kill)."""
    s = ShardStats(slot_steps=10, prefill_rounds=4, decode_tokens=5,
                   first_tokens=2, wasted_slot_steps=3,
                   nonfinite_decode_rounds=0, non_spec_tokens=5)
    assert s.identity_ok()
    s.wasted_slot_steps = 2
    assert not s.identity_ok()


def test_meshless_engine_has_single_shard(setup):
    """dp=1 always: the per-shard machinery runs (one shard covering the
    whole pool) so the identity is continuously checked even meshless."""
    cfg, params = setup
    out, eng = _run(cfg, params, None, n_req=5)
    assert len(eng.stats.shards) == 1
    st = eng.stats
    assert st.shards[0].slot_steps == st.slot_steps
    assert st.shard_identities_ok()
