"""Whole-block decode megakernel: ops-vs-oracle parity, multi-tile
interpret equality, and the kernel-tier dispatch matrix.

The elementwise parity contracts (block-fused step/chunk vs the forced
jnp path, chunk vs looped step) live in test_decode.py / test_packing.py
-- here the kernel is pinned against its standalone ``ref`` oracle, the
decode_step single-tile-under-interpret rule is held on a multi-tile
config, and the ``fuse_block`` x ``scan_strategy`` x TP dispatch
precedence is spied end-to-end."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocks
from repro.distributed import context as mesh_ctx
from repro.kernels.block_step import ops as block_ops
from repro.kernels.block_step import ref as block_ref
from repro.kernels.decode_step import ops as step_ops


def _block(cell="mingru", use_conv=True, use_mlp=True, d_model=16,
           seed=0, **kw):
    cfg = blocks.MinRNNBlockConfig(d_model=d_model, cell=cell,
                                   expansion=1.5, use_conv=use_conv,
                                   use_mlp=use_mlp, **kw)
    params = blocks.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# ops wrapper vs the standalone jnp oracle (interpret-mode parity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
@pytest.mark.parametrize("use_conv,use_mlp",
                         [(True, True), (True, False), (False, True),
                          (False, False)])
def test_block_step_ops_match_ref(cell, use_conv, use_mlp):
    cfg, params = _block(cell, use_conv, use_mlp)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, cfg.d_model))
    state = blocks.init_state(cfg, (3,))
    y, s = block_ops.fused_block_step(
        params, x, state, cell=cell, mode=cfg.mode, use_conv=use_conv,
        use_mlp=use_mlp)
    y_ref, s_ref = block_ref.block_step_ref(
        params, x, state, cell=cell, mode=cfg.mode, use_conv=use_conv,
        use_mlp=use_mlp)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s["h"]), np.asarray(s_ref["h"]),
                               rtol=1e-6, atol=1e-6)
    if use_conv:
        np.testing.assert_allclose(np.asarray(s["conv"]),
                                   np.asarray(s_ref["conv"]),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
def test_block_chunk_ops_match_ref(cell):
    cfg, params = _block(cell)
    c = 5
    x = jax.random.normal(jax.random.PRNGKey(2), (3, c, cfg.d_model))
    state = blocks.init_state(cfg, (3,))
    valid = jnp.asarray([3, 5, 1], jnp.int32)
    ys, s, pos = block_ops.fused_block_chunk(
        params, x, state, valid, cell=cell, mode=cfg.mode, use_conv=True,
        use_mlp=True, return_positions=True)
    ys_ref, s_ref, pos_ref = block_ref.block_chunk_ref(
        params, x, state, valid, cell=cell, mode=cfg.mode, use_conv=True,
        use_mlp=True)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s["h"]), np.asarray(s_ref["h"]),
                               rtol=1e-6, atol=1e-6)
    # per-position state snapshots ARE the speculative rollback table
    np.testing.assert_allclose(np.asarray(pos["h"]),
                               np.asarray(pos_ref["h"]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(pos["conv"]),
                               np.asarray(pos_ref["conv"]),
                               rtol=1e-6, atol=1e-6)


def test_block_step_bf16_compute_dtype_finite_and_close():
    cfg, params = _block("minlstm")
    x = jax.random.normal(jax.random.PRNGKey(3), (4, cfg.d_model))
    state = blocks.init_state(cfg, (4,))
    y, s = block_ops.fused_block_step(
        params, x, state, cell="minlstm", mode=cfg.mode, use_conv=True,
        use_mlp=True, compute_dtype=jnp.bfloat16)
    y_ref, s_ref = block_ref.block_step_ref(
        params, x, state, cell="minlstm", mode=cfg.mode, use_conv=True,
        use_mlp=True, compute_dtype=jnp.bfloat16)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(s["h"], np.float32),
                               np.asarray(s_ref["h"], np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# decode_step multi-tile configs: single-tile-under-interpret equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_dh", [128, 256])
def test_decode_chunk_bitexact_on_multitile_config(block_dh):
    """dh=320 with block_dh=128 historically drifted ~1 ulp between the
    step and chunk kernels under interpret (XLA merges the unrolled
    per-tile dots of the step grid into one fused dot).  ops._tile now
    forces a single tile under interpret, so equality is EXACT on every
    requested tiling -- including multi-tile ones."""
    dx, dh, b, c = 24, 320, 3, 4
    key = jax.random.PRNGKey(4)
    wz = jax.random.normal(key, (dx, dh)) * 0.3
    wh = jax.random.normal(jax.random.PRNGKey(5), (dx, dh)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(6), (b, c, dx))
    h0 = jax.random.normal(jax.random.PRNGKey(7), (b, dh))
    valid = jnp.asarray([2, 4, 1], jnp.int32)
    hs = step_ops.fused_mingru_chunk(x, wz, None, wh, None, h0, valid,
                                     block_dh=block_dh)
    h = h0
    for t in range(c):
        h_new = step_ops.fused_mingru_step(x[:, t], wz, None, wh, None, h,
                                           block_dh=block_dh)
        h = jnp.where((t < valid)[:, None], h_new, h)
        np.testing.assert_array_equal(
            np.asarray(hs[:, t]), np.asarray(h),
            err_msg=f"t={t} block_dh={block_dh}")


def test_tile_helper_contract():
    """Interpret forces one lane-rounded tile; real backends keep the
    caller's streaming tile."""
    assert step_ops._tile(320, 128, interpret=True) == 384
    assert step_ops._tile(128, 128, interpret=True) == 128
    assert step_ops._tile(320, 128, interpret=False) == 128


# ---------------------------------------------------------------------------
# dispatch precedence: scan_strategy x fuse_block x arch x TP
# ---------------------------------------------------------------------------

def _spies(monkeypatch):
    calls = {"block_step": 0, "block_chunk": 0, "cell_step": 0,
             "cell_chunk": 0}

    def wrap(mod, name, key):
        real = getattr(mod, name)

        def spy(*a, **kw):
            calls[key] += 1
            return real(*a, **kw)

        monkeypatch.setattr(mod, name, spy)

    wrap(block_ops, "fused_block_step", "block_step")
    wrap(block_ops, "fused_block_chunk", "block_chunk")
    for name in ("fused_mingru_step", "fused_minlstm_step"):
        wrap(step_ops, name, "cell_step")
    for name in ("fused_mingru_chunk", "fused_minlstm_chunk"):
        wrap(step_ops, name, "cell_chunk")
    return calls


@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
@pytest.mark.parametrize("strategy,fuse,want_tier", [
    ("auto", "auto", "block-fused"),
    ("auto", "on", "block-fused"),
    ("auto", "off", "cell-fused"),
    ("fused", "auto", "block-fused"),
    ("fused", "off", "cell-fused"),
    ("sequential", "auto", "unfused"),
    ("sequential", "off", "unfused"),
])
def test_step_dispatch_matrix(monkeypatch, cell, strategy, fuse,
                              want_tier):
    cfg, params = _block(cell, scan_strategy=strategy, fuse_block=fuse)
    assert blocks.fuse_block_tier(cfg, params) == want_tier
    calls = _spies(monkeypatch)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, cfg.d_model))
    state = blocks.init_state(cfg, (2,))
    blocks.step(params, cfg, x, state)
    assert (calls["block_step"] > 0) == (want_tier == "block-fused")
    assert (calls["cell_step"] > 0) == (want_tier == "cell-fused")
    xs = jax.random.normal(jax.random.PRNGKey(9), (2, 3, cfg.d_model))
    blocks.step_chunk(params, cfg, xs, state,
                      jnp.asarray([3, 2], jnp.int32))
    assert (calls["block_chunk"] > 0) == (want_tier == "block-fused")
    assert (calls["cell_chunk"] > 0) == (want_tier == "cell-fused")


def test_step_scan_strategy_argument_overrides_config(monkeypatch):
    """An explicit ``scan_strategy=`` to step() wins over the config,
    exactly as for the cell-level dispatch."""
    cfg, params = _block("mingru", scan_strategy="auto", fuse_block="auto")
    calls = _spies(monkeypatch)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, cfg.d_model))
    state = blocks.init_state(cfg, (2,))
    blocks.step(params, cfg, x, state, scan_strategy="sequential")
    assert calls["block_step"] == 0 and calls["cell_step"] == 0


def test_non_rmsnorm_falls_back_to_cell_tier(monkeypatch):
    cfg, params = _block("mingru", norm="layernorm")
    assert blocks.fuse_block_tier(cfg, params) == "cell-fused"
    calls = _spies(monkeypatch)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, cfg.d_model))
    state = blocks.init_state(cfg, (2,))
    blocks.step(params, cfg, x, state)
    assert calls["block_step"] == 0 and calls["cell_step"] > 0


def test_tp_sliced_layer_falls_back_to_cell_tier():
    """Inside a serving_tp trace a row-parallel-sliced layer (down /
    mlp_out kernels see a d_hidden/m column block) must keep the psum
    outside the kernel -- cell tier.  Unsliced params (replicated layer
    riding the same trace) stay block-fused."""
    cfg, params = _block("mingru")
    assert blocks.fuse_block_tier(cfg, params) == "block-fused"
    half = cfg.d_hidden // 2
    sliced = dict(params)
    sliced["down"] = {"kernel": params["down"]["kernel"][:half]}
    with mesh_ctx.serving_tp("model"):
        assert blocks.fuse_block_tier(cfg, sliced) == "cell-fused"
        assert blocks.fuse_block_tier(cfg, params) == "block-fused"
        # an mlp_out slice alone must also demote
        sliced_mlp = dict(params)
        sliced_mlp["mlp_out"] = {
            "kernel": params["mlp_out"]["kernel"][:cfg.d_mlp // 2],
            "bias": params["mlp_out"]["bias"]}
        assert blocks.fuse_block_tier(cfg, sliced_mlp) == "cell-fused"
    # outside the TP trace sliced shapes are not consulted
    assert blocks.fuse_block_tier(cfg, params) == "block-fused"


def test_fuse_block_tier_unfused_when_strategy_not_fused():
    cfg, _ = _block("mingru")
    assert blocks.fuse_block_tier(cfg, scan_strategy="associative") \
        == "unfused"
    assert blocks.fuse_block_tier(cfg, scan_strategy="fused") \
        == "block-fused"
