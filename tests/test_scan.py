"""Parallel scan: every strategy must agree with the sequential oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scan as scan_lib

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, kind):
    k1, k2 = jax.random.split(key)
    if kind == "gate":      # a in (0,1) like (1-z)
        a = jax.nn.sigmoid(jax.random.normal(k1, shape))
    else:                   # arbitrary sign/scale
        a = jax.random.normal(k1, shape) * 0.9
    b = jax.random.normal(k2, shape)
    return a, b


@pytest.mark.parametrize("shape", [(2, 8, 4), (1, 128, 16), (3, 33, 7)])
@pytest.mark.parametrize("kind", ["gate", "free"])
def test_associative_matches_sequential(shape, kind):
    a, b = _rand(jax.random.PRNGKey(0), shape, kind)
    ref = scan_lib.scan_sequential(a, b)
    out = scan_lib.scan_associative(a, b)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(2, 16, 4)])
def test_associative_with_h0(shape):
    a, b = _rand(jax.random.PRNGKey(1), shape, "gate")
    h0 = jax.random.normal(jax.random.PRNGKey(2), shape[:1] + shape[2:])
    ref = scan_lib.scan_sequential(a, b, h0)
    out = scan_lib.scan_associative(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [4, 16, 64])
@pytest.mark.parametrize("t", [12, 64, 100])
def test_chunked_matches_sequential(chunk, t):
    a, b = _rand(jax.random.PRNGKey(3), (2, t, 8), "gate")
    ref = scan_lib.scan_sequential(a, b)
    out = scan_lib.scan_chunked(a, b, chunk=chunk)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_chunked_with_h0():
    a, b = _rand(jax.random.PRNGKey(4), (2, 40, 8), "gate")
    h0 = jax.random.normal(jax.random.PRNGKey(5), (2, 8))
    ref = scan_lib.scan_sequential(a, b, h0)
    out = scan_lib.scan_chunked(a, b, h0, chunk=16)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_log_space_matches_linear():
    """Heinsen scan == linear scan when a, b > 0."""
    key = jax.random.PRNGKey(6)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 32, 8)))
    b = jnp.exp(jax.random.normal(k2, (2, 32, 8)) * 0.5)
    ref = scan_lib.scan_sequential(a, b)
    out = scan_lib.scan_log_space(jnp.log(a), jnp.log(b))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_log_space_with_h0():
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 16, 4)))
    b = jnp.exp(jax.random.normal(k2, (2, 16, 4)) * 0.5)
    h0 = jnp.exp(jax.random.normal(k3, (2, 4)) * 0.5)
    ref = scan_lib.scan_sequential(a, b, h0)
    out = scan_lib.scan_log_space(jnp.log(a), jnp.log(b), jnp.log(h0))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_log_space_stability_extreme_gates():
    """Saturated gates (|preact| ~ 40) must not produce inf/nan in log space."""
    k = jnp.full((1, 64, 4), 40.0)           # z -> 1:   log(1-z) ~ -40
    log_a = -jax.nn.softplus(k)
    log_b = -jax.nn.softplus(-k) + 0.3       # log z + log h~
    out = scan_lib.scan_log_space(log_a, log_b)
    assert bool(jnp.all(jnp.isfinite(out)))


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 50),
    d=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_associative_equals_sequential(t, d, seed):
    a, b = _rand(jax.random.PRNGKey(seed), (2, t, d), "gate")
    ref = scan_lib.scan_sequential(a, b)
    out = scan_lib.scan_associative(a, b)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(2, 40),
    split=st.integers(1, 39),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_scan_composition(t, split, seed):
    """Scanning [0:s] then [s:T] with the carried state == scanning [0:T].

    This is the associativity invariant that makes chunking, sequence
    parallelism and prefill/decode splits all correct.
    """
    if split >= t:
        split = t - 1
    if split < 1:
        return
    a, b = _rand(jax.random.PRNGKey(seed), (1, t, 3), "gate")
    full = scan_lib.scan_sequential(a, b)
    h_first = scan_lib.scan_sequential(a[:, :split], b[:, :split])
    h_rest = scan_lib.scan_sequential(a[:, split:], b[:, split:],
                                      h_first[:, -1])
    np.testing.assert_allclose(
        jnp.concatenate([h_first, h_rest], axis=1), full,
        rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# Pallas chunked-scan kernel: tiling sweeps against the pure-jnp oracle
# (interpret mode; ops.py handles padding of ragged T / D)
# ---------------------------------------------------------------------------

from repro.kernels.scan import kernel as scan_kernel
from repro.kernels.scan import ops as scan_ops
from repro.kernels.scan import ref as scan_ref


def _kernel_case(key, t, d, bsz=2):
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (bsz, t, d)))
    b = jax.random.normal(k2, (bsz, t, d))
    h0 = jax.random.normal(k3, (bsz, d))
    return a, b, h0


@pytest.mark.parametrize("block_t,block_d", [
    (8, 128),        # minimum sublane tile
    (16, 256),       # wider lanes
    (32, 128),
    (128, 512),      # block_t > T: ops clamps to next pow2 of T
    (256, 128),      # default
])
def test_linear_scan_kernel_tilings(block_t, block_d):
    a, b, h0 = _kernel_case(jax.random.PRNGKey(block_t + block_d), 96, 40)
    out = scan_ops.linear_scan(a, b, h0, block_t, block_d, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("t,d", [
    (1, 1),          # degenerate
    (5, 3),          # both odd, below one tile
    (33, 17),        # odd, just past tile boundaries
    (100, 70),       # ragged mid-size
    (127, 129),      # one under / one over pow2 and lane width
    (257, 1),        # long time axis, single feature
])
def test_linear_scan_kernel_odd_sizes_padding_path(t, d):
    """Arbitrary T/D exercise the ops.py identity-padding (a=1, b=0) path."""
    a, b, h0 = _kernel_case(jax.random.PRNGKey(t * 1000 + d), t, d)
    out = scan_ops.linear_scan(a, b, h0, 64, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(
    t=st.integers(1, 70),
    d=st.integers(1, 40),
    block_t=st.sampled_from([8, 16, 64, 256]),
    seed=st.integers(0, 2**20),
)
def test_property_linear_scan_kernel_matches_ref(t, d, block_t, seed):
    a, b, h0 = _kernel_case(jax.random.PRNGKey(seed), t, d, bsz=1)
    out = scan_ops.linear_scan(a, b, h0, block_t, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


def test_linear_scan_kernel_direct_tile_aligned():
    """Call the raw kernel (no ops padding) on exactly tile-aligned shapes
    with a non-default tiling."""
    a, b, h0 = _kernel_case(jax.random.PRNGKey(42), 64, 256)
    out = scan_kernel.linear_scan_kernel(a, b, h0, block_t=16, block_d=128,
                                         interpret=True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_bf16_scan_runs():
    a, b = _rand(jax.random.PRNGKey(8), (2, 32, 8), "gate")
    out = scan_lib.scan_associative(a.astype(jnp.bfloat16),
                                    b.astype(jnp.bfloat16))
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


def test_scan_grad_finite():
    a, b = _rand(jax.random.PRNGKey(9), (2, 64, 8), "gate")

    def loss(ab):
        return jnp.sum(scan_lib.scan_associative(*ab) ** 2)

    g = jax.grad(loss)((a, b))
    for leaf in g:
        assert bool(jnp.all(jnp.isfinite(leaf)))
