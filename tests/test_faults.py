"""Serving fault-tolerance layer: deterministic chaos injection,
NaN-quarantine with bounded retry, deadlines, cancellation, bounded-queue
admission and stall diagnosis.

The contract under test: every submitted request reaches a well-defined
terminal status whatever faults fire, the terminal accounting identity
``submitted == completed + cancelled + timed_out + failed + shed +
rejected`` holds once the engine drains, the extended slot-step identity
``slot_steps == prefill_rounds + decode_tokens - first_token_overlaps +
wasted_slot_steps + nonfinite_decode_rounds`` holds under faults, and the
fault-free path is bit-identical with the injector disabled OR armed at
rate zero (all guard ops are masks that reduce to identity)."""

import jax
import numpy as np
import pytest

from repro.configs import archs
from repro.models import lm
from repro.serving.engine import (
    CANCELLED, COMPLETED, FAILED, SHED, TERMINAL_STATUSES, TIMED_OUT,
    EngineStallError, ServingEngine, generate_one)
from repro.serving.faults import (
    INJECTION_POINTS, FaultConfig, FaultInjector)
from repro.serving.scheduler import (
    ADMITTED, REJECTED_QUEUE_FULL, SHED_UNMEETABLE_DEADLINE)

MAX_LEN = 64

_CACHE = {}


def _setup():
    if "v" not in _CACHE:
        cfg = archs.smoke("mingru-lm")
        _CACHE["v"] = (cfg, lm.init_params(jax.random.PRNGKey(0), cfg))
    return _CACHE["v"]


def _check_identities(engine):
    """Extended slot-step identity + terminal accounting (drained)."""
    s = engine.stats
    tokens = s.non_spec_tokens if engine.draft is not None \
        else s.decode_tokens
    overlaps = len(s.ttft_rounds)   # one per service epoch that emitted
    assert s.slot_steps == (s.prefill_rounds + tokens - overlaps
                            + s.wasted_slot_steps
                            + s.nonfinite_decode_rounds), (
        s.slot_steps, s.prefill_rounds, tokens, overlaps,
        s.wasted_slot_steps, s.nonfinite_decode_rounds)
    assert s.submitted == (s.completed + s.cancelled + s.timed_out
                           + s.failed + s.shed + s.rejected)
    for req in engine.requests.values():
        assert req.status in TERMINAL_STATUSES, (req.rid, req.status)


# ---------------------------------------------------------------------------
# Injector determinism + inertness (pure host logic, no model)
# ---------------------------------------------------------------------------

def test_injector_same_seed_same_schedule():
    def drive(inj):
        out = []
        for call in range(20):
            out.append(tuple(inj.corrupt_state(call * 4, 4, 8)))
            out.append(tuple(inj.drop_upload(call, [0, 3, 5])[1]))
            out.append(inj.straggler(call) > 0)
        return out, list(inj.events)

    kw = dict(seed=11, nan_rate=0.05, drop_rate=0.3, straggler_rate=0.2)
    a, ev_a = drive(FaultInjector(**kw))
    b, ev_b = drive(FaultInjector(FaultConfig(**kw)))
    assert a == b and ev_a == ev_b
    c, _ = drive(FaultInjector(seed=12, nan_rate=0.05, drop_rate=0.3,
                               straggler_rate=0.2))
    assert a != c            # seed actually reaches the draws
    assert any(ev_a)         # the schedule is non-trivial


def test_injector_zero_rates_inject_nothing():
    inj = FaultInjector(seed=0)
    for call in range(10):
        assert inj.corrupt_state(call, 4, 8) == []
        assert inj.drop_upload(call, [1, 2]) == ([1, 2], [])
        assert inj.straggler(call) == 0.0
    assert inj.events == []
    with pytest.raises(ValueError):
        FaultInjector(FaultConfig(seed=0), nan_rate=0.5)


def test_fault_config_rejects_out_of_range_rates():
    """A typo'd rate (nan_rate=10) must fail loudly at construction,
    not silently saturate at probability 1."""
    for kw in (dict(nan_rate=1.5), dict(drop_rate=-0.1),
               dict(straggler_rate=2.0), dict(straggler_s=-1.0)):
        with pytest.raises(ValueError):
            FaultConfig(**kw)
    FaultConfig(nan_rate=0.0, drop_rate=1.0)     # the boundaries are legal


def test_counts_keys_every_injection_point():
    counts = FaultInjector(seed=0).counts()
    assert set(counts) == set(INJECTION_POINTS)
    assert counts["shard_crash"] == 0


def test_shard_crash_schedule_fires_once_per_shard():
    inj = FaultInjector(shard_crash_at=((5, 1), (5, 9), (11, 0)))
    assert inj.shard_crash(0, 4, 2) == []      # rounds [0, 4): nothing
    assert inj.shard_crash(4, 4, 2) == [1]     # round 5 in [4, 8)
    assert inj.shard_crash(4, 4, 2) == []      # a dead shard stays dead
    assert inj.shard_crash(8, 4, 2) == [0]     # shard 9 out of range
    assert inj.counts()["shard_crash"] == 2


def test_injector_state_dict_resumes_schedule():
    """Restoring a snapshotted injector into a fresh one makes the
    remaining fault schedule identical to the uninterrupted run -- the
    property journal-tail replay relies on."""
    kw = dict(seed=9, nan_rate=0.2, drop_rate=0.3, straggler_rate=0.5)
    a = FaultInjector(**kw)
    for call in range(5):
        a.corrupt_state(call * 4, 4, 8)
        a.drop_upload(call, [0, 1, 2])
        a.straggler(call)
    state = a.state_dict()
    b = FaultInjector(**kw)
    b.load_state_dict(state)
    for call in range(5, 10):
        assert a.corrupt_state(call * 4, 4, 8) == \
            b.corrupt_state(call * 4, 4, 8)
        assert a.drop_upload(call, [0, 1, 2]) == \
            b.drop_upload(call, [0, 1, 2])
        assert a.straggler(call) == b.straggler(call)
    assert a.events == b.events


def test_explicit_nan_schedule_targets_round_window():
    inj = FaultInjector(nan_at=((5, 1), (9, 0), (3, 99)))
    assert inj.corrupt_state(4, 4, 4) == [1]      # rounds [4, 8)
    assert inj.corrupt_state(8, 4, 4) == [0]      # rounds [8, 12)
    assert inj.corrupt_state(0, 2, 4) == []       # slot 99 out of range


# ---------------------------------------------------------------------------
# Fault-free path stays bit-identical (inert injector)
# ---------------------------------------------------------------------------

def test_zero_rate_injector_is_bit_identical():
    cfg, params = _setup()
    prompts = [[1, 2, 3, 4], [5, 6, 7], [2, 4, 6, 8, 10]]
    refs = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
            for p in prompts]
    outs = {}
    for faults in (None, FaultInjector(seed=0)):
        engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                               decode_block=4, faults=faults)
        rids = [engine.submit(p, max_new=6) for p in prompts]
        got = engine.run_to_completion()
        outs[faults is None] = [got[r] for r in rids]
        assert engine.stats.quarantined == 0
        assert engine.stats.nonfinite_decode_rounds == 0
        _check_identities(engine)
    assert outs[True] == outs[False] == refs


def test_drop_upload_faults_keep_streams_exact():
    """Dropped staging uploads delay arming (the request retries on the
    next round-trip) but never lose a request or perturb its stream."""
    cfg, params = _setup()
    prompts = [[i + 1, i + 2, i + 3] for i in range(6)]
    refs = [generate_one(cfg, params, p, max_new=5, max_len=MAX_LEN)
            for p in prompts]
    inj = FaultInjector(seed=3, drop_rate=0.7)
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=4, faults=inj)
    rids = [engine.submit(p, max_new=5) for p in prompts]
    outs = engine.run_to_completion()
    assert inj.counts()["drop_upload"] > 0    # the fault actually fired
    assert [outs[r] for r in rids] == refs
    assert engine.stats.completed == len(prompts)
    _check_identities(engine)


# ---------------------------------------------------------------------------
# NaN quarantine: bounded retry, then FAILED
# ---------------------------------------------------------------------------

def test_nan_quarantine_retries_and_completes():
    """A poisoned row is killed in-loop, its request re-enqueued with
    backoff, and the retry regenerates the exact reference stream."""
    cfg, params = _setup()
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    refs = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
            for p in prompts]
    inj = FaultInjector(nan_at=((4, 0),))
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=4, faults=inj, max_retries=2,
                           retry_backoff=2)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    assert engine.stats.quarantined >= 1
    assert engine.stats.retried >= 1
    assert engine.stats.nonfinite_decode_rounds >= 1
    assert engine.stats.failed == 0
    assert all(engine.finished[r].status == COMPLETED for r in rids)
    assert [outs[r] for r in rids] == refs   # retry restarts from scratch
    _check_identities(engine)


def test_retry_exhaustion_fails_and_drains():
    """Under saturating corruption every request burns its retry budget
    and retires FAILED -- the engine drains instead of spinning."""
    cfg, params = _setup()
    inj = FaultInjector(seed=1, nan_rate=1.0)
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=2, faults=inj, max_retries=1,
                           retry_backoff=1)
    rids = [engine.submit([1, 2, 3], max_new=6) for _ in range(2)]
    engine.run_to_completion(max_steps=200)
    assert all(engine.finished[r].status == FAILED for r in rids)
    assert engine.stats.failed == 2
    assert engine.stats.retried == 2         # one retry each, then FAILED
    assert engine.stats.quarantined >= 4
    _check_identities(engine)


# ---------------------------------------------------------------------------
# Cancellation across the lifecycle
# ---------------------------------------------------------------------------

def test_cancel_queued_staged_and_inflight():
    cfg, params = _setup()
    ref = generate_one(cfg, params, [1, 2], max_new=10, max_len=MAX_LEN)
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                           decode_block=4)
    r0 = engine.submit([1, 2], max_new=10)
    r1 = engine.submit([3, 4], max_new=10)
    r2 = engine.submit([5, 6], max_new=10)
    engine.step()     # r0 armed in-loop (RUNNING)
    engine.step()     # staging restocked: r1 parked, r2 still queued
    assert engine.current[0] is engine.requests[r0]
    assert engine.staged[0] is engine.requests[r1]
    assert engine.cancel(r2)                  # queued
    assert engine.cancel(r1)                  # staged
    assert engine.cancel(r0)                  # in-flight: keeps partial
    assert not engine.cancel(r0)              # already terminal
    assert not engine.cancel(12345)           # unknown rid
    outs = engine.run_to_completion()
    assert all(engine.finished[r].status == CANCELLED
               for r in (r0, r1, r2))
    assert outs[r1] == outs[r2] == []
    # partial output is a proper prefix of the reference stream
    assert 0 < len(outs[r0]) < 10 and outs[r0] == ref[:len(outs[r0])]
    assert engine.stats.cancelled == 3
    _check_identities(engine)


# ---------------------------------------------------------------------------
# Deadlines: sweep for queued / staged / in-flight, shed at admission
# ---------------------------------------------------------------------------

def test_deadline_sweep_times_out_inflight_with_partial_output():
    cfg, params = _setup()
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=4)
    victim = engine.submit([1, 2, 3], max_new=12)
    other = engine.submit([4, 5, 6], max_new=12)
    engine.step()
    # the capacity estimate is accurate enough that a feasible deadline
    # is met; simulate it having been wrong by tightening post-admission
    engine.requests[victim].deadline = engine.stats.decode_steps
    outs = engine.run_to_completion()
    assert engine.finished[victim].status == TIMED_OUT
    assert 0 < len(outs[victim]) < 12         # partial output preserved
    assert engine.finished[other].status == COMPLETED
    assert len(outs[other]) == 12
    assert engine.stats.timed_out == 1
    _check_identities(engine)


def test_deadline_sweep_times_out_queued_and_staged():
    cfg, params = _setup()
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                           decode_block=4)
    r0 = engine.submit([1, 2], max_new=16)
    r1 = engine.submit([3, 4], max_new=16)
    r2 = engine.submit([5, 6], max_new=16)
    engine.step()     # r0 running, r1 staged, r2 queued
    engine.requests[r1].deadline = engine.stats.decode_steps
    engine.requests[r2].deadline = engine.stats.decode_steps
    outs = engine.run_to_completion()
    assert engine.finished[r1].status == TIMED_OUT
    assert engine.finished[r2].status == TIMED_OUT
    assert outs[r1] == [] and outs[r2] == []
    assert engine.finished[r0].status == COMPLETED
    _check_identities(engine)


def test_unmeetable_deadline_shed_at_admission():
    cfg, params = _setup()
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                           decode_block=4)
    r0 = engine.submit([1, 2], max_new=20)
    # service needs ~21 rounds behind a 21-round occupant: 4 is hopeless
    r1 = engine.submit([3, 4], max_new=20, deadline=4)
    assert engine.requests[r1].verdict == SHED_UNMEETABLE_DEADLINE
    assert engine.requests[r1].status == SHED
    assert engine.finished[r1].out == []
    assert engine.stats.shed == 1
    # a generous deadline admits and completes normally
    r2 = engine.submit([5, 6], max_new=4, deadline=512)
    assert engine.requests[r2].verdict == ADMITTED
    outs = engine.run_to_completion()
    assert engine.finished[r0].status == COMPLETED
    assert engine.finished[r2].status == COMPLETED
    assert len(outs[r2]) == 4
    _check_identities(engine)


# ---------------------------------------------------------------------------
# Bounded queue: backpressure sheds instead of growing
# ---------------------------------------------------------------------------

def test_bounded_queue_rejects_burst_and_recovers():
    cfg, params = _setup()
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                           decode_block=4, max_queue=2, low_watermark=0.5)
    rids = [engine.submit([i + 1, i + 2], max_new=4) for i in range(8)]
    rejected = [r for r in rids
                if engine.requests[r].verdict == REJECTED_QUEUE_FULL]
    assert rejected                          # the burst hit the watermark
    assert all(engine.requests[r].status == SHED for r in rejected)
    assert engine.stats.queue_peak <= 2      # the queue never grew past it
    assert engine.stats.rejected == len(rejected)
    engine.run_to_completion()
    admitted = [r for r in rids if r not in rejected]
    assert all(engine.finished[r].status == COMPLETED for r in admitted)
    # hysteresis re-opened admission once the queue drained
    late = engine.submit([9, 9], max_new=4)
    assert engine.requests[late].verdict == ADMITTED
    engine.run_to_completion()
    assert engine.finished[late].status == COMPLETED
    _check_identities(engine)


# ---------------------------------------------------------------------------
# Submit-time validation + stall diagnosis
# ---------------------------------------------------------------------------

def test_submit_validates_controls_and_budget():
    cfg, params = _setup()
    engine = ServingEngine(cfg, params, max_batch=1, max_len=16)
    with pytest.raises(ValueError):
        engine.submit([], max_new=4)                     # empty prompt
    with pytest.raises(ValueError):
        engine.submit([1, 2], max_new=64)                # exceeds max_len
    with pytest.raises(ValueError):
        engine.submit([1], max_new=4, temperature=-0.5)
    with pytest.raises(ValueError):
        engine.submit([1], max_new=4, top_k=-1)
    with pytest.raises(ValueError):
        engine.submit([1], max_new=4, top_p=0.0)
    with pytest.raises(ValueError):
        engine.submit([1], max_new=4, top_p=1.5)
    with pytest.raises(ValueError):
        engine.submit([1], max_new=4, deadline=0)
    with pytest.raises(ValueError):
        generate_one(cfg, params, [], max_new=4, max_len=16)
    assert engine.stats.submitted == 0       # rejected before accounting


def test_run_to_completion_stall_raises_with_occupancy_report():
    cfg, params = _setup()
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                           decode_block=1)
    rid = engine.submit([1, 2, 3], max_new=20)
    engine.submit([4, 5, 6], max_new=20)
    with pytest.raises(EngineStallError) as ei:
        engine.run_to_completion(max_steps=2)
    rep = ei.value.report
    assert rep["in_flight"] == 1 and rep["staged"] == 1
    assert rep["slots"][0]["current"]["rid"] == rid
    assert rep["decode_steps"] == 2
    # the stall error is diagnostic, not terminal: stepping on finishes
    engine.run_to_completion()
    assert engine.stats.completed == 2


# ---------------------------------------------------------------------------
# Speculative degradation: rolling accept-rate floor
# ---------------------------------------------------------------------------

def test_spec_accept_floor_disables_drafting_keeps_streams():
    cfg, params = _setup()
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    refs = [generate_one(cfg, params, p, max_new=10, max_len=MAX_LEN)
            for p in prompts]
    # an impossible floor (accept rate can never reach 1.01) trips the
    # breaker as soon as the window fills; streams must not change
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=2, speculative="ngram",
                           draft_len=4, spec_accept_floor=1.01,
                           spec_window=1)
    rids = [engine.submit(p, max_new=10) for p in prompts]
    outs = engine.run_to_completion()
    assert engine.stats.spec_disabled >= 1
    assert not engine._spec_active
    assert [outs[r] for r in rids] == refs
    _check_identities(engine)


# ---------------------------------------------------------------------------
# Chaos replay: mixed trace under all fault kinds at once
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_trace_every_request_terminal():
    """Mixed arrival trace under NaN + drop + straggler faults, deadlines
    on a slice and a bounded queue: 100% of requests reach a terminal
    status and both identities hold."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    inj = FaultInjector(seed=5, nan_rate=0.01, drop_rate=0.1,
                        straggler_rate=0.1, straggler_s=0.001)
    engine = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                           decode_block=4, faults=inj, max_retries=2,
                           retry_backoff=2, max_queue=8)
    rids = []
    for i in range(24):
        prompt = list(rng.integers(1, 250, size=int(rng.integers(2, 9))))
        kw = {}
        if i % 4 == 0:
            kw["deadline"] = 2 * (len(prompt) + 12)
        rids.append(engine.submit(prompt, max_new=int(rng.integers(4, 13)),
                                  priority=int(rng.integers(0, 3)), **kw))
        if i % 3 == 2:
            engine.step()
    engine.run_to_completion(max_steps=2000)
    assert sum(v > 0 for v in inj.counts().values()) >= 2
    assert len(engine.finished) == 24
    assert all(engine.finished[r].status in TERMINAL_STATUSES
               for r in rids)
    assert engine.stats.completed > 0
    _check_identities(engine)
