"""FifoScheduler + superstep-engine admission properties.

Replays random arrival / prompt-length / max_new traces and asserts the
scheduler contract the superstep engine depends on:

  * **FIFO fairness** -- requests leave the queue in exact submission
    order (a request is never overtaken while waiting), and the engine
    stages them in that same order;
  * **no starvation** -- under continuous admission every request is
    eventually staged, armed and completed;
  * **conservation** -- every submitted request completes exactly once
    with exactly ``max_new`` tokens (no EOS in these traces).
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import archs
from repro.models import lm
from repro.serving.engine import ServingEngine, replay_trace
from repro.serving.scheduler import ADMITTED, REJECTED_QUEUE_FULL, \
    SHED_UNMEETABLE_DEADLINE, AdmissionScheduler, EngineStats, \
    FifoScheduler, SchedulerConfig

# ---------------------------------------------------------------------------
# Scheduler-level FIFO properties (pure host logic, no model)
# ---------------------------------------------------------------------------


class _Tag:
    def __init__(self, i):
        self.i = i


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_fifo_take_preserves_submission_order(seed):
    rng = np.random.default_rng(seed)
    sched = FifoScheduler(SchedulerConfig(max_batch=4))
    submitted, taken = 0, []
    for _ in range(30):
        for _ in range(int(rng.integers(0, 4))):
            sched.submit(_Tag(submitted))
            submitted += 1
        got = sched.take(int(rng.integers(0, 5)))
        assert len(got) <= 4 + submitted
        taken.extend(t.i for t in got)
    taken.extend(t.i for t in sched.take(len(sched)))
    assert len(sched) == 0
    # conservation + exact FIFO order
    assert taken == list(range(submitted))


def test_take_never_exceeds_request_or_queue():
    sched = FifoScheduler(SchedulerConfig())
    for i in range(3):
        sched.submit(_Tag(i))
    assert [t.i for t in sched.take(2)] == [0, 1]
    assert [t.i for t in sched.take(5)] == [2]
    assert sched.take(3) == []
    assert sched.take(0) == []
    assert sched.take(-1) == []


# ---------------------------------------------------------------------------
# Admission policies: priority / EDF / aging / watermarks / backoff
# ---------------------------------------------------------------------------


class _Req:
    """Minimal request stand-in with the scheduling attributes."""

    def __init__(self, i, priority=1, deadline=None, submit_round=0,
                 not_before=0):
        self.i = i
        self.priority = priority
        self.deadline = deadline
        self.submit_round = submit_round
        self.not_before = not_before


def test_priority_classes_order_take():
    sched = AdmissionScheduler(SchedulerConfig(aging_rounds=0))
    for i, pr in enumerate([2, 0, 1, 0]):
        sched.submit(_Req(i, priority=pr))
    # (priority, fifo seq) order: both priority-0 keep submission order
    assert [r.i for r in sched.take(4)] == [1, 3, 2, 0]


def test_edf_orders_within_a_priority_class():
    sched = AdmissionScheduler(SchedulerConfig(aging_rounds=0))
    sched.submit(_Req(0))                      # no deadline -> last
    sched.submit(_Req(1, deadline=90))
    sched.submit(_Req(2, deadline=30))
    assert [r.i for r in sched.take(3)] == [2, 1, 0]


def test_aging_promotes_old_low_priority_work():
    sched = AdmissionScheduler(SchedulerConfig(aging_rounds=8))
    sched.submit(_Req(0, priority=2, submit_round=0))     # low class
    sched.submit(_Req(1, priority=1, submit_round=0))     # urgent
    # inside one aging window plain priority order holds ...
    assert sched.take(1, now_round=7)[0].i == 1
    sched.submit(_Req(1, priority=1, submit_round=16))    # fresh, urgent
    # ... but every 8 waited rounds buy one class: by round 16 the old
    # request (2 - 16//8 = 0) outranks the fresh priority-1 arrival
    assert sched.take(1, now_round=16)[0].i == 0


def test_bounded_queue_watermark_hysteresis():
    sched = AdmissionScheduler(SchedulerConfig(
        max_queue=4, high_watermark=1.0, low_watermark=0.5))
    assert [sched.submit(_Req(i)) for i in range(4)] == [ADMITTED] * 4
    assert sched.submit(_Req(4)) == REJECTED_QUEUE_FULL
    sched.take(2)
    # len == 2 is not yet below low watermark (0.5 * 4): still closed
    assert sched.submit(_Req(5)) == REJECTED_QUEUE_FULL
    sched.take(1)
    # len == 1 < 2: hysteresis re-opens admission
    assert sched.submit(_Req(6)) == ADMITTED


def test_unmeetable_deadline_shed_by_estimate():
    sched = AdmissionScheduler(SchedulerConfig())
    assert sched.submit(_Req(0, deadline=10), est_finish=11) == \
        SHED_UNMEETABLE_DEADLINE
    assert len(sched) == 0
    assert sched.submit(_Req(1, deadline=10), est_finish=10) == ADMITTED
    assert sched.submit(_Req(2), est_finish=10 ** 9) == ADMITTED  # no ddl


def test_remove_withdraws_queued_request():
    sched = AdmissionScheduler(SchedulerConfig())
    reqs = [_Req(i) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    assert sched.remove(reqs[1]) and len(sched) == 2
    assert not sched.remove(reqs[1])          # already gone
    assert [r.i for r in sched.take(3)] == [0, 2]


def test_backoff_skips_until_round_or_ignored():
    sched = AdmissionScheduler(SchedulerConfig())
    sched.submit(_Req(0, not_before=10))
    assert sched.take(1, now_round=0) == []
    assert sched.take(1, now_round=9) == []
    # an idle engine ignores backoff rather than stalling empty slots
    assert sched.take(1, now_round=0, ignore_backoff=True)[0].i == 0
    sched.submit(_Req(1, not_before=10))
    assert sched.take(1, now_round=10)[0].i == 1


# ---------------------------------------------------------------------------
# Engine-level: random arrival traces under continuous admission
# ---------------------------------------------------------------------------

def _setup():
    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


_CFG_PARAMS = {}


def _cached_setup():
    if "v" not in _CFG_PARAMS:
        _CFG_PARAMS["v"] = _setup()
    return _CFG_PARAMS["v"]


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_engine_random_trace_all_requests_complete_in_fifo_order(seed):
    """Random arrival trace: every request completes with exactly its
    max_new tokens, and staging follows submission order exactly."""
    cfg, params = _cached_setup()
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 7))
    trace = [dict(arrival=int(rng.integers(0, 12)),
                  prompt=list(rng.integers(1, 250,
                                           size=int(rng.integers(1, 9)))),
                  max_new=int(rng.integers(1, 7)))
             for _ in range(n)]
    trace.sort(key=lambda r: r["arrival"])

    engine = ServingEngine(cfg, params, max_batch=2, max_len=32,
                           decode_block=3)
    rids = []
    # replay_trace raises RuntimeError on starvation (trace not draining)
    replay_trace(engine, trace,
                 lambda i, r: rids.append(
                     engine.submit(r["prompt"], max_new=r["max_new"])),
                 max_steps=500)

    outs = {rid: engine.finished[rid].out for rid in rids}
    # conservation: all complete, exact lengths (no EOS in the trace)
    assert set(outs) == set(rids)
    for rid, r in zip(rids, trace):
        assert len(outs[rid]) == r["max_new"], (rid, r)
    # FIFO fairness: staging order == submission order
    seqs = [engine.finished[rid].admit_seq for rid in rids]
    assert seqs == sorted(seqs)
    assert engine.stats.completed == engine.stats.admitted == len(rids)


def test_engine_saturated_queue_drains_without_starvation():
    """More requests than slots + staging can hold: the backlog drains in
    strict FIFO staging order and nothing is dropped."""
    cfg, params = _cached_setup()
    engine = ServingEngine(cfg, params, max_batch=2, max_len=32,
                           decode_block=4)
    rids = [engine.submit([i + 1, i + 2], max_new=3) for i in range(9)]
    outs = engine.run_to_completion()
    assert set(outs) == set(rids)
    assert all(len(o) == 3 for o in outs.values())
    seqs = [engine.finished[r].admit_seq for r in rids]
    assert seqs == list(range(9))
    assert engine.stats.queue_peak >= 5      # 2 slots + 2 staged absorbed


def test_engine_stages_queue_head_behind_soonest_free_row():
    """Lookahead staging must not strand the queue head behind the
    longest-running request: with every row busy, the next queued
    request parks behind the row with the smallest rounds-to-free
    estimate, so it also starts (and typically finishes) first."""
    cfg, params = _cached_setup()
    engine = ServingEngine(cfg, params, max_batch=2, max_len=64,
                           decode_block=2)
    slow = engine.submit([1, 2], max_new=14)
    fast = engine.submit([3, 4], max_new=6)
    for _ in range(2):
        engine.step()                    # 4 rounds: both armed, decoding
    assert all(r is not None and not r.done for r in engine.current)
    third = engine.submit([5, 6], max_new=3)
    fourth = engine.submit([7, 8], max_new=3)
    engine.step()                        # stages third/fourth by row ETA
    # the earlier-submitted request is parked behind the sooner-free row
    fast_slot = next(r.slot for r in engine.current if r and r.rid == fast)
    assert engine.staged[fast_slot] is not None
    assert engine.staged[fast_slot].rid == third
    engine.run_to_completion()
    assert engine.finished[third].first_round < \
        engine.finished[fourth].first_round
    assert len(engine.finished[slow].out) == 14


# ---------------------------------------------------------------------------
# EngineStats latency aggregation
# ---------------------------------------------------------------------------

def test_stats_latency_aggregates():
    s = EngineStats()
    s.record_first_token(0.010, 4)
    s.record_first_token(0.030, 8)
    s.record_completion(5, 10, 18, 1.0, 1.8)  # itl = 2 rounds, 0.2s/token
    s.record_completion(1, 3, 3)              # single token: no itl sample
    s.slot_steps, s.wasted_slot_steps = 100, 25
    snap = s.snapshot()
    assert snap["ttft_s_mean"] == pytest.approx(0.020)
    assert snap["ttft_rounds_mean"] == pytest.approx(6.0)
    assert snap["ttft_s_p95"] == pytest.approx(0.030)
    assert snap["itl_rounds_mean"] == pytest.approx(2.0)
    assert snap["itl_s_mean"] == pytest.approx(0.2)
    assert snap["wasted_slot_fraction"] == pytest.approx(0.25)
    assert "ttft_s" not in snap              # raw lists stay off the wire
