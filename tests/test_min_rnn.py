"""minGRU / minLSTM: parallel == sequential, param-count ratios, stability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import gru, lstm, min_gru, min_lstm, nn, blocks


def _roll_out(step_fn, params, x, h0, **kw):
    hs = []
    h = h0
    for t in range(x.shape[-2]):
        h = step_fn(params, x[..., t, :], h, **kw)
        hs.append(h)
    return jnp.stack(hs, axis=-2)


@pytest.mark.parametrize("mode", ["log", "linear"])
def test_mingru_parallel_equals_sequential(mode):
    key = jax.random.PRNGKey(0)
    params = min_gru.init(key, 6, 10)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 17, 6))
    h0 = jnp.zeros((3, 10))
    par = min_gru.parallel(params, x, mode=mode)
    seq = _roll_out(min_gru.step, params, x, h0, mode=mode)
    np.testing.assert_allclose(par, seq, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["log", "linear"])
@pytest.mark.parametrize("normalize", [True, False])
def test_minlstm_parallel_equals_sequential(mode, normalize):
    if mode == "log" and not normalize:
        pass  # unnormalized log mode is also supported
    key = jax.random.PRNGKey(2)
    params = min_lstm.init(key, 5, 8)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 13, 5))
    h0 = jnp.zeros((2, 8))
    par = min_lstm.parallel(params, x, mode=mode, normalize=normalize)
    seq = _roll_out(min_lstm.step, params, x, h0, mode=mode,
                    normalize=normalize)
    np.testing.assert_allclose(par, seq, rtol=2e-4, atol=2e-4)


def test_mingru_nonzero_h0():
    params = min_gru.init(jax.random.PRNGKey(4), 4, 4)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 9, 4))
    h0 = nn.g(jax.random.normal(jax.random.PRNGKey(6), (2, 4)))  # positive
    par = min_gru.parallel(params, x, h0, mode="log")
    seq = _roll_out(min_gru.step, params, x, h0, mode="log")
    np.testing.assert_allclose(par, seq, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Paper claim: parameter-count ratios (Sections 3.1.3 / 3.2.4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha,expected", [(1, 1 / 3), (2, 2 / 9),
                                            (3, 1 / 6), (4, 2 / 15)])
def test_param_ratio_mingru_vs_gru(alpha, expected):
    """minGRU / GRU = 2*dh*dx / (3*dh*(dx+dh)) with dh = alpha*dx."""
    dx = 64
    dh = alpha * dx
    ratio = min_gru.n_params(dx, dh) / gru.n_params(dx, dh)
    assert abs(ratio - expected) < 1e-9
    # paper quotes ~33%, 22%, 17%, 13%
    paper = {1: 0.33, 2: 0.22, 3: 0.17, 4: 0.13}[alpha]
    assert abs(ratio - paper) < 0.006


@pytest.mark.parametrize("alpha,paper", [(1, 0.38), (2, 0.25),
                                         (3, 0.19), (4, 0.15)])
def test_param_ratio_minlstm_vs_lstm(alpha, paper):
    dx = 64
    dh = alpha * dx
    ratio = min_lstm.n_params(dx, dh) / lstm.n_params(dx, dh)
    assert abs(ratio - paper) < 0.006


def test_actual_param_counts_match_formula():
    dx, dh = 7, 11
    p = min_gru.init(jax.random.PRNGKey(0), dx, dh, use_bias=False)
    count = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert count == min_gru.n_params(dx, dh)
    p = min_lstm.init(jax.random.PRNGKey(0), dx, dh, use_bias=False)
    count = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert count == min_lstm.n_params(dx, dh)
    p = gru.init(jax.random.PRNGKey(0), dx, dh, use_bias=False)
    count = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert count == gru.n_params(dx, dh)
    p = lstm.init(jax.random.PRNGKey(0), dx, dh, use_bias=False)
    count = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert count == lstm.n_params(dx, dh)


# ---------------------------------------------------------------------------
# g() transform identities (Appendix B Listing 6)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(-30, 30))
def test_g_positive_and_log_consistent(v):
    x = jnp.asarray(v, jnp.float32)
    gx = nn.g(x)
    assert float(gx) > 0
    np.testing.assert_allclose(float(nn.log_g(x)), float(jnp.log(gx)),
                               rtol=1e-5, atol=1e-5)


def test_minlstm_normalized_gates_sum_to_one():
    params = min_lstm.init(jax.random.PRNGKey(7), 4, 6)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 5, 4)) * 5
    f, b = min_lstm.gates(params, x, mode="linear", normalize=True)
    # b = i' * h~ ; recover i' indirectly: f' + i' == 1
    kf = nn.dense_apply(params["wf"], x)
    ki = nn.dense_apply(params["wi"], x)
    ff, ii = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    np.testing.assert_allclose(ff / (ff + ii) + ii / (ff + ii),
                               np.ones_like(f), rtol=1e-6)
    np.testing.assert_allclose(f, ff / (ff + ii), rtol=1e-6)


# ---------------------------------------------------------------------------
# Traditional baselines sanity
# ---------------------------------------------------------------------------

def test_gru_forward_shapes_finite():
    p = gru.init(jax.random.PRNGKey(9), 5, 7)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 11, 5))
    h = gru.forward(p, x)
    assert h.shape == (2, 11, 7)
    assert bool(jnp.all(jnp.isfinite(h)))


def test_lstm_forward_shapes_finite():
    p = lstm.init(jax.random.PRNGKey(11), 5, 7)
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 11, 5))
    h = lstm.forward(p, x)
    assert h.shape == (2, 11, 7)
    assert bool(jnp.all(jnp.isfinite(h)))


# ---------------------------------------------------------------------------
# Block: parallel == step roll-out (prefill/decode consistency at block level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
@pytest.mark.parametrize("use_conv,use_mlp", [(False, False), (True, True)])
def test_block_parallel_equals_step(cell, use_conv, use_mlp):
    cfg = blocks.MinRNNBlockConfig(d_model=8, cell=cell, expansion=2.0,
                                   use_conv=use_conv, use_mlp=use_mlp)
    params = blocks.init(jax.random.PRNGKey(13), cfg)
    x = jax.random.normal(jax.random.PRNGKey(14), (2, 7, 8))
    par = blocks.apply(params, cfg, x)
    state = blocks.init_state(cfg, (2,))
    outs = []
    for t in range(x.shape[1]):
        y, state = blocks.step(params, cfg, x[:, t], state)
        outs.append(y)
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(par, seq, rtol=3e-4, atol=3e-4)


def test_mingru_grad_through_long_sequence_finite():
    params = min_gru.init(jax.random.PRNGKey(15), 8, 8)
    x = jax.random.normal(jax.random.PRNGKey(16), (1, 2048, 8))

    def loss(p):
        return jnp.mean(min_gru.parallel(p, x, mode="log") ** 2)

    grads = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
