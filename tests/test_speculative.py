"""Speculative decoding: draft -> chunk-verify -> O(d_hidden) rollback.

Speculation may only change *when* tokens are emitted -- never what gets
generated.  The contract tested here, bottom-up:

  * admission boundary: a request needs ``len(prompt) + max_new - 1``
    cache positions -- submit/generate_one accept exactly that and
    reject one more (the off-by-one regression);
  * every draft source (n-gram self-draft, tiny draft model, the
    constant-token rejection stressor) streams bit-identical to the
    non-speculative engine -- greedy AND seeded -- across decode_block,
    prompt_chunk and draft-length combos, for both cell archs;
  * rollback is exact at the extremes: first-token rejection (every
    draft thrown away, state rolls back to the one committed position),
    full acceptance (target-as-draft oracle: ``draft_accepted ==
    draft_proposed``), and EOS landing *inside* an accepted draft run
    (emission truncates at EOS, the slot retires that round);
  * the stats identities hold exactly: ``decode_tokens ==
    draft_accepted + non_spec_tokens`` and the slot-step identity with
    ``non_spec_tokens`` in place of ``decode_tokens`` (a spec round is
    ONE slot-step however many tokens it emits);
  * the staging ETA reads device-synced prompt progress, not the full
    prompt length (the mid-prefill overestimate regression).
"""

import jax
import numpy as np
import pytest

from repro.configs import archs
from repro.models import lm
from repro.serving import draft as draft_lib
from repro.serving.engine import ServingEngine, generate_one

MAX_LEN = 64


def _setup(arch):
    cfg = archs.smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(n, seed=0, lo=2, hi=14):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, 250, size=int(rng.integers(lo, hi))))
            for _ in range(n)]


def _run_engine(cfg, params, prompts, max_new=10, *, eos=None,
                temperature=0.0, seed=0, **kw):
    eng = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                        seed=seed, **kw)
    rids = [eng.submit(p, max_new=max_new, temperature=temperature,
                       top_k=0, top_p=1.0, eos=eos) for p in prompts]
    outs = eng.run_to_completion()
    return [outs[r] for r in rids], eng


# ---------------------------------------------------------------------------
# Admission boundary (the off-by-one regression)
# ---------------------------------------------------------------------------

def test_submit_accepts_exact_cache_budget():
    """len(prompt) + max_new - 1 == max_len is admissible: the final
    output token is emitted without being fed back."""
    cfg, params = _setup("mingru-lm")
    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN)
    prompt = list(range(1, 11))                        # 10 tokens
    rid = eng.submit(prompt, max_new=MAX_LEN - len(prompt) + 1)
    outs = eng.run_to_completion()
    assert len(outs[rid]) == MAX_LEN - len(prompt) + 1


def test_submit_rejects_one_past_cache_budget():
    cfg, params = _setup("mingru-lm")
    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN)
    prompt = list(range(1, 11))
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(prompt, max_new=MAX_LEN - len(prompt) + 2)


def test_generate_one_boundary_matches_submit():
    cfg, params = _setup("mingru-lm")
    prompt = list(range(1, 11))
    out = generate_one(cfg, params, prompt,
                       max_new=MAX_LEN - len(prompt) + 1, max_len=MAX_LEN)
    assert len(out) == MAX_LEN - len(prompt) + 1
    with pytest.raises(ValueError, match="cache positions"):
        generate_one(cfg, params, prompt,
                     max_new=MAX_LEN - len(prompt) + 2, max_len=MAX_LEN)


# ---------------------------------------------------------------------------
# Stream parity: speculative == non-speculative, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mingru-lm", "minlstm-lm"])
@pytest.mark.parametrize("k,c,s", [(1, 1, 1), (4, 2, 3), (3, 4, 4),
                                   (8, 1, 2)])
def test_ngram_greedy_streams_bitexact(arch, k, c, s):
    cfg, params = _setup(arch)
    prompts = _prompts(5, seed=arch == "minlstm-lm")
    base, _ = _run_engine(cfg, params, prompts)
    spec, _ = _run_engine(cfg, params, prompts, speculative="ngram",
                          draft_len=s, decode_block=k, prompt_chunk=c)
    assert spec == base


@pytest.mark.parametrize("source", ["fixed", "oracle"])
def test_other_sources_greedy_streams_bitexact(source):
    cfg, params = _setup("mingru-lm")
    prompts = _prompts(5, seed=2)
    base, _ = _run_engine(cfg, params, prompts)
    if source == "fixed":
        drf = draft_lib.FixedDraft(251, draft_len=3)
    else:
        drf = draft_lib.ModelDraft(cfg, params, draft_len=3)
    spec, _ = _run_engine(cfg, params, prompts, speculative=drf,
                          decode_block=4, prompt_chunk=2)
    assert spec == base


@pytest.mark.parametrize("arch", ["mingru-lm", "minlstm-lm"])
def test_seeded_sampling_unchanged_under_speculation(arch):
    """Emission-aligned keys: a request's k-th output token uses the
    k-th key in its slot chain whether it arrived via a spec multi-emit
    or a plain round, so seeded streams are bit-identical."""
    cfg, params = _setup(arch)
    prompts = _prompts(4, seed=3)
    base, _ = _run_engine(cfg, params, prompts, temperature=0.8, seed=7)
    for s in (1, 3):
        spec, _ = _run_engine(cfg, params, prompts, temperature=0.8,
                              seed=7, speculative="ngram", draft_len=s,
                              decode_block=3, prompt_chunk=2)
        assert spec == base, f"draft_len={s}"


# ---------------------------------------------------------------------------
# Rollback extremes
# ---------------------------------------------------------------------------

def test_first_token_rejection_rolls_back_exactly():
    """A constant-token draft the target never emits: every proposal is
    rejected at position 0, so every round commits exactly one token
    and the stream must still match -- the rollback-to-prefix path
    under maximal stress."""
    cfg, params = _setup("mingru-lm")
    prompts = _prompts(4, seed=4)
    base, _ = _run_engine(cfg, params, prompts)
    drf = draft_lib.FixedDraft(251, draft_len=4)
    spec, eng = _run_engine(cfg, params, prompts, speculative=drf,
                            decode_block=4)
    assert spec == base
    assert eng.stats.draft_proposed > 0
    assert eng.stats.draft_accepted == 0
    assert eng.stats.non_spec_tokens == eng.stats.decode_tokens


def test_oracle_draft_full_acceptance():
    """The target model drafting for itself is exact: every proposed
    token is accepted (greedy verify reproduces greedy propose)."""
    cfg, params = _setup("mingru-lm")
    prompts = _prompts(4, seed=5)
    base, _ = _run_engine(cfg, params, prompts)
    drf = draft_lib.ModelDraft(cfg, params, draft_len=3)
    spec, eng = _run_engine(cfg, params, prompts, speculative=drf,
                            decode_block=4)
    assert spec == base
    assert eng.stats.draft_proposed > 0
    assert eng.stats.draft_accepted == eng.stats.draft_proposed
    snap = eng.stats.snapshot()
    assert snap["accept_rate"] == 1.0
    # multi-emit is real: fewer emitting rounds than tokens
    assert eng.stats.non_spec_tokens < eng.stats.decode_tokens
    assert snap["itl_rounds_mean"] < 1.0


def test_eos_inside_accepted_draft_truncates():
    """EOS emitted mid-way through an accepted draft run must truncate
    the emission at the EOS position and retire the slot that round."""
    cfg, params = _setup("mingru-lm")
    prompts = _prompts(3, seed=3)
    base, _ = _run_engine(cfg, params, prompts, max_new=12)
    # pick an EOS token whose FIRST occurrence is mid-stream (index >= 2)
    # in some row, so the oracle's accepted draft run straddles it
    eos = next((t for o in base for j, t in enumerate(o)
                if j >= 2 and t not in o[:j]), None)
    assert eos is not None, "degenerate reference streams"
    ref, _ = _run_engine(cfg, params, prompts, max_new=12, eos=eos)
    drf = draft_lib.ModelDraft(cfg, params, draft_len=4)
    spec, eng = _run_engine(cfg, params, prompts, max_new=12, eos=eos,
                            speculative=drf, decode_block=4)
    assert spec == ref
    # the EOS stream really ends in eos and is shorter than max_new
    assert any(o and o[-1] == eos and len(o) < 12 for o in spec)
    assert eng.stats.completed == len(prompts)


# ---------------------------------------------------------------------------
# Stats identities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_kw", [
    dict(),
    dict(speculative="ngram", draft_len=3),
    dict(speculative="ngram", draft_len=3, prompt_chunk=4),
])
def test_stats_identities(spec_kw):
    cfg, params = _setup("mingru-lm")
    prompts = _prompts(6, seed=7)
    outs, eng = _run_engine(cfg, params, prompts, decode_block=4,
                            **spec_kw)
    st = eng.stats
    assert st.decode_tokens == sum(len(o) for o in outs)
    assert st.decode_tokens == st.draft_accepted + st.non_spec_tokens
    # a request's first token rides its final prefill round, so each
    # completed request contributes one prefill/emit overlap round
    overlaps = len(st.ttft_rounds)
    assert st.slot_steps == (st.prefill_rounds + st.non_spec_tokens
                             - overlaps + st.wasted_slot_steps)
    if spec_kw.get("speculative"):
        assert st.draft_proposed > 0
        assert 0 <= st.draft_accepted <= st.draft_proposed
    else:
        assert st.draft_proposed == 0 and st.draft_accepted == 0


def test_row_eta_uses_device_synced_prompt_progress():
    """Mid-prefill the ETA must charge only the prompt tokens the device
    has NOT yet consumed (the synced prompt_pos mirror)."""
    cfg, params = _setup("mingru-lm")
    eng = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                        prompt_chunk=4)
    eng.submit(list(range(1, 14)), max_new=5)          # 13 prompt tokens
    eng._stage()
    eng._upload_staging()
    eng.step(n_tokens=1)       # device consumed 4 of 13 prompt tokens
    assert int(eng._prompt_pos[0]) == 4
    assert eng._row_eta(0) == -(-(13 - 4) // 4) + 5    # ceil(9/4)+5 = 8
    eng.step(n_tokens=1)
    assert eng._row_eta(0) == -(-(13 - 8) // 4) + 5    # ceil(5/4)+5 = 7
