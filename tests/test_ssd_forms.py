"""SSD dual forms: masked (paper-faithful) vs compact (beyond-paper) must
agree with the sequential oracle, including the strong-decay stress case
that refuted the factored-decay attempt (EXPERIMENTS.md §Perf)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssd


def _inputs(seed, t=40, nh=8, hd=8, g=2, ds=8, dt_scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (2, t, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, t, nh))) * dt_scale
    b = jax.random.normal(ks[2], (2, t, g, ds))
    c = jax.random.normal(ks[3], (2, t, g, ds))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, nh))
    return x, dt, b, c, a_log, jnp.ones(nh)


@pytest.mark.parametrize("form", ["masked", "compact"])
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_forms_match_sequential(form, chunk):
    x, dt, b, c, a_log, dsk = _inputs(0)
    seq = ssd.ssd_sequential(x, dt, a_log, b, c, dsk)
    y = ssd.ssd_chunked(x, dt, a_log, b, c, dsk, chunk=chunk, form=form)
    np.testing.assert_allclose(y, seq, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("form", ["masked", "compact"])
def test_forms_strong_decay(form):
    """Per-chunk decay >> e^30: the regime that broke factored decay."""
    x, dt, b, c, a_log, dsk = _inputs(1, dt_scale=20.0)
    seq = ssd.ssd_sequential(x, dt, a_log, b, c, dsk)
    y = ssd.ssd_chunked(x, dt, a_log, b, c, dsk, chunk=8, form=form)
    np.testing.assert_allclose(y, seq, rtol=1e-3, atol=1e-3)


def test_forms_grads_match():
    x, dt, b, c, a_log, dsk = _inputs(2)

    def loss(form):
        def f(args):
            return jnp.mean(ssd.ssd_chunked(*args, dsk, chunk=8,
                                            form=form) ** 2)
        return jax.grad(f)((x, dt, a_log, b, c))

    g_m = loss("masked")
    g_c = loss("compact")
    for a, b_ in zip(jax.tree.leaves(g_m), jax.tree.leaves(g_c)):
        np.testing.assert_allclose(a, b_, rtol=2e-3, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]))
def test_property_compact_equals_masked(seed, chunk):
    x, dt, b, c, a_log, dsk = _inputs(seed, t=24, nh=4, hd=4, g=1, ds=4)
    y_m = ssd.ssd_chunked(x, dt, a_log, b, c, dsk, chunk=chunk,
                          form="masked")
    y_c = ssd.ssd_chunked(x, dt, a_log, b, c, dsk, chunk=chunk,
                          form="compact")
    np.testing.assert_allclose(y_m, y_c, rtol=2e-4, atol=2e-4)


def test_masked_form_no_nan_gradient_at_extreme_decay():
    """Regression: exp(seg) on the masked triangle used to overflow and
    its inf cotangent x 0 produced NaN grads once dt grew during training
    (fig2 mamba2 NaN at ~150 steps)."""
    x, dt, b, c, a_log, dsk = _inputs(3, dt_scale=50.0)

    def loss(args):
        y = ssd.ssd_chunked(*args, dsk, chunk=8, form="masked")
        return jnp.mean(y ** 2)

    g = jax.grad(loss)((x, dt, a_log, b, c))
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf))), "NaN/inf gradient"
