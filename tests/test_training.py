"""Training substrate: optimizer vs numpy reference, grad-accumulation
equivalence, checkpoint roundtrip + restart, fault-tolerant supervisor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.data import lm_corpus
from repro.models import lm
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib
from repro.training.fault_tolerance import TrainSupervisor


# ---------------------------------------------------------------------------
# AdamW vs a straight-line numpy reference
# ---------------------------------------------------------------------------

def _np_adamw(p, g, mu, nu, step, cfg, wd_on):
    mu = cfg.b1 * mu + (1 - cfg.b1) * g
    nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
    lr = float(opt_lib.schedule_lr(cfg, jnp.asarray(step)))
    mu_hat = mu / (1 - cfg.b1 ** step)
    nu_hat = nu / (1 - cfg.b2 ** step)
    p = p - lr * (mu_hat / (np.sqrt(nu_hat) + cfg.eps)
                  + cfg.weight_decay * wd_on * p)
    return p, mu, nu


def test_adamw_matches_numpy_reference():
    cfg = opt_lib.AdamWConfig(lr=1e-2, grad_clip=0.0, warmup_steps=0,
                              schedule="constant")
    params = {"w": {"kernel": jnp.ones((3, 4))},
              "norm": {"scale": jnp.ones((4,))}}
    state = opt_lib.init(cfg, params)
    g = {"w": {"kernel": jnp.full((3, 4), 0.5)},
         "norm": {"scale": jnp.full((4,), 0.25)}}
    p_np = np.ones((3, 4))
    mu_np = np.zeros((3, 4))
    nu_np = np.zeros((3, 4))
    p, s = params, state
    for step in range(1, 4):
        p, s, _ = opt_lib.apply(cfg, s, p, g)
        p_np, mu_np, nu_np = _np_adamw(p_np, np.full((3, 4), 0.5), mu_np,
                                       nu_np, step, cfg, wd_on=1.0)
        np.testing.assert_allclose(p["w"]["kernel"], p_np, rtol=1e-5)
    # norms get no weight decay: pure adam on scale
    assert not np.allclose(p["norm"]["scale"], 1.0)


def test_grad_clip_bounds_update():
    cfg = opt_lib.AdamWConfig(grad_clip=1.0, warmup_steps=0,
                              schedule="constant", weight_decay=0.0)
    params = {"w": {"kernel": jnp.zeros((4, 4))}}
    state = opt_lib.init(cfg, params)
    g = {"w": {"kernel": jnp.full((4, 4), 100.0)}}
    _, _, metrics = opt_lib.apply(cfg, state, params, g)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)


def test_schedule_shapes():
    cfg = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
    lrs = [float(opt_lib.schedule_lr(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# gradient accumulation == large batch
# ---------------------------------------------------------------------------

def test_microbatch_accumulation_matches_full_batch():
    cfg = archs.smoke("mingru-lm")
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    data, _ = lm_corpus.build_corpus()
    batch = lm_corpus.lm_batch(data, 0, 0, 8, 32)

    step1 = jax.jit(ts_lib.make_train_step(cfg, ocfg, microbatches=1))
    step4 = jax.jit(ts_lib.make_train_step(cfg, ocfg, microbatches=4))
    o1 = opt_lib.init(ocfg, params)
    o4 = opt_lib.init(ocfg, params)
    p1, _, m1 = step1(params, o1, batch)
    p4, _, m4 = step4(params, o4, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# checkpoint roundtrip / restart / GC
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.AdamWConfig()
    opt_state = opt_lib.init(ocfg, params)
    path = ckpt_lib.save(str(tmp_path), 7, params, opt_state)
    step, p2, o2 = ckpt_lib.restore(path)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2.step) == int(opt_state.step)


def test_checkpoint_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.ones((3,), jnp.bfloat16) * 1.5}
    path = ckpt_lib.save(str(tmp_path), 1, tree)
    _, t2, _ = ckpt_lib.restore(path)
    assert t2["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(t2["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_checkpoint_manager_gc_and_latest(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=2, save_interval=1)
    tree = {"w": jnp.zeros((2,))}
    for step in (1, 2, 3):
        mgr.maybe_save(step, {"w": jnp.full((2,), float(step))})
    assert ckpt_lib.latest_step(str(tmp_path)) == 3
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2          # GC kept only 2


def test_supervisor_recovers_from_failure(tmp_path):
    cfg = archs.smoke("mingru-lm")
    ocfg = opt_lib.AdamWConfig(lr=1e-3, total_steps=20)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(ocfg, params)
    data, _ = lm_corpus.build_corpus()
    step_fn = jax.jit(ts_lib.make_train_step(cfg, ocfg))
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=2, save_interval=5)
    sup = TrainSupervisor(step_fn,
                          lambda s: lm_corpus.lm_batch(data, 0, s, 4, 32),
                          mgr)
    fired = []

    def hook(step):
        if step == 12 and not fired:
            fired.append(step)
            raise RuntimeError("injected fault")

    sup.failure_hook = hook
    params, opt_state, report = sup.run(params, opt_state, 15)
    assert report.failures_recovered == 1
    assert report.restarts == [12]
    assert report.steps_run >= 15 - 10   # resumed from ckpt at 10


def test_checkpoint_checksum_detects_corruption(tmp_path):
    path = ckpt_lib.save(str(tmp_path), 3, {"w": jnp.arange(4.0)})
    assert ckpt_lib.verify(path)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        f.write(bytes([f.read(1)[0] ^ 0xFF]))       # flip one byte
    assert not ckpt_lib.verify(path)
    with pytest.raises(ckpt_lib.CheckpointCorruptError):
        ckpt_lib.restore(path)


def test_restore_latest_falls_back_past_corrupt(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=5)
    for step in (1, 2):
        ckpt_lib.save(str(tmp_path), step, {"w": jnp.full((2,),
                                                          float(step))})
    with open(os.path.join(tmp_path, "step_00000002", "arrays.npz"),
              "r+b") as f:
        f.write(b"\x00" * 16)                       # corrupt the newest
    step, params, _ = mgr.restore_latest()
    assert step == 1                                # fell back one save
    np.testing.assert_array_equal(np.asarray(params["w"]), [1.0, 1.0])
    assert mgr.corrupt_skipped == [2]
    # with every checkpoint corrupt, restore_latest reports None
    with open(os.path.join(tmp_path, "step_00000001", "arrays.npz"),
              "r+b") as f:
        f.write(b"\x00" * 16)
    assert mgr.restore_latest() is None
    assert mgr.corrupt_skipped == [2, 2, 1]


def test_supervisor_falls_back_past_corrupt_checkpoint(tmp_path):
    """A failure whose newest checkpoint is corrupt recovers from the
    previous good one; ``report.ckpt_fallbacks`` records the skip."""
    for step in (5, 10):
        ckpt_lib.save(str(tmp_path), step, {"w": jnp.full((1,),
                                                          float(step))})
    with open(os.path.join(tmp_path, "step_00000010", "arrays.npz"),
              "r+b") as f:
        f.write(b"\x00" * 16)
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=5,
                                     save_interval=10 ** 6)
    sup = TrainSupervisor(lambda p, o, b: (p, o, {}), lambda s: s, mgr)
    fired = []

    def hook(step):
        if step == 12 and not fired:
            fired.append(step)
            raise RuntimeError("injected fault")

    sup.failure_hook = hook
    params, _, report = sup.run({"w": jnp.zeros((1,))}, None, 14,
                                start_step=11)
    assert report.failures_recovered == 1
    assert report.restarts == [12]
    assert report.ckpt_fallbacks == 1        # skipped the corrupt step-10
    np.testing.assert_array_equal(np.asarray(params["w"]), [5.0])


def test_supervisor_restore_fast_forward_reproduces_trajectory(tmp_path):
    """The data pipeline is a pure function of step index, so a crash +
    restore + fast-forward must reproduce the exact no-failure metric
    trajectory from the restore point on."""
    def step_fn(params, opt_state, batch):
        w = params["w"] + batch
        return {"w": w}, opt_state, {"loss": float(w)}

    def run(d, hook):
        mgr = ckpt_lib.CheckpointManager(d, keep=5, save_interval=3)
        sup = TrainSupervisor(step_fn, lambda s: s, mgr)
        seen = []

        def wrapped(step):
            seen.append(step)
            if hook is not None:
                hook(step)

        sup.failure_hook = wrapped
        params, _, report = sup.run({"w": jnp.zeros(())}, None, 10)
        return float(params["w"]), seen, report

    ref, ref_steps, _ = run(str(tmp_path / "a"), None)
    fired = []

    def hook(step):
        if step == 7 and not fired:
            fired.append(step)
            raise RuntimeError("crash")

    got, steps, report = run(str(tmp_path / "b"), hook)
    assert report.failures_recovered == 1
    assert got == ref                        # identical final state
    assert steps[-4:] == ref_steps[-4:]      # replayed 6..9 after restore
    assert steps.count(7) == 2               # the failed step was re-run


def test_supervisor_straggler_watchdog_counts_slow_steps(tmp_path):
    import time as _time

    def step_fn(params, opt_state, batch):
        _time.sleep(0.2 if batch == 4 else 0.02)
        return params, opt_state, {}

    mgr = ckpt_lib.CheckpointManager(str(tmp_path),
                                     save_interval=10 ** 6)
    sup = TrainSupervisor(step_fn, lambda s: s, mgr, straggler_factor=4.0)
    _, _, report = sup.run({}, None, 6)
    assert report.straggler_events >= 1      # step 4 blew the EMA budget
    assert report.steps_run == 6


def test_supervisor_gives_up_after_max_retries(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path))
    sup = TrainSupervisor(lambda p, o, b: (p, o, {}), lambda s: None, mgr,
                          max_retries=2)

    def hook(step):
        raise RuntimeError("always fails")

    sup.failure_hook = hook
    with pytest.raises(RuntimeError):
        sup.run({}, {}, 5)


def test_dp_compressed_step_runs_single_device():
    """shard_map DP path with bf16 grad psum on a 1x1 mesh."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = archs.smoke("mingru-lm")
    ocfg = opt_lib.AdamWConfig(lr=1e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(ocfg, params)
    data, _ = lm_corpus.build_corpus()
    batch = lm_corpus.lm_batch(data, 0, 0, 4, 32)
    step = ts_lib.make_dp_compressed_step(cfg, ocfg, mesh)
    p2, o2, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
