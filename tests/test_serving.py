"""Serving engine: continuous batching must reproduce the single-request
path exactly (greedy), across cache kinds (RNN state / KV / SSD state)."""

import jax
import numpy as np
import pytest

from repro.configs import archs
from repro.models import lm
from repro.serving.engine import ServingEngine, generate_one


@pytest.mark.parametrize("arch", ["mingru-lm", "mamba2-370m", "gemma-2b"])
def test_engine_matches_single_request(arch):
    cfg = archs.smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[1, 2, 3, 4], [5, 6, 7], [2, 4, 6, 8, 10, 1]]
    singles = [generate_one(cfg, params, p, max_new=6, max_len=64)
               for p in prompts]

    engine = ServingEngine(cfg, params, max_batch=2, max_len=64)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, singles):
        assert outs[rid] == ref, (outs[rid], ref)


def test_engine_queueing_more_requests_than_slots():
    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=2, max_len=32)
    rids = [engine.submit([i + 1, i + 2], max_new=4) for i in range(5)]
    outs = engine.run_to_completion()
    assert set(outs) == set(rids)
    assert all(len(o) == 4 for o in outs.values())


def test_engine_eos_stops_early():
    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # find the first greedy token, then use it as EOS
    first = generate_one(cfg, params, [1, 2, 3], max_new=2, max_len=32)[1]
    engine = ServingEngine(cfg, params, max_batch=1, max_len=32)
    rid = engine.submit([1, 2, 3], max_new=16, eos=first)
    outs = engine.run_to_completion()
    assert len(outs[rid]) <= 16
    assert outs[rid][-1] == first or len(outs[rid]) == 16
