"""Serving engine v4 (the superstep): continuous batching with in-loop
prefill, sampling and re-admission must reproduce the single-request path
exactly (greedy), across cache kinds (RNN state / KV / MLA latent / SSD
state / hybrid), admission orders, mid-stream admissions, slot reuse and
long prompts.  ``generate_one`` drives the prompt through the same
``lm.decode_step`` path the superstep uses, so greedy parity is
bit-exact; the parallel ``lm.prefill`` keeps its own padding-invariance
contract (and argmax-matches the sequential path) below."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import archs
from repro.models import lm
from repro.serving.engine import ServingEngine, generate_one

MAX_LEN = 64


def _setup(arch):
    cfg = archs.smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Greedy parity with the single-request reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mingru-lm", "mamba2-370m", "gemma-2b",
                                  "zamba2-2.7b", "gemma-2b-mingru"])
def test_engine_matches_single_request(arch):
    cfg, params = _setup(arch)
    prompts = [[1, 2, 3, 4], [5, 6, 7], [2, 4, 6, 8, 10, 1]]
    singles = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
               for p in prompts]

    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, singles):
        assert outs[rid] == ref, (outs[rid], ref)


def test_generate_one_matches_parallel_prefill():
    """The sequential reference (prompt via decode_step) must agree with
    the parallel-prefill path on greedy streams: the two prompt paths are
    the same recurrence evaluated in different associativity orders, so
    logits agree to fp32 rounding and argmax streams coincide."""
    for arch in ("mingru-lm", "minlstm-lm", "gemma-2b"):
        cfg, params = _setup(arch)
        for prompt in ([1, 2, 3, 4], [7, 5, 3], [2] * 9):
            seq = generate_one(cfg, params, prompt, max_new=6,
                               max_len=MAX_LEN)
            logits, cache = lm.prefill(
                params, cfg, jnp.asarray([prompt], jnp.int32), MAX_LEN)
            par = [int(np.asarray(logits)[0, :cfg.vocab_size].argmax())]
            for _ in range(5):
                logits, cache = lm.decode_step(
                    params, cfg, jnp.asarray([par[-1]], jnp.int32), cache)
                par.append(int(np.asarray(logits)[0,
                                                  :cfg.vocab_size].argmax()))
            assert seq == par, (arch, prompt, seq, par)


@pytest.mark.parametrize("arch", ["mingru-lm", "gemma-2b"])
def test_engine_mixed_admission_order(arch):
    """Per-request output is independent of submission order and of which
    other requests share the batch."""
    cfg, params = _setup(arch)
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7], [3, 1, 4, 1, 5, 9], [2, 6]]
    refs = {tuple(p): generate_one(cfg, params, p, max_new=5,
                                   max_len=MAX_LEN) for p in prompts}
    for order in ([0, 1, 2, 3], [3, 2, 1, 0], [2, 0, 3, 1]):
        engine = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN)
        rids = {engine.submit(prompts[i], max_new=5): tuple(prompts[i])
                for i in order}
        outs = engine.run_to_completion()
        for rid, key in rids.items():
            assert outs[rid] == refs[key], (order, key)


@pytest.mark.parametrize("arch", ["mingru-lm", "mamba2-370m"])
def test_engine_mid_stream_admission(arch):
    """Requests submitted while others are decoding join the running batch
    without disturbing them."""
    cfg, params = _setup(arch)
    first = [[1, 2, 3, 4], [5, 6, 7, 8, 9]]
    late = [[2, 4, 6], [7, 5, 3, 1]]
    refs = [generate_one(cfg, params, p, max_new=8, max_len=MAX_LEN)
            for p in first + late]

    engine = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN)
    rids = [engine.submit(p, max_new=8) for p in first]
    for _ in range(3):
        engine.step()
    rids += [engine.submit(p, max_new=8) for p in late]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)


def test_engine_queueing_more_requests_than_slots():
    cfg, params = _setup("mingru-lm")
    engine = ServingEngine(cfg, params, max_batch=2, max_len=32)
    rids = [engine.submit([i + 1, i + 2], max_new=4) for i in range(5)]
    outs = engine.run_to_completion()
    assert set(outs) == set(rids)
    assert all(len(o) == 4 for o in outs.values())
    assert engine.stats.completed == 5
    assert engine.stats.queue_peak >= 1        # staging absorbs 2x batch


def test_engine_eos_stops_early_and_slot_is_reused():
    cfg, params = _setup("mingru-lm")
    # find the first greedy token, then use it as EOS
    first = generate_one(cfg, params, [1, 2, 3], max_new=2, max_len=32)[1]
    engine = ServingEngine(cfg, params, max_batch=1, max_len=32)
    rid = engine.submit([1, 2, 3], max_new=16, eos=first)
    # a second request queued behind the EOS'd one must reuse slot 0 and
    # still match its clean-engine reference
    ref = generate_one(cfg, params, [4, 5, 6, 7], max_new=6, max_len=32)
    rid2 = engine.submit([4, 5, 6, 7], max_new=6)
    outs = engine.run_to_completion()
    assert len(outs[rid]) <= 16
    assert outs[rid][-1] == first or len(outs[rid]) == 16
    assert outs[rid2] == ref


def test_engine_slot_reuse_after_eos_matches_reference():
    """Slots freed by EOS are recycled mid-flight; the recycled slot's new
    request must be bit-equal to a fresh single-request run."""
    cfg, params = _setup("mingru-lm")
    eos_tok = generate_one(cfg, params, [1, 2, 3], max_new=2,
                           max_len=MAX_LEN)[1]
    prompts = [[1, 2, 3], [6, 5, 4, 3], [9, 9, 1], [2, 7, 1, 8, 2]]
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN)
    rid0 = engine.submit(prompts[0], max_new=16, eos=eos_tok)  # dies fast
    rids = [engine.submit(p, max_new=7) for p in prompts[1:]]
    outs = engine.run_to_completion()
    assert outs[rid0][-1] == eos_tok
    for rid, p in zip(rids, prompts[1:]):
        ref = generate_one(cfg, params, p, max_new=7, max_len=MAX_LEN)
        assert outs[rid] == ref


# ---------------------------------------------------------------------------
# Long prompts prefill inside the decode loop (no phase, no barrier)
# ---------------------------------------------------------------------------

def test_engine_long_prompts_prefill_in_loop():
    """Mixed long/short prompts: every prompt token is consumed by the
    superstep itself (teacher-forced rounds) and streams still match the
    single-request reference."""
    cfg, params = _setup("mingru-lm")
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, 200, size=n)) for n in (19, 7, 26, 3)]
    refs = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
            for p in prompts]
    engine = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                           decode_block=4)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)
    assert engine.stats.prefill_tokens == sum(len(p) for p in prompts)


def test_engine_long_prompt_does_not_block_short_requests():
    """A long prompt occupies one row while short requests admitted later
    decode to completion beside it -- there is no prefill barrier."""
    cfg, params = _setup("mingru-lm")
    rng = np.random.default_rng(1)
    long_p = list(rng.integers(1, 200, size=40))
    shorts = [[1, 2, 3], [4, 5]]
    refs = [generate_one(cfg, params, p, max_new=5, max_len=MAX_LEN)
            for p in [long_p] + shorts]
    engine = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                           decode_block=4)
    rids = [engine.submit(long_p, max_new=5)]
    engine.step()
    rids += [engine.submit(p, max_new=5) for p in shorts]
    for _ in range(4):
        engine.step()                       # 5 steps x K=4 = 20 rounds
    # shorts (len 3+5, 2+5 rounds) are done; the 40-token prompt is not
    assert engine.finished and rids[0] not in engine.finished
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)


def test_prefill_resume_raises_for_unsupported_arch():
    cfg, params = _setup("gemma-2b")
    with pytest.raises(NotImplementedError):
        lm.prefill(params, cfg, jnp.asarray([[1, 2]], jnp.int32), 32,
                   cache=lm.init_cache(cfg, 1, 32))


# ---------------------------------------------------------------------------
# Batched-prefill padding invariance (the parallel-path contract; training
# and batch eval use lm.prefill even though serving now steps the prompt)
# ---------------------------------------------------------------------------

def _prefill_rows_vs_single(arch, prompts, exact):
    cfg, params = _setup(arch)
    t_pad = max(len(p) for p in prompts) + 3        # force real padding
    toks = np.zeros((len(prompts), t_pad), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    lg_b, cache_b = lm.prefill(params, cfg, jnp.asarray(toks), MAX_LEN,
                               lengths=lengths)
    for i, p in enumerate(prompts):
        lg1, c1 = lm.prefill(params, cfg, jnp.asarray([p], jnp.int32),
                             MAX_LEN)
        for name in c1:
            if name == "pos":
                assert int(cache_b["pos"][i]) == int(c1["pos"][0]) == len(p)
                continue
            big, one = cache_b[name], c1[name]
            if name in ("k", "v", "ckv", "krope"):
                # KV caches: only positions < len are meaningful
                big, one = big[:, i, :len(p)], one[:, 0, :len(p)]
            else:
                big, one = big[:, i], one[:, 0]
            if exact:
                np.testing.assert_array_equal(np.asarray(big),
                                              np.asarray(one),
                                              err_msg=f"{arch}/{name}[{i}]")
            else:
                np.testing.assert_allclose(np.asarray(big), np.asarray(one),
                                           rtol=1e-5, atol=1e-5,
                                           err_msg=f"{arch}/{name}[{i}]")
        if exact:
            np.testing.assert_array_equal(np.asarray(lg_b[i]),
                                          np.asarray(lg1[0]))
        else:
            np.testing.assert_allclose(np.asarray(lg_b[i]),
                                       np.asarray(lg1[0]),
                                       rtol=1e-5, atol=1e-5)
        # argmax (greedy token) parity must hold regardless
        assert int(jnp.argmax(lg_b[i])) == int(jnp.argmax(lg1[0]))


@pytest.mark.parametrize("arch,exact", [
    ("mingru-lm", True),        # pure recurrence: bit-exact
    ("minlstm-lm", True),
    ("mamba2-370m", True),      # SSD with inert-step masking: bit-exact
    ("zamba2-2.7b", True),      # hybrid
    ("gemma-2b-mingru", True),  # minGRU mixer in an attention trunk
    # XLA fuses the lax.scan-over-layers attention body differently per
    # sequence length, reassociating a reduction (~1e-6); argmax parity
    # still checked exactly
    ("gemma-2b", False),
    ("deepseek-v3-671b", False),
])
def test_batched_prefill_padding_invariance(arch, exact):
    _prefill_rows_vs_single(arch, [[1, 2, 3, 4], [5, 6, 7],
                                   [2, 4, 6, 8, 10, 1, 3, 7, 9]], exact)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_padding_invariance_mingru(seed):
    """Random prompt lengths/content: padded batched prefill states are
    identical to unpadded per-request prefill (paper arch, bit-exact)."""
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(1, 250, size=int(n)))
               for n in rng.integers(1, 20, size=3)]
    _prefill_rows_vs_single("mingru-lm", prompts, exact=True)


# ---------------------------------------------------------------------------
# Sampled decoding / limits / stats through the engine
# ---------------------------------------------------------------------------

def test_engine_prompt_near_max_len():
    """A prompt near max_len must fit the per-slot staging buffer and
    prefill correctly through the superstep (KV arch: cache row writes
    beyond the prompt must stay invisible)."""
    cfg, params = _setup("gemma-2b")
    prompt = list(np.arange(1, 66))             # 65 tokens, max_len 100
    ref = generate_one(cfg, params, prompt, max_new=5, max_len=100)
    engine = ServingEngine(cfg, params, max_batch=1, max_len=100)
    rid = engine.submit(prompt, max_new=5)
    assert engine.run_to_completion()[rid] == ref


def test_engine_sampled_requests_reproducible_and_in_vocab():
    cfg, params = _setup("mingru-lm")

    def run():
        engine = ServingEngine(cfg, params, max_batch=2, max_len=32, seed=7)
        rids = [engine.submit([1, 2, 3], max_new=8, temperature=0.9,
                              top_k=50, top_p=0.95),
                engine.submit([4, 5], max_new=8, temperature=1.2)]
        return [engine.run_to_completion()[r] for r in rids]

    a, b = run(), run()
    assert a == b                       # same engine seed -> same streams
    for out in a:
        assert len(out) == 8
        assert all(0 <= t < cfg.vocab_size for t in out)


def test_engine_rejects_oversized_request():
    cfg, params = _setup("mingru-lm")
    engine = ServingEngine(cfg, params, max_batch=1, max_len=16)
    with pytest.raises(ValueError):
        engine.submit(list(range(1, 15)), max_new=8)
    with pytest.raises(ValueError):
        engine.submit([], max_new=2)


def test_engine_stats_accounting():
    cfg, params = _setup("mingru-lm")
    engine = ServingEngine(cfg, params, max_batch=2, max_len=32,
                           decode_block=2)
    engine.submit([1, 2, 3, 4], max_new=4)
    engine.submit([5, 6], max_new=4)
    outs = engine.run_to_completion()
    s = engine.stats
    assert s.prefill_tokens == 6                 # prompt tokens, in-loop
    assert s.decode_tokens == sum(len(o) for o in outs.values()) == 8
    assert s.completed == s.submitted == 2
    # every slot-round is prefill, emission, waste -- or both prefill and
    # emission in the round that consumes the last prompt token
    n_first = 2
    assert s.slot_steps == (s.prefill_tokens + s.decode_tokens - n_first
                            + s.wasted_slot_steps)
    assert len(s.ttft_s) == len(s.ttft_rounds) == 2
    # ttft in rounds = prompt length (one teacher-forced round per token)
    assert sorted(s.ttft_rounds) == [2, 4]
    snap = s.snapshot()
    assert snap["tokens_per_second"] > 0
    assert 0.0 <= snap["wasted_slot_fraction"] < 1.0
    assert snap["itl_rounds_mean"] == 1.0        # back-to-back rounds
