"""Prompt packing: C-token chunked prefill inside the superstep.

The packed path (``prompt_chunk=C > 1``) may only change *when* prompt
tokens are consumed -- never what gets generated.  The contract tested
here, bottom-up:

  * the varlen chunk kernels (``kernels/decode_step``) are bit-identical
    to sequential fused step-kernel calls with per-row freezing, and
    match their jnp oracles (``ref.py``);
  * ``blocks.step_chunk`` / ``lm.decode_chunk`` are bit-identical to a
    loop of ``blocks.step`` / ``lm.decode_step``;
  * the packed superstep is bit-exact with the C=1 superstep -- greedy
    AND seeded (keys are emission-aligned, so a request's k-th output
    token uses the k-th key regardless of how many packed rounds its
    prompt took);
  * the engine under ``prompt_chunk`` keeps the ``generate_one`` parity
    contract across odd prompt lengths straddling chunk boundaries,
    prompts shorter than C, EOS + re-admission inside one packed round,
    and exact slot-step/TTFT accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.core import blocks, min_gru, min_lstm
from repro.kernels.decode_step import ops as step_ops
from repro.kernels.decode_step import ref as step_ref
from repro.models import lm
from repro.serving import sampling
from repro.serving.engine import ServingEngine, generate_one

MAX_LEN = 64


def _setup(arch):
    cfg = archs.smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Varlen chunk kernels: vs sequential fused steps (bitwise) and jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dx,dh,b,c", [(16, 32, 4, 4), (12, 13, 3, 5),
                                       (48, 128, 5, 3)])
def test_mingru_chunk_bitexact_vs_sequential_fused_steps(dx, dh, b, c):
    params = min_gru.init(jax.random.PRNGKey(0), dx, dh)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, c, dx))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, dh))
    valid = jnp.asarray(
        np.random.default_rng(0).integers(1, c + 1, size=b), jnp.int32)
    wz, wh = params["wz"]["kernel"], params["wh"]["kernel"]
    bz, bh = params["wz"]["bias"], params["wh"]["bias"]
    hs = step_ops.fused_mingru_chunk(x, wz, bz, wh, bh, h0, valid)
    h = h0
    for t in range(c):
        h_new = step_ops.fused_mingru_step(x[:, t], wz, bz, wh, bh, h)
        h = jnp.where((t < valid)[:, None], h_new, h)
        np.testing.assert_array_equal(np.asarray(hs[:, t]), np.asarray(h),
                                      err_msg=f"t={t}")
    # frozen tail: position valid-1 onward all hold the final state
    np.testing.assert_array_equal(np.asarray(hs[:, -1]), np.asarray(h))


@pytest.mark.parametrize("dx,dh,b,c", [(16, 32, 4, 4), (10, 17, 3, 6)])
def test_minlstm_chunk_bitexact_vs_sequential_fused_steps(dx, dh, b, c):
    params = min_lstm.init(jax.random.PRNGKey(3), dx, dh)
    x = jax.random.normal(jax.random.PRNGKey(4), (b, c, dx))
    h0 = jax.random.normal(jax.random.PRNGKey(5), (b, dh))
    valid = jnp.asarray(
        np.random.default_rng(1).integers(1, c + 1, size=b), jnp.int32)
    ws = [params[k]["kernel"] for k in ("wf", "wi", "wh")]
    bs = [params[k]["bias"] for k in ("wf", "wi", "wh")]
    hs = step_ops.fused_minlstm_chunk(x, ws[0], bs[0], ws[1], bs[1],
                                      ws[2], bs[2], h0, valid)
    h = h0
    for t in range(c):
        h_new = step_ops.fused_minlstm_step(x[:, t], ws[0], bs[0], ws[1],
                                            bs[1], ws[2], bs[2], h)
        h = jnp.where((t < valid)[:, None], h_new, h)
        np.testing.assert_array_equal(np.asarray(hs[:, t]), np.asarray(h),
                                      err_msg=f"t={t}")


def test_chunk_kernels_match_jnp_oracles():
    dx, dh, b, c = 20, 50, 5, 4
    x = jax.random.normal(jax.random.PRNGKey(6), (b, c, dx)) * 0.5
    h0 = jax.random.normal(jax.random.PRNGKey(7), (b, dh))
    valid = jnp.asarray([1, 4, 2, 3, 4], jnp.int32)
    wz = jax.random.normal(jax.random.PRNGKey(8), (dx, dh)) * 0.3
    wh = jax.random.normal(jax.random.PRNGKey(9), (dx, dh)) * 0.3
    bz = jax.random.normal(jax.random.PRNGKey(10), (dh,))
    out = step_ops.fused_mingru_chunk(x, wz, bz, wh, None, h0, valid)
    ref = step_ref.mingru_chunk_ref(x, wz, bz, wh, jnp.zeros((dh,)), h0,
                                    valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    wi = jax.random.normal(jax.random.PRNGKey(11), (dx, dh)) * 0.3
    out = step_ops.fused_minlstm_chunk(x, wz, bz, wi, None, wh, None, h0,
                                       valid)
    ref = step_ref.minlstm_chunk_ref(x, wz, bz, wi, jnp.zeros((dh,)), wh,
                                     jnp.zeros((dh,)), h0, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
def test_cell_step_chunk_jnp_fallback_matches_looped_step(cell):
    """The non-fused step_chunk path is the masked loop of the jnp step.
    The scan body compiles once where the python loop compiles per call,
    so XLA's fusion context differs -- identical arithmetic to ~1 ulp
    (the fused kernel path, which serving uses, is the bitwise one)."""
    mod = {"mingru": min_gru, "minlstm": min_lstm}[cell]
    params = mod.init(jax.random.PRNGKey(12), 16, 24)
    x = jax.random.normal(jax.random.PRNGKey(13), (3, 4, 16))
    h0 = jax.random.normal(jax.random.PRNGKey(14), (3, 24))
    valid = jnp.asarray([2, 4, 1], jnp.int32)
    hs = mod.step_chunk(params, x, h0, valid, scan_strategy="sequential")
    h = h0
    for t in range(4):
        h_new = mod.step(params, x[:, t], h)
        h = jnp.where((t < valid)[:, None], h_new, h)
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Block / model level: chunk vs looped single-token step, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
def test_block_step_chunk_bitexact_vs_looped_step(cell):
    cfg = blocks.MinRNNBlockConfig(d_model=16, cell=cell, expansion=1.5,
                                   use_conv=True, use_mlp=True)
    params = blocks.init(jax.random.PRNGKey(15), cfg)
    c = 5
    x = jax.random.normal(jax.random.PRNGKey(16), (3, c, 16))
    state0 = blocks.init_state(cfg, (3,))
    valid = jnp.asarray([3, 5, 1], jnp.int32)
    y_blk, s_blk = blocks.step_chunk(params, cfg, x, state0, valid)
    # loop the single-token form, freezing each row at its valid length
    state = state0
    ys = []
    for t in range(c):
        y_t, s_new = blocks.step(params, cfg, x[:, t], state)
        keep = (t < valid)
        state = {k: jnp.where(keep.reshape((-1,) + (1,) * (v.ndim - 1)),
                              s_new[k], state[k]) for k, v in state.items()}
        ys.append(y_t)
    np.testing.assert_array_equal(np.asarray(s_blk["h"]),
                                  np.asarray(state["h"]))
    np.testing.assert_array_equal(np.asarray(s_blk["conv"]),
                                  np.asarray(state["conv"]))
    # per-row outputs at valid positions match the loop bit-exactly
    for b in range(3):
        for t in range(int(valid[b])):
            np.testing.assert_array_equal(np.asarray(y_blk[b, t]),
                                          np.asarray(ys[t][b]),
                                          err_msg=f"b={b} t={t}")


@pytest.mark.parametrize("arch", ["mingru-lm", "minlstm-lm"])
def test_decode_chunk_matches_looped_decode_step(arch):
    """Full-model chunk vs a loop of ``decode_step``: position counters
    exact, recurrent state and last-valid-position logits identical to
    fp32 rounding with exact argmax (the two are the same per-token
    arithmetic compiled in different fusion contexts -- interpret-mode
    Pallas inlines into the surrounding jit, so a whole-program diff of
    ~1 ulp is the compilation artifact, not reassociation; the stream-
    level bit-exactness contract is pinned by the engine tests below)."""
    cfg, params = _setup(arch)
    c, bsz = 4, 3
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(1, 200, size=(bsz, c)), jnp.int32)
    valid = jnp.asarray([4, 1, 3], jnp.int32)
    cache0 = lm.init_cache(cfg, bsz, MAX_LEN)
    logits_c, cache_c = jax.jit(
        lambda p, t, v, ca: lm.decode_chunk(p, cfg, t, v, ca))(
            params, tokens, valid, cache0)
    # loop decode_step per row up to its valid length
    step = jax.jit(lambda p, t, ca: lm.decode_step(p, cfg, t, ca))
    cache = cache0
    last_logits = [None] * bsz
    for t in range(c):
        logits_t, cache_new = step(params, tokens[:, t], cache)
        keep = (t < valid)
        cache = {k: jnp.where(keep.reshape((1, -1) + (1,) * (v.ndim - 2))
                              if k != "pos" else keep, cache_new[k],
                              cache[k])
                 for k, v in cache.items()}
        for b in range(bsz):
            if t == int(valid[b]) - 1:
                last_logits[b] = logits_t[b]
    np.testing.assert_array_equal(np.asarray(cache_c["pos"]),
                                  np.asarray(cache["pos"]))
    np.testing.assert_allclose(np.asarray(cache_c["h"]),
                               np.asarray(cache["h"]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cache_c["conv"]),
                               np.asarray(cache["conv"]),
                               rtol=1e-6, atol=1e-6)
    for b in range(bsz):
        np.testing.assert_allclose(np.asarray(logits_c[b]),
                                   np.asarray(last_logits[b]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"row {b}")
        assert int(jnp.argmax(logits_c[b])) == \
            int(jnp.argmax(last_logits[b]))


def test_decode_chunk_rejects_non_recurrent_arch():
    cfg, params = _setup("gemma-2b")
    cache = lm.init_cache(cfg, 1, 32)
    with pytest.raises(NotImplementedError):
        lm.decode_chunk(params, cfg, jnp.asarray([[1, 2]], jnp.int32),
                        jnp.asarray([2], jnp.int32), cache)
    with pytest.raises(NotImplementedError):
        lm.superstep(params, cfg, lm.init_slot_state(cfg, 1, 32), 2,
                     prompt_chunk=4)
    assert not lm.supports_prompt_packing(cfg)
    assert lm.supports_prompt_packing(archs.smoke("mingru-lm"))


# ---------------------------------------------------------------------------
# Packed superstep vs C=1 superstep: bit-exact, greedy AND seeded
# ---------------------------------------------------------------------------

def _staged_state(cfg, prompts, max_new, bsz, *, seed=0, temperature=0.0,
                  top_k=0, top_p=1.0):
    """Slot state with ``prompts`` parked in the staging buffers."""
    state = lm.init_slot_state(cfg, bsz, MAX_LEN, seed=seed)
    for i, p in enumerate(prompts):
        state["s_valid"] = state["s_valid"].at[i].set(True)
        state["s_prompt"] = state["s_prompt"].at[i, :len(p)].set(
            jnp.asarray(p, jnp.int32))
        state["s_prompt_len"] = state["s_prompt_len"].at[i].set(len(p))
        state["s_rid"] = state["s_rid"].at[i].set(i)
        state["s_remaining"] = state["s_remaining"].at[i].set(max_new)
        state["s_temperature"] = state["s_temperature"].at[i].set(
            temperature)
        state["s_top_k"] = state["s_top_k"].at[i].set(top_k)
        state["s_top_p"] = state["s_top_p"].at[i].set(top_p)
    return state


def _streams(buf, rids):
    out = {}
    b, r = np.asarray(buf), np.asarray(rids)
    for slot in range(b.shape[0]):
        for j in range(b.shape[1]):
            if r[slot, j] >= 0:
                out.setdefault(int(r[slot, j]), []).append(int(b[slot, j]))
    return out


@pytest.mark.parametrize("arch", ["mingru-lm", "minlstm-lm"])
@pytest.mark.parametrize("temperature", [0.0, 0.9])
def test_packed_superstep_bitexact_vs_c1(arch, temperature):
    """Prompts straddling the chunk (1, C-1, C, C+1, 2C+3 with C=4) --
    every emitted stream, greedy or seeded, is bit-identical between the
    packed and the unpacked superstep; counters stay consistent."""
    cfg, params = _setup(arch)
    prompts = [[7], [1, 2, 3], [1, 2, 3, 4], [5, 4, 3, 2, 1],
               [2, 7, 1, 8, 2, 8, 1, 8, 2, 8, 4]]
    max_new = 5
    kw = dict(seed=3, temperature=temperature, top_k=20, top_p=0.95)

    state1 = _staged_state(cfg, prompts, max_new, len(prompts), **kw)
    n1 = max(len(p) for p in prompts) + max_new + 2
    buf1, rid1, st1, ct1 = jax.jit(
        lambda p, s: lm.superstep(p, cfg, s, n1))(params, state1)

    state4 = _staged_state(cfg, prompts, max_new, len(prompts), **kw)
    n4 = (max(len(p) for p in prompts) + 3) // 4 + max_new + 2
    buf4, rid4, st4, ct4 = jax.jit(
        lambda p, s: lm.superstep(p, cfg, s, n4,
                                  prompt_chunk=4))(params, state4)

    s1, s4 = _streams(buf1, rid1), _streams(buf4, rid4)
    assert set(s1) == set(s4) == set(range(len(prompts)))
    for rid in s1:
        assert s1[rid] == s4[rid], (rid, s1[rid], s4[rid])
    assert all(len(s) == max_new for s in s4.values())
    assert int(ct1["prefill_steps"]) == int(ct4["prefill_steps"]) == \
        sum(len(p) for p in prompts)
    assert int(ct4["prefill_rounds"]) == \
        sum(-(-len(p) // 4) for p in prompts)
    assert int(ct1["prefill_rounds"]) == int(ct1["prefill_steps"])
    # emission-aligned keys: final key state matches per slot once both
    # paths have emitted the same tokens
    np.testing.assert_array_equal(np.asarray(st1["keys"]),
                                  np.asarray(st4["keys"]))


def test_packed_superstep_prompt_shorter_than_chunk():
    """A 2-token prompt under C=8 arms, prefills and emits its first
    token in ONE packed round."""
    cfg, params = _setup("mingru-lm")
    state = _staged_state(cfg, [[5, 6]], 3, 1)
    buf, rids, st, ct = lm.superstep(params, cfg, state, 1, prompt_chunk=8)
    assert int(ct["prefill_steps"]) == 2
    assert int(ct["prefill_rounds"]) == 1
    assert int(np.asarray(rids)[0, 0]) == 0          # emitted round 0
    ref = generate_one(cfg, params, [5, 6], max_new=1, max_len=MAX_LEN)
    assert int(np.asarray(buf)[0, 0]) == ref[0]


# ---------------------------------------------------------------------------
# Engine under prompt_chunk: generate_one parity + edge cases + accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mingru-lm", "minlstm-lm"])
@pytest.mark.parametrize("c", [2, 4])
def test_engine_packed_matches_single_request(arch, c):
    """Odd prompt lengths straddling the chunk boundary (1, C-1, C, C+1,
    2C+3) under queue pressure: packed engine streams == generate_one."""
    cfg, params = _setup(arch)
    prompts = [[7], [1, 2, 3], [1, 2, 3, 4], [5, 4, 3, 2, 1],
               [2, 7, 1, 8, 2, 8, 1, 8, 2, 8, 4]][:5]
    prompts = [p for p in prompts
               if len(p) in (1, c - 1, c, c + 1, 2 * c + 3)] or prompts
    refs = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
            for p in prompts]
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=3, prompt_chunk=c)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)
    assert engine.stats.prefill_tokens == sum(len(p) for p in prompts)


def test_engine_packed_greedy_streams_identical_across_chunks():
    """The acceptance contract: greedy streams are bit-exact across
    --prompt-chunk values (packing changes when prompt tokens are
    consumed, never what is generated)."""
    cfg, params = _setup("mingru-lm")
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(1, 200, size=n))
               for n in (19, 1, 7, 26, 3, 12)]
    outs_by_c = {}
    for c in (1, 2, 4, 16):
        engine = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                               decode_block=4, prompt_chunk=c)
        rids = [engine.submit(p, max_new=6) for p in prompts]
        outs = engine.run_to_completion()
        outs_by_c[c] = [outs[r] for r in rids]
    for c in (2, 4, 16):
        assert outs_by_c[c] == outs_by_c[1], f"chunk {c} diverged"


def test_engine_packed_seeded_streams_identical_across_chunks():
    """Emission-aligned keys make even SEEDED streams bit-exact across
    chunk sizes (fixed request->slot assignment: all fit the batch)."""
    cfg, params = _setup("mingru-lm")
    prompts = [[1, 2, 3, 4, 5, 6, 7], [9, 8, 7], [2, 4, 6, 8, 10]]

    def run(c):
        engine = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                               seed=7, decode_block=4, prompt_chunk=c)
        rids = [engine.submit(p, max_new=8, temperature=0.9, top_k=50,
                              top_p=0.95) for p in prompts]
        outs = engine.run_to_completion()
        return [outs[r] for r in rids]

    assert run(1) == run(2) == run(4)
    assert run(4) == run(4)                     # and reproducible


def test_engine_packed_eos_readmission_same_packed_round_with_waste():
    """EOS mid-buffer under packing: the staged successor arms the next
    round and prefills PACKED; slot-step accounting stays exact.  Mirrors
    test_engine_block_decode_eos_readmits_in_same_buffer at C=4."""
    cfg, params = _setup("mingru-lm")
    eos_tok = generate_one(cfg, params, [1, 2, 3], max_new=2,
                           max_len=MAX_LEN)[1]
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                           prompt_chunk=4)
    rid = engine.submit([1, 2, 3], max_new=16, eos=eos_tok)
    engine.step(n_tokens=1)     # one packed round: 3 prompt toks + emit
    assert engine.stats.prefill_tokens == 3
    assert engine.stats.prefill_rounds == 1
    ref = generate_one(cfg, params, [4, 5, 6, 7], max_new=6,
                       max_len=MAX_LEN)
    rid2 = engine.submit([4, 5, 6, 7], max_new=6)   # staged behind it
    engine.step(n_tokens=12)
    outs = engine.run_to_completion()
    assert engine.stats.decode_calls == 2
    n1 = len(outs[rid])
    assert outs[rid][-1] == eos_tok and n1 <= 2
    assert outs[rid2] == ref
    # 13 rounds total: req1 = 1 packed prefill round + (n1 - 1) decode
    # rounds; req2 arms next round = 1 packed prefill round + 5 decode
    # rounds; the rest is tail waste
    assert engine.stats.wasted_slot_steps == 13 - (1 + n1 - 1) - (1 + 5)
    # slot-step identity, exact under C>1
    s = engine.stats
    assert s.slot_steps == s.prefill_rounds + s.decode_tokens \
        - len(s.ttft_rounds) + s.wasted_slot_steps


def test_engine_packed_stats_and_ttft_accounting():
    cfg, params = _setup("mingru-lm")
    engine = ServingEngine(cfg, params, max_batch=2, max_len=32,
                           decode_block=2, prompt_chunk=4)
    engine.submit([1, 2, 3, 4, 5, 6, 7], max_new=4)   # ceil(7/4) = 2 rounds
    engine.submit([5, 6], max_new=4)                  # ceil(2/4) = 1 round
    outs = engine.run_to_completion()
    s = engine.stats
    assert s.prefill_tokens == 9
    assert s.prefill_rounds == 3
    assert s.decode_tokens == sum(len(o) for o in outs.values()) == 8
    # ttft in rounds = packed prompt rounds, not prompt tokens
    assert sorted(s.ttft_rounds) == [1, 2]
    assert s.slot_steps == s.prefill_rounds + s.decode_tokens \
        - len(s.ttft_rounds) + s.wasted_slot_steps
    snap = s.snapshot()
    assert snap["prompt_chunk"] == 4
    assert snap["prefill_rounds"] == 3
    assert 0.0 <= snap["wasted_slot_fraction"] < 1.0
    assert snap["itl_rounds_mean"] == 1.0


def test_engine_packed_long_prompt_does_not_block_short_requests():
    """The no-barrier property survives packing: a long prompt packs its
    prefill while neighbours decode to completion."""
    cfg, params = _setup("mingru-lm")
    rng = np.random.default_rng(5)
    long_p = list(rng.integers(1, 200, size=40))
    shorts = [[1, 2, 3], [4, 5]]
    refs = [generate_one(cfg, params, p, max_new=5, max_len=MAX_LEN)
            for p in [long_p] + shorts]
    engine = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                           decode_block=4, prompt_chunk=4)
    rids = [engine.submit(long_p, max_new=5)]
    engine.step()
    rids += [engine.submit(p, max_new=5) for p in shorts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)
    # packed: the 40-token prompt took ceil(40/4) = 10 prefill rounds,
    # not 40 -- visible in the request's TTFT rounds
    assert min(engine.stats.ttft_rounds) >= 1
    assert max(engine.stats.ttft_rounds) <= 12


def test_engine_rejects_packing_for_unsupported_arch():
    cfg, params = _setup("gemma-2b")
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_batch=1, max_len=32, prompt_chunk=4)
    # C=1 keeps working for every arch
    ServingEngine(cfg, params, max_batch=1, max_len=32, prompt_chunk=1)


def test_row_eta_accounts_for_packed_prefill():
    """The staging ETA divides remaining prompt rounds by C -- the
    unpacked estimate would mis-rank rows by up to C."""
    cfg, params = _setup("mingru-lm")
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           prompt_chunk=4)
    engine.submit(list(range(1, 10)), max_new=5)      # 9 prompt tokens
    engine.submit([1, 2], max_new=5)
    engine._stage()
    engine._upload_staging()
    engine.step(n_tokens=1)     # arm both rows (1 packed round each)
    # row 0: first round consumed 4 of 9 prompt tokens, and the host
    # mirror of prompt_pos knows it: ceil((9-4)/4)=2 + 5
    assert engine._row_eta(0) == 2 + 5
    # row 1: 2-token prompt emitted its first token in round 0
    assert engine._row_eta(1) == 5 - len(engine.current[1].out)
    # idle rows report 0
    engine.run_to_completion()
    assert engine._row_eta(0) == 0 and engine._row_eta(1) == 0
