"""SPMD correctness on 8 fake devices (subprocess: device count is fixed at
jax init, so each test execs a fresh interpreter with XLA_FLAGS set)."""

import os
import subprocess
import sys
import textwrap

import pytest

# heavy tier: each test boots a fresh 8-fake-device interpreter
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_spmd(body: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_sequence_parallel_scan_matches_sequential():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import scan as scan_lib
        from repro.distributed import context as mesh_ctx

        mesh = jax.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        a = jax.nn.sigmoid(jax.random.normal(k1, (2, 64, 4)))
        b = jax.random.normal(k2, (2, 64, 4))
        ref = scan_lib.scan_sequential(a, b)

        fn = mesh_ctx.shard_map(
            lambda a, b: scan_lib.scan_sequence_parallel(a, b, "data"),
            mesh=mesh, in_specs=(P(None, "data", None),) * 2,
            out_specs=P(None, "data", None))
        out = jax.jit(fn)(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("seq-parallel scan OK")
    """)


def test_moe_expert_parallel_matches_local():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig, MoEConfig
        from repro.distributed import context as mesh_ctx
        from repro.models import moe

        import sys
        mode = sys.argv[1] if len(sys.argv) > 1 else "auto"
        cfg = ModelConfig(d_model=16, moe=MoEConfig(
            n_experts=8, top_k=2, d_expert=32, capacity_factor=16.0,
            ep_2d=mode))
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))

        y_local, aux_local = moe.moe_apply(params, cfg, x)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh_ctx.use_mesh(mesh):
            y_ep, aux_ep = jax.jit(
                lambda p, x: moe.moe_apply(p, cfg, x))(params, x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_local),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(aux_ep), float(aux_local),
                                   rtol=1e-4)
        print("EP MoE OK")
    """)


def test_dp_compressed_step_matches_single_device_trend():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import archs
        from repro.data import lm_corpus
        from repro.models import lm
        from repro.training import optimizer as opt_lib
        from repro.training import train_step as ts_lib

        cfg = archs.smoke("mingru-lm")
        ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=0,
                                   schedule="constant")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt_lib.init(ocfg, params)
        data, _ = lm_corpus.build_corpus()
        batch = lm_corpus.lm_batch(data, 0, 0, 8, 32)

        ref_step = jax.jit(ts_lib.make_train_step(cfg, ocfg))
        p_ref, _, m_ref = ref_step(params, opt_state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        dp_step = ts_lib.make_dp_compressed_step(cfg, ocfg, mesh)
        p_dp, _, m_dp = dp_step(params, opt_state, batch)
        # bf16-compressed grads: parameters close, not bitwise
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_dp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0.1, atol=2e-3)
        assert abs(float(m_ref["loss"]) - float(m_dp["loss"])) < 1e-2
        print("dp compressed OK")
    """)


def test_tiny_dryrun_lower_compile():
    """The dry-run machinery end-to-end on a small mesh, smoke configs."""
    run_spmd("""
        import jax
        from repro.configs import archs
        from repro.configs.base import SHAPES, ShapeConfig
        from repro.distributed import context as mesh_ctx
        from repro.launch.dryrun import build_lowerable

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("tiny_train", 64, 8, "train")
        dshape = ShapeConfig("tiny_decode", 64, 8, "decode")
        for arch in ("gemma-2b", "mamba2-370m", "deepseek-moe-16b",
                     "mingru-lm", "zamba2-2.7b"):
            cfg = archs.smoke(arch).replace(scan_layers=False)
            for sh in (shape, dshape):
                fn, args, in_sh, out_sh, donate = build_lowerable(
                    cfg, sh, mesh)
                kw = dict(in_shardings=in_sh)
                if out_sh is not None:
                    kw["out_shardings"] = out_sh
                with mesh_ctx.use_mesh(mesh):
                    c = jax.jit(fn, **kw).lower(*args).compile()
                ca = c.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                assert ca["flops"] > 0
                print(arch, sh.name, "OK")
    """, timeout=900)


def test_checkpoint_reshard_restore():
    """Save unsharded, restore onto an 8-device mesh with shardings."""
    import tempfile
    tmp = tempfile.mkdtemp()
    run_spmd(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training import checkpoint as ckpt_lib

        tree = {{"layer": {{"kernel": jnp.arange(64, dtype=jnp.float32
                                                ).reshape(8, 8)}}}}
        ckpt_lib.save("{tmp}", 3, tree)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        sh = {{"layer": {{"kernel": NamedSharding(mesh,
                                                  P("data", "model"))}}}}
        step, restored, _ = ckpt_lib.restore(
            "{tmp}/step_00000003", shardings=sh)
        assert step == 3
        k = restored["layer"]["kernel"]
        assert len(k.sharding.device_set) == 8
        np.testing.assert_array_equal(np.asarray(k),
                                      np.asarray(tree["layer"]["kernel"]))
        print("reshard restore OK")
    """)
