"""Multi-round on-device decode: ``lm.superstep`` must be bit-exact with
a host loop of ``decode_step`` + ``sample_tokens`` (greedy and seeded
sampling, mid-buffer EOS, length caps), the fused Pallas decode-step
kernel must match the jnp cell step (incl. bf16 and odd d_hidden), and
the engine's ``step(n_tokens=K>1)`` path must keep the ``generate_one``
parity contract across admission orders, mid-superstep arrivals, slot
retire + in-loop re-admission inside a single buffer, odd prompt
lengths, and long prompts prefilled by the loop itself."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.core import blocks, min_gru, min_lstm
from repro.kernels.decode_step import ops as step_ops
from repro.kernels.decode_step import ref as step_ref
from repro.models import lm
from repro.serving import sampling
from repro.serving.engine import ServingEngine, generate_one

MAX_LEN = 64


def _setup(arch):
    cfg = archs.smoke(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Fused decode-step kernel vs jnp cell step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["log", "linear"])
@pytest.mark.parametrize("dx,dh,b", [(16, 32, 4), (12, 13, 3), (64, 200, 1)])
def test_fused_mingru_step_matches_ref(mode, dx, dh, b):
    params = min_gru.init(jax.random.PRNGKey(0), dx, dh)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, dx))
    h = jax.random.normal(jax.random.PRNGKey(2), (b, dh))
    ref = min_gru.step(params, x, h, mode=mode)
    fused = min_gru.step(params, x, h, mode=mode, scan_strategy="auto")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("normalize", [True, False])
@pytest.mark.parametrize("dx,dh,b", [(16, 32, 4), (10, 17, 3)])
def test_fused_minlstm_step_matches_ref(normalize, dx, dh, b):
    params = min_lstm.init(jax.random.PRNGKey(3), dx, dh)
    x = jax.random.normal(jax.random.PRNGKey(4), (b, dx))
    h = jax.random.normal(jax.random.PRNGKey(5), (b, dh))
    ref = min_lstm.step(params, x, h, normalize=normalize)
    fused = min_lstm.step(params, x, h, normalize=normalize,
                          scan_strategy="fused")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_fused_step_bf16_and_saturated_gates():
    """bf16 inputs upcast to fp32 in-kernel; the stable minLSTM gate
    normalisation must stay finite where naive f/(f+i) is 0/0 = NaN."""
    dx, dh = 24, 40
    params = min_lstm.init(jax.random.PRNGKey(6), dx, dh)
    x = (jax.random.normal(jax.random.PRNGKey(7), (4, dx))
         .astype(jnp.bfloat16))
    h = jax.random.normal(jax.random.PRNGKey(8), (4, dh)).astype(jnp.bfloat16)
    ref = min_lstm.step(params, x, h, compute_dtype=jnp.bfloat16)
    fused = min_lstm.step(params, x, h, compute_dtype=jnp.bfloat16,
                          scan_strategy="fused")
    assert fused.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(fused, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2)
    # saturated gates: both sigmoids underflow in fp32
    ws = [params[k]["kernel"] for k in ("wf", "wi", "wh")]
    big = jnp.full((2, dx), -200.0)
    sat = step_ops.fused_minlstm_step(
        big, ws[0], jnp.full((dh,), -200.0), ws[1], jnp.full((dh,), -200.0),
        ws[2], None, jnp.ones((2, dh)))
    assert bool(jnp.all(jnp.isfinite(sat)))


def test_fused_step_ops_match_pure_ref_oracle():
    """ops wrapper (padding + kernel) against the standalone ref module."""
    dx, dh, b = 20, 50, 5
    key = jax.random.PRNGKey(9)
    wz = jax.random.normal(key, (dx, dh)) * 0.3
    wh = jax.random.normal(jax.random.PRNGKey(10), (dx, dh)) * 0.3
    bz = jax.random.normal(jax.random.PRNGKey(11), (dh,))
    x = jax.random.normal(jax.random.PRNGKey(12), (b, dx))
    h = jax.random.normal(jax.random.PRNGKey(13), (b, dh))
    out = step_ops.fused_mingru_step(x, wz, bz, wh, None, h)
    ref = step_ref.mingru_step_ref(x, wz, bz, wh, jnp.zeros((dh,)), h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("cell", ["mingru", "minlstm"])
def test_block_step_fused_matches_sequential_oracle(cell):
    """blocks.step under the default 'auto' strategy == forced jnp path."""
    cfg = blocks.MinRNNBlockConfig(d_model=16, cell=cell, expansion=1.5,
                                   use_conv=True, use_mlp=True)
    params = blocks.init(jax.random.PRNGKey(14), cfg)
    x = jax.random.normal(jax.random.PRNGKey(15), (3, 16))
    state = blocks.init_state(cfg, (3,))
    y_auto, s_auto = blocks.step(params, cfg, x, state)
    y_ref, s_ref = blocks.step(params, cfg, x, state,
                               scan_strategy="sequential")
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_auto["h"]),
                               np.asarray(s_ref["h"]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# superstep vs looped decode_step + sample_tokens (decode-only rows)
# ---------------------------------------------------------------------------

def _decoding_state(cfg, cache, tok0, keys, controls_np):
    """Slot state whose rows are already past their prompt (prompt_len=0)
    -- the superstep then behaves as a pure multi-token decode loop."""
    bsz = int(tok0.shape[0])
    state = lm.init_slot_state(cfg, bsz, MAX_LEN)
    state["cache"] = cache
    state["tok"] = tok0.astype(jnp.int32)
    state["keys"] = keys
    state["alive"] = jnp.asarray(controls_np["alive"])
    state["remaining"] = jnp.asarray(controls_np["remaining"], jnp.int32)
    state["eos"] = jnp.asarray(controls_np["eos"], jnp.int32)
    state["temperature"] = jnp.asarray(controls_np["temperature"])
    state["top_k"] = jnp.asarray(controls_np["top_k"], jnp.int32)
    state["top_p"] = jnp.asarray(controls_np["top_p"])
    return state


def _loop_reference(cfg, params, tok, cache, keys, controls_np, n):
    """Host re-implementation of the superstep's decode contract: step +
    sample every round, emit only while alive, stop on EOS / length cap.
    Keys are emission-aligned: a slot's key advances only on rounds it
    emits (here: while alive), so sampled streams are invariant to how
    many teacher-forced/dead rounds interleave -- the property that makes
    packed-prefill seeded streams bit-exact across prompt_chunk."""
    step_fn = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))
    alive = controls_np["alive"].copy()
    remaining = controls_np["remaining"].copy()
    emitted = np.full((tok.shape[0], n), -1, np.int32)
    tok = jnp.asarray(tok)
    for j in range(n):
        logits, cache = step_fn(params, tok, cache)
        toks, new_keys = sampling.sample_tokens(
            logits, keys, jnp.asarray(controls_np["temperature"]),
            jnp.asarray(controls_np["top_k"]),
            jnp.asarray(controls_np["top_p"]))
        keys = jnp.where(jnp.asarray(alive)[:, None], new_keys, keys)
        toks_np = np.asarray(toks)
        next_tok = np.asarray(tok).copy()
        for b in range(tok.shape[0]):
            if not alive[b]:
                continue
            emitted[b, j] = toks_np[b]
            next_tok[b] = toks_np[b]
            remaining[b] -= 1
            if (controls_np["eos"][b] >= 0
                    and toks_np[b] == controls_np["eos"][b]) \
                    or remaining[b] <= 0:
                alive[b] = False
        tok = jnp.asarray(next_tok)
    return emitted, keys, alive


@pytest.mark.parametrize("arch", ["mingru-lm", "minlstm-lm"])
@pytest.mark.parametrize("temperature", [0.0, 0.9])
def test_superstep_matches_looped_decode_step(arch, temperature):
    cfg, params = _setup(arch)
    toks = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 0], [9, 8, 0, 0]], jnp.int32)
    lengths = jnp.asarray([4, 3, 2], jnp.int32)
    logits, cache = lm.prefill(params, cfg, toks, MAX_LEN, lengths=lengths)
    bsz = 3
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    keys = sampling.make_keys(7, bsz)
    controls_np = {
        "temperature": np.full((bsz,), temperature, np.float32),
        "top_k": np.asarray([0, 40, 5], np.int32),
        "top_p": np.asarray([1.0, 0.9, 1.0], np.float32),
        "eos": np.full((bsz,), -1, np.int32),
        "alive": np.ones((bsz,), bool),
        "remaining": np.asarray([6, 3, 5], np.int32),
    }
    n = 6
    state = _decoding_state(cfg, cache, tok0, keys, controls_np)
    buf, _, state_out, counters = jax.jit(
        lambda p, s: lm.superstep(p, cfg, s, n))(params, state)

    ref, ref_keys, ref_alive = _loop_reference(
        cfg, params, tok0, cache, keys, controls_np, n)
    np.testing.assert_array_equal(np.asarray(buf), ref)
    np.testing.assert_array_equal(np.asarray(state_out["keys"]),
                                  np.asarray(ref_keys))
    np.testing.assert_array_equal(np.asarray(state_out["alive"]), ref_alive)
    # length caps honoured on device: slot 1 emitted exactly 3 tokens
    assert int((np.asarray(buf)[1] >= 0).sum()) == 3
    # decode-only rows: nothing prefilling, nothing staged -> dead rows
    # after the length caps hit are counted as waste
    assert int(counters["prefill_steps"]) == 0
    assert int(counters["wasted_slot_steps"]) == \
        int((np.asarray(buf) == -1).sum())


def test_superstep_mid_buffer_eos_stops_emission():
    cfg, params = _setup("mingru-lm")
    logits, cache = lm.prefill(params, cfg,
                               jnp.asarray([[1, 2, 3]], jnp.int32), MAX_LEN)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    controls = {
        "temperature": np.zeros((1,), np.float32),
        "top_k": np.zeros((1,), np.int32),
        "top_p": np.ones((1,), np.float32),
        "eos": np.full((1,), -1, np.int32),
        "alive": np.ones((1,), bool),
        "remaining": np.full((1,), 8, np.int32),
    }
    state = _decoding_state(cfg, cache, tok0, sampling.make_keys(0, 1),
                            controls)
    buf, _, _, _ = lm.superstep(params, cfg, state, 8)
    eos = int(np.asarray(buf)[0, 1])
    controls["eos"] = np.full((1,), eos, np.int32)
    state = _decoding_state(cfg, cache, tok0, sampling.make_keys(0, 1),
                            controls)
    buf2, _, state_out, _ = lm.superstep(params, cfg, state, 8)
    b = np.asarray(buf2)[0]
    stop = int(np.argmax(b == eos))
    assert b[stop] == eos
    assert (b[stop + 1:] == -1).all()
    assert not bool(np.asarray(state_out["alive"])[0])


def test_superstep_dead_slots_do_not_disturb_live_rows():
    """A dead slot keeps stepping (dense batch) but its garbage must not
    leak into live rows: live-row tokens match a solo run."""
    cfg, params = _setup("mingru-lm")
    toks = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    logits, cache = lm.prefill(params, cfg, toks, MAX_LEN)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)

    def controls(bsz, alive):
        return {"temperature": np.zeros((bsz,), np.float32),
                "top_k": np.zeros((bsz,), np.int32),
                "top_p": np.ones((bsz,), np.float32),
                "eos": np.full((bsz,), -1, np.int32),
                "alive": np.asarray(alive),
                "remaining": np.full((bsz,), 5, np.int32)}

    state = _decoding_state(cfg, cache, tok0, sampling.make_keys(0, 2),
                            controls(2, [False, True]))
    buf, _, _, _ = lm.superstep(params, cfg, state, 5)
    lg1, c1 = lm.prefill(params, cfg, toks[1:], MAX_LEN)
    state1 = _decoding_state(cfg, c1,
                             jnp.argmax(lg1, -1).astype(jnp.int32),
                             sampling.make_keys(0, 1),
                             controls(1, [True]))
    buf1, _, _, _ = lm.superstep(params, cfg, state1, 5)
    b = np.asarray(buf)
    assert (b[0] == -1).all()
    np.testing.assert_array_equal(b[1], np.asarray(buf1)[0])


def test_superstep_teacher_forced_prefill_matches_decode_step_loop():
    """A staged request's prompt consumed by teacher-forced superstep
    rounds yields bit-identical state/logits to stepping the prompt by
    hand through decode_step."""
    cfg, params = _setup("mingru-lm")
    prompt = [3, 1, 4, 1, 5, 9, 2]
    state = lm.init_slot_state(cfg, 1, MAX_LEN)
    state["s_valid"] = jnp.asarray([True])
    state["s_prompt"] = state["s_prompt"].at[0, :len(prompt)].set(
        jnp.asarray(prompt, jnp.int32))
    state["s_prompt_len"] = jnp.asarray([len(prompt)], jnp.int32)
    state["s_rid"] = jnp.asarray([0], jnp.int32)
    state["s_remaining"] = jnp.asarray([4], jnp.int32)
    n = len(prompt) + 3                     # prompt rounds + 3 emissions
    buf, rids, _, counters = lm.superstep(params, cfg, state, n)
    got = [int(t) for t in np.asarray(buf)[0] if t >= 0]
    assert int(counters["prefill_steps"]) == len(prompt)
    assert (np.asarray(rids)[0][np.asarray(buf)[0] >= 0] == 0).all()

    cache = lm.init_cache(cfg, 1, MAX_LEN)
    logits = None
    for t in prompt:
        logits, cache = lm.decode_step(params, cfg,
                                       jnp.asarray([t], jnp.int32), cache)
    ref = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = lm.decode_step(
            params, cfg, jnp.asarray([ref[-1]], jnp.int32), cache)
        ref.append(int(jnp.argmax(logits[0])))
    assert got == ref[:len(got)] == ref


# ---------------------------------------------------------------------------
# Engine parity with n_tokens=K>1 (the acceptance contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [
    "mingru-lm",
    # KV/SSD cache kinds ride the same superstep loop; heavier compiles
    pytest.param("mamba2-370m", marks=pytest.mark.slow),
    pytest.param("gemma-2b", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("k", [3])
def test_engine_block_decode_matches_single_request(arch, k):
    cfg, params = _setup(arch)
    prompts = [[1, 2, 3, 4], [5, 6, 7], [2, 4, 6, 8, 10, 1]]
    singles = [generate_one(cfg, params, p, max_new=7, max_len=MAX_LEN)
               for p in prompts]
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=k)
    rids = [engine.submit(p, max_new=7) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, singles):
        assert outs[rid] == ref, (outs[rid], ref)
    # max_new=7 with K=3 exercises partial buffers and mid-buffer retire
    assert engine.stats.decode_calls < engine.stats.decode_steps


@pytest.mark.parametrize("k", [2, 4])
def test_engine_block_decode_admission_orders(k):
    cfg, params = _setup("mingru-lm")
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7], [3, 1, 4, 1, 5, 9], [2, 6]]
    refs = {tuple(p): generate_one(cfg, params, p, max_new=5,
                                   max_len=MAX_LEN) for p in prompts}
    for order in ([0, 1, 2, 3], [3, 2, 1, 0], [2, 0, 3, 1]):
        engine = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                               decode_block=k)
        rids = {engine.submit(prompts[i], max_new=5): tuple(prompts[i])
                for i in order}
        outs = engine.run_to_completion()
        for rid, key in rids.items():
            assert outs[rid] == refs[key], (order, key)


def test_engine_block_decode_mid_superstep_arrivals():
    """Requests submitted while a batch is mid-flight are staged between
    supersteps and armed in-loop without disturbing running streams."""
    cfg, params = _setup("mingru-lm")
    first = [[1, 2, 3, 4], [5, 6, 7, 8, 9]]
    late = [[2, 4, 6], [7, 5, 3, 1]]
    refs = [generate_one(cfg, params, p, max_new=8, max_len=MAX_LEN)
            for p in first + late]
    engine = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                           decode_block=3)
    rids = [engine.submit(p, max_new=8) for p in first]
    for _ in range(2):
        engine.step()
    rids += [engine.submit(p, max_new=8) for p in late]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)


def test_engine_block_decode_eos_readmits_in_same_buffer():
    """EOS mid-buffer retires the request and the staged successor arms
    on the next device round: both streams can land in ONE (B, K)
    buffer, demuxed by the rid plane, with zero idle rounds between
    them."""
    cfg, params = _setup("mingru-lm")
    eos_tok = generate_one(cfg, params, [1, 2, 3], max_new=2,
                           max_len=MAX_LEN)[1]
    engine = ServingEngine(cfg, params, max_batch=1, max_len=MAX_LEN)
    rid = engine.submit([1, 2, 3], max_new=16, eos=eos_tok)
    engine.step(n_tokens=1)             # arm the first request (round 0)
    ref = generate_one(cfg, params, [4, 5, 6, 7], max_new=6,
                       max_len=MAX_LEN)
    rid2 = engine.submit([4, 5, 6, 7], max_new=6)   # staged behind it
    engine.step(n_tokens=16)
    outs = engine.run_to_completion()   # already drained: no more calls
    assert engine.stats.decode_calls == 2
    n1 = len(outs[rid])
    assert outs[rid][-1] == eos_tok and n1 <= 2     # eos is token 1 or 2
    assert outs[rid2] == ref
    assert engine.stats.completed == 2
    # round timeline across the 17 rounds: the first request uses 3
    # prompt rounds with its n1 emissions starting on the last of them
    # (2 + n1 rounds), the successor arms the very next round and uses
    # 4 + 6 - 1 = 9 -> waste only at the tail of the buffer, zero idle
    # rounds between the two requests
    assert engine.stats.wasted_slot_steps == 17 - (2 + n1) - 9


@pytest.mark.parametrize("k", [4])
def test_engine_block_decode_odd_prompt_lengths(k):
    """Prompt lengths straddling the block size (1, K-1, K, K+1, 2K+3):
    teacher-forced prefill must hand off to sampling at the right round
    regardless of where the prompt ends relative to buffer boundaries."""
    cfg, params = _setup("mingru-lm")
    prompts = [[7], [1, 2, 3], [1, 2, 3, 4], [5, 4, 3, 2, 1],
               [2, 7, 1, 8, 2, 8, 1, 8, 2, 8, 4]]
    refs = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
            for p in prompts]
    engine = ServingEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                           decode_block=k)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)


def test_engine_block_decode_long_prompts_interleave():
    cfg, params = _setup("mingru-lm")
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, 200, size=n)) for n in (19, 7, 26, 3)]
    refs = [generate_one(cfg, params, p, max_new=6, max_len=MAX_LEN)
            for p in prompts]
    engine = ServingEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                           decode_block=4)
    rids = [engine.submit(p, max_new=6) for p in prompts]
    outs = engine.run_to_completion()
    for rid, ref in zip(rids, refs):
        assert outs[rid] == ref, (outs[rid], ref)
    assert engine.stats.prefill_tokens == sum(len(p) for p in prompts)


def test_engine_block_decode_sampled_streams_reproducible():
    cfg, params = _setup("mingru-lm")

    def run(k):
        engine = ServingEngine(cfg, params, max_batch=2, max_len=32,
                               seed=7, decode_block=k)
        rids = [engine.submit([1, 2, 3], max_new=8, temperature=0.9,
                              top_k=50, top_p=0.95),
                engine.submit([4, 5], max_new=8, temperature=1.2)]
        return [engine.run_to_completion()[r] for r in rids]

    a, b = run(4), run(4)
    assert a == b
    for out in a:
        assert len(out) == 8
        assert all(0 <= t < cfg.vocab_size for t in out)
    # K=1 must be reproducible too (per-slot keys advance once per round
    # regardless of block size)
    assert run(1) == run(1)


def test_engine_per_call_override_and_roundtrip_accounting():
    cfg, params = _setup("mingru-lm")
    engine = ServingEngine(cfg, params, max_batch=2, max_len=32)
    engine.submit([1, 2, 3], max_new=6)
    engine.step(n_tokens=4)             # explicit block override
    engine.step(n_tokens=4)
    assert engine.stats.decode_calls == 2
    assert engine.stats.decode_steps == 8
    # 3 teacher-forced rounds; the first emission rides the round that
    # consumes the last prompt token, so all 6 tokens fit in 8 rounds
    assert engine.stats.prefill_tokens == 3
    assert engine.stats.decode_tokens == 6
    snap = engine.stats.snapshot()
    assert snap["host_roundtrips_per_decode_token"] <= 0.5
    outs = engine.run_to_completion()
    assert len(list(outs.values())[0]) == 6
