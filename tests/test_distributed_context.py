"""Direct unit tests for repro.distributed.context: the version-portable
shard_map wrapper, axis introspection helpers and the serving-TP trace
context.  These run in-process under the conftest multi-device harness
(REPRO_FORCE_DEVICES, default 8 virtual CPU devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import context as mesh_ctx


def _need_devices(n: int):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices (REPRO_FORCE_DEVICES)")


# ---------------------------------------------------------------------------
# shard_map wrapper
# ---------------------------------------------------------------------------

def test_shard_map_wrapper_runs_sharded():
    _need_devices(4)
    mesh = jax.make_mesh((4,), ("data",))
    x = jnp.arange(8.0)
    fn = mesh_ctx.shard_map(lambda v: v * 2.0, mesh=mesh,
                            in_specs=P("data"), out_specs=P("data"))
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(x)),
                                  np.asarray(x) * 2.0)


def test_shard_map_wrapper_check_vma_kw():
    """check_vma=False must be accepted and still produce correct output
    (it maps to check_rep on older jax)."""
    _need_devices(2)
    mesh = jax.make_mesh((2,), ("data",))
    x = jnp.arange(4.0)

    def body(v):
        return jax.lax.psum(v.sum(), "data") * jnp.ones_like(v)

    fn = mesh_ctx.shard_map(body, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(fn)(x)),
                               np.full(4, 6.0))


def test_shard_map_wrapper_new_jax_branch(monkeypatch):
    """With jax.shard_map present the wrapper must prefer it and pass
    check_vma through under that name (not check_rep)."""
    _need_devices(2)
    from jax.experimental.shard_map import shard_map as real
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, **kw):
        seen.update(kw)
        kw.pop("check_vma", None)
        return real(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    mesh = jax.make_mesh((2,), ("data",))
    fn = mesh_ctx.shard_map(lambda v: v + 1.0, mesh=mesh,
                            in_specs=P("data"), out_specs=P("data"),
                            check_vma=False)
    out = jax.jit(fn)(jnp.zeros(4))
    np.testing.assert_array_equal(np.asarray(out), np.ones(4))
    assert seen == {"check_vma": False}


def test_shard_map_wrapper_old_jax_fallback(monkeypatch):
    """Without jax.shard_map the wrapper must route through
    jax.experimental.shard_map with check_vma renamed to check_rep."""
    _need_devices(2)
    import jax.experimental.shard_map as esm
    real = esm.shard_map
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, **kw):
        seen.update(kw)
        kw.pop("check_rep", None)
        return real(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    if hasattr(jax, "shard_map"):
        monkeypatch.delattr(jax, "shard_map")
    monkeypatch.setattr(esm, "shard_map", fake_shard_map)

    mesh = jax.make_mesh((2,), ("data",))
    fn = mesh_ctx.shard_map(lambda v: v + 1.0, mesh=mesh,
                            in_specs=P("data"), out_specs=P("data"),
                            check_vma=False)
    out = jax.jit(fn)(jnp.zeros(4))
    np.testing.assert_array_equal(np.asarray(out), np.ones(4))
    assert seen == {"check_rep": False}


# ---------------------------------------------------------------------------
# axis helpers
# ---------------------------------------------------------------------------

def test_axis_size_and_dp_axes():
    _need_devices(4)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    assert mesh_ctx.axis_size(mesh, "data") == 2
    assert mesh_ctx.axis_size(mesh, "model") == 2
    assert mesh_ctx.axis_size(mesh, "pod") == 1       # absent axis -> 1
    assert mesh_ctx.axis_size(None, "data") == 1      # no mesh -> 1
    assert mesh_ctx.dp_axes(mesh) == ("data",)
    pod = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
    assert mesh_ctx.dp_axes(pod) == ("pod", "data")


def test_use_mesh_nesting_restores():
    _need_devices(2)
    mesh = jax.make_mesh((2,), ("data",))
    assert mesh_ctx.current_mesh() is None
    with mesh_ctx.use_mesh(mesh, pure_dp=True):
        assert mesh_ctx.current_mesh() is mesh
        assert mesh_ctx.pure_dp()
        with mesh_ctx.use_mesh(None):
            assert mesh_ctx.current_mesh() is None
        assert mesh_ctx.current_mesh() is mesh
    assert mesh_ctx.current_mesh() is None
    assert not mesh_ctx.pure_dp()


# ---------------------------------------------------------------------------
# serving-TP trace context
# ---------------------------------------------------------------------------

def test_serving_tp_context_restores_on_error():
    assert mesh_ctx.serving_tp_axis() is None
    with mesh_ctx.serving_tp("model"):
        assert mesh_ctx.serving_tp_axis() == "model"
        with mesh_ctx.serving_tp(None):
            assert mesh_ctx.serving_tp_axis() is None
        assert mesh_ctx.serving_tp_axis() == "model"
    assert mesh_ctx.serving_tp_axis() is None
    with pytest.raises(RuntimeError):
        with mesh_ctx.serving_tp("model"):
            raise RuntimeError("boom")
    assert mesh_ctx.serving_tp_axis() is None


def test_row_parallel_apply_psums_under_tp():
    """blocks._row_parallel_apply: identity without the context or for a
    full-width kernel; psum of block partials under the context."""
    _need_devices(2)
    from repro.core import blocks

    mesh = jax.make_mesh((2,), ("model",))
    full = jax.random.normal(jax.random.PRNGKey(0), (8, 5))
    bias = jax.random.normal(jax.random.PRNGKey(1), (5,))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 8))
    p = {"kernel": full, "bias": bias}
    ref = x @ full + bias

    # no context: plain dense
    np.testing.assert_allclose(
        np.asarray(blocks._row_parallel_apply(p, x, None, 8)), np.asarray(ref),
        rtol=1e-6)

    # under the context, a sharded kernel psums its partials; bias is
    # added once AFTER the reduction (not once per shard)
    def body(k, xs):
        with mesh_ctx.serving_tp("model"):
            return blocks._row_parallel_apply(
                {"kernel": k, "bias": bias}, xs, None, 8)

    fn = mesh_ctx.shard_map(body, mesh=mesh,
                            in_specs=(P("model", None), P(None, "model")),
                            out_specs=P(), check_vma=False)
    out = jax.jit(fn)(full, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)

    # full-width kernel under the context: no psum needed, stays dense
    def body_full(xs):
        with mesh_ctx.serving_tp("model"):
            return blocks._row_parallel_apply(p, xs, None, 8)

    fn2 = mesh_ctx.shard_map(body_full, mesh=mesh, in_specs=P(),
                             out_specs=P(), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(fn2)(x)),
                               np.asarray(ref), rtol=1e-6)
