"""Crash-tolerant serving: write-ahead journal codec, snapshot
round-trips, kill/restore bit-exactness and DP-shard failover.

The contract under test: greedy and seeded-sampled streams are a pure
function of the submit/cancel/step sequence (wall clock feeds stats
only), so an engine rebuilt on a fresh "process" -- newest good
snapshot + journal-tail replay through the real submit/cancel/step code
paths -- must finish every request with streams bit-identical to an
uninterrupted run, on the same device-round clock.  Corrupt snapshot
generations are fallen past (the journal replays the difference), a
torn journal tail is dropped and truncated, and a killed DP shard
drains its requests onto the survivors without losing a stream.
"""

import json
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import archs
from repro.models import lm
from repro.serving import recovery
from repro.serving.engine import (
    CANCELLED, COMPLETED, ServingEngine, replay_trace)
from repro.serving.faults import FaultInjector
from repro.serving.recovery import Journal, RecoveryError

MAX_LEN = 64

_CACHE = {}


def _setup():
    if "v" not in _CACHE:
        cfg = archs.smoke("mingru-lm")
        _CACHE["v"] = (cfg, lm.init_params(jax.random.PRNGKey(0), cfg))
    return _CACHE["v"]


def _engine(recover_dir=None, **kw):
    cfg, params = _setup()
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("decode_block", 1)
    return ServingEngine(cfg, params, recover_dir=recover_dir, **kw)


def _trace(n, seed=0):
    rng = np.random.default_rng(seed)
    t = [dict(arrival=int(rng.integers(0, 3 * n)),
              prompt=[int(x) for x in
                      rng.integers(1, 250, size=int(rng.integers(2, 6)))],
              max_new=int(rng.integers(3, 8)))
         for _ in range(n)]
    t.sort(key=lambda r: r["arrival"])
    return t


def _submitter(eng):
    # mixed greedy/sampled requests: BOTH must replay bit-identically
    # (the sampling key chains live in the snapshotted slot state)
    def fn(i, r):
        eng.submit(r["prompt"], max_new=r["max_new"],
                   temperature=0.0 if i % 2 == 0 else 0.8,
                   top_k=0 if i % 2 == 0 else 40)
    return fn


def _outs(eng):
    return {rid: req.out for rid, req in sorted(eng.finished.items())}


def _jpath(tmp_path):
    return os.path.join(str(tmp_path), recovery.JOURNAL_NAME)


# ---------------------------------------------------------------------------
# Journal codec (pure host logic, no model)
# ---------------------------------------------------------------------------

def _mk_journal(tmp_path):
    j = Journal.create(_jpath(tmp_path),
                       {"config": {"name": "t"}, "engine": {}})
    j.record_submit({"rid": 0, "round": 0, "prompt": [1, 2], "max_new": 4})
    j.record_step({"round": 0, "k": 4, "emits": [[0, 7]],
                   "digest": {"completed": 0}})
    j.record_cancel({"rid": 0, "round": 4})
    j.close()
    return _jpath(tmp_path)


def test_journal_roundtrip(tmp_path):
    path = _mk_journal(tmp_path)
    header, records, dropped, good = recovery.read_journal(path)
    assert header is not None and header["config"] == {"name": "t"}
    assert [r["kind"] for r in records] == ["submit", "step", "cancel"]
    assert [r["seq"] for r in records] == [1, 2, 3]
    assert dropped == 0 and good == os.path.getsize(path)


def test_journal_numpy_scalars_normalized(tmp_path):
    """Trace prompts arrive as np.int64; the codec must store plain ints
    so recorded and replayed payloads compare equal."""
    j = Journal.create(_jpath(tmp_path), {"config": {}, "engine": {}})
    j.record_submit({"rid": 0, "prompt": list(np.arange(3)),
                     "max_new": np.int64(4)})
    j.close()
    _, records, dropped, _ = recovery.read_journal(_jpath(tmp_path))
    assert dropped == 0
    assert records[0]["prompt"] == [0, 1, 2]
    assert records[0]["max_new"] == 4


def test_journal_torn_tail_dropped_then_truncated_on_resume(tmp_path):
    path = _mk_journal(tmp_path)
    good_size = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(b'{"seq":4,"kind":"step","torn')        # no newline
    header, records, dropped, good = recovery.read_journal(path)
    assert header is not None
    assert len(records) == 3 and dropped == 1
    assert good == good_size
    # replay the tail through the verification path, then flip to append
    j = Journal.for_replay(path, list(records),
                           records[-1]["seq"] + 1, good)
    j.record_submit({"rid": 0, "round": 0, "prompt": [1, 2], "max_new": 4})
    j.record_step({"round": 0, "k": 4, "emits": [[0, 7]],
                   "digest": {"completed": 0}})
    assert j.replaying
    j.record_cancel({"rid": 0, "round": 4})
    assert not j.replaying                    # tail exhausted: append mode
    assert j.replayed == 3 and j.replayed_rounds == 4
    assert os.path.getsize(path) == good_size  # torn bytes truncated
    j.record_step({"round": 4, "k": 4, "emits": [], "digest": {}})
    j.close()
    _, records2, dropped2, _ = recovery.read_journal(path)
    assert dropped2 == 0
    assert [r["seq"] for r in records2] == [1, 2, 3, 4]


def test_journal_mid_corruption_stops_reading(tmp_path):
    path = _mk_journal(tmp_path)
    with open(path, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    lines[2] = lines[2].replace(b'"step"', b'"stop"', 1)  # breaks the crc
    with open(path, "wb") as f:
        f.writelines(lines)
    header, records, dropped, good = recovery.read_journal(path)
    assert header is not None
    # records after a corrupt line cannot be trusted to be gap-free
    assert [r["kind"] for r in records] == ["submit"]
    assert dropped == 2
    assert good == len(lines[0]) + len(lines[1])


def test_journal_replay_divergence_raises(tmp_path):
    path = _mk_journal(tmp_path)
    _, records, _, good = recovery.read_journal(path)
    j = Journal.for_replay(path, list(records), 4, good)
    with pytest.raises(RecoveryError, match="divergence"):
        j.record_step({"round": 0, "k": 4})           # wrong kind
    j = Journal.for_replay(path, list(records), 4, good)
    with pytest.raises(RecoveryError, match="rid"):   # wrong field value
        j.record_submit({"rid": 5, "round": 0, "prompt": [1, 2],
                         "max_new": 4})


# ---------------------------------------------------------------------------
# Journaling is inert: armed recovery never perturbs streams
# ---------------------------------------------------------------------------

def test_journaling_is_inert(tmp_path):
    trace = _trace(5, seed=1)
    ref = _engine()
    replay_trace(ref, trace, _submitter(ref))
    eng = _engine(recover_dir=str(tmp_path), snapshot_every=3)
    replay_trace(eng, trace, _submitter(eng))
    assert _outs(eng) == _outs(ref)
    assert eng.stats.decode_steps == ref.stats.decode_steps
    header, records, dropped, _ = recovery.read_journal(_jpath(tmp_path))
    assert dropped == 0
    assert header["engine"]["max_batch"] == 2
    assert sum(r["kind"] == "submit" for r in records) == len(trace)
    assert recovery.list_snapshots(str(tmp_path))     # snapshots written


# ---------------------------------------------------------------------------
# Kill/restore: the tentpole bit-exactness contract
# ---------------------------------------------------------------------------

def _kill_and_restore(tmp_path, trace, kill_round, snapshot_every):
    """Run a journaled engine until ``kill_round``, abandon it (the
    "crash"), restore on fresh objects and finish the trace."""
    cfg, params = _setup()
    eng = _engine(recover_dir=str(tmp_path), snapshot_every=snapshot_every)
    replay_trace(eng, trace, _submitter(eng),
                 stop=lambda e: e.stats.decode_steps >= kill_round)
    assert len(eng.finished) < len(trace)      # it died with work pending
    eng.journal.close()
    del eng
    rec = ServingEngine.restore(str(tmp_path), cfg, params)
    replay_trace(rec, trace, _submitter(rec), start=len(rec.requests))
    return rec


def test_kill_restore_bit_identical(tmp_path):
    trace = _trace(6, seed=2)
    ref = _engine()
    replay_trace(ref, trace, _submitter(ref))
    rec = _kill_and_restore(tmp_path, trace, kill_round=7,
                            snapshot_every=3)
    rep = rec.recovery_report
    assert rep["snapshot_round"] is not None
    assert rep["replayed_records"] >= 1        # snapshot cadence 3, K=1:
    assert rep["replayed_rounds"] >= 1         # the tail is non-trivial
    assert rep["dropped_tail_records"] == 0
    assert _outs(rec) == _outs(ref)            # bit-identical streams
    assert rec.stats.decode_steps == ref.stats.decode_steps  # round clock
    assert rec.stats.completed == len(trace)
    # the restored engine kept journaling: one contiguous seq line
    _, records, dropped, _ = recovery.read_journal(_jpath(tmp_path))
    assert dropped == 0
    assert [r["seq"] for r in records] == list(range(1, len(records) + 1))


def test_cold_restore_replays_journal_from_scratch(tmp_path):
    """Crash before the first snapshot: recovery is journal-only, the
    whole prefix re-executes from round 0."""
    trace = _trace(4, seed=3)
    ref = _engine()
    replay_trace(ref, trace, _submitter(ref))
    rec = _kill_and_restore(tmp_path, trace, kill_round=5,
                            snapshot_every=10 ** 9)
    rep = rec.recovery_report
    assert rep["snapshot"] is None and rep["snapshot_round"] is None
    assert rep["replayed_records"] == rep["journal_records"]
    assert _outs(rec) == _outs(ref)
    assert rec.stats.decode_steps == ref.stats.decode_steps


def test_corrupt_snapshot_falls_back_a_generation(tmp_path):
    trace = _trace(6, seed=4)
    ref = _engine()
    replay_trace(ref, trace, _submitter(ref))
    cfg, params = _setup()
    eng = _engine(recover_dir=str(tmp_path), snapshot_every=2)
    replay_trace(eng, trace, _submitter(eng),
                 stop=lambda e: e.stats.decode_steps >= 9)
    eng.journal.close()
    del eng
    rounds = recovery.list_snapshots(str(tmp_path))
    assert len(rounds) >= 2
    newest = recovery.snapshot_path(str(tmp_path), rounds[-1])
    with open(os.path.join(newest, "arrays.npz"), "ab") as f:
        f.write(b"bitrot")                     # sha256 now mismatches
    rec = ServingEngine.restore(str(tmp_path), cfg, params)
    rep = rec.recovery_report
    assert rep["corrupt_snapshots_skipped"] == [rounds[-1]]
    assert rep["snapshot_round"] == rounds[-2]
    replay_trace(rec, trace, _submitter(rec), start=len(rec.requests))
    assert _outs(rec) == _outs(ref)


def test_cancel_survives_kill_and_replay(tmp_path):
    cfg, params = _setup()

    def ops(eng):
        rids = [eng.submit([i + 1, i + 2, i + 3], max_new=8)
                for i in range(3)]
        eng.step()
        eng.step()
        eng.cancel(rids[1])                    # staged at this point
        return rids

    ref = _engine(max_batch=1)
    rids = ops(ref)
    ref.run_to_completion()

    eng = _engine(recover_dir=str(tmp_path), snapshot_every=4,
                  max_batch=1)
    assert ops(eng) == rids                    # rids are deterministic
    for _ in range(3):
        eng.step()
    eng.journal.close()
    del eng
    rec = ServingEngine.restore(str(tmp_path), cfg, params)
    rec.run_to_completion()
    assert _outs(rec) == _outs(ref)
    assert rec.finished[rids[1]].status == CANCELLED
    assert rec.stats.cancelled == 1
    assert rec.stats.completed == 2


def test_restore_config_mismatch_raises(tmp_path):
    cfg, params = _setup()
    eng = _engine(recover_dir=str(tmp_path))
    eng.submit([1, 2, 3], max_new=3)
    eng.step()
    eng.journal.close()
    with pytest.raises(RecoveryError, match="config"):
        ServingEngine.restore(str(tmp_path), archs.smoke("minlstm-lm"),
                              params)


def test_restore_without_journal_raises(tmp_path):
    cfg, params = _setup()
    with pytest.raises(RecoveryError, match="journal"):
        ServingEngine.restore(str(tmp_path), cfg, params)


def test_apply_snapshot_rejects_knob_mismatch(tmp_path):
    eng = _engine()
    eng.submit([1, 2, 3], max_new=4)
    eng.step()
    arrays, manifest = recovery.snapshot_engine(eng)
    manifest = json.loads(json.dumps(manifest, default=recovery._np_item))
    clone = _engine(decode_block=2)
    with pytest.raises(RecoveryError, match="decode_block"):
        recovery.apply_snapshot(clone, arrays, manifest)


# ---------------------------------------------------------------------------
# Property: snapshot -> apply resumes bit-identically from ANY state
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 4),
       rounds=st.integers(0, 9))
def test_snapshot_roundtrip_resumes_bit_identically(seed, n, rounds):
    """For a random engine state -- random trace prefix interleaved with
    steps, then a random number of extra rounds -- the snapshot codec's
    (arrays, manifest), JSON round-tripped like the on-disk format,
    applied onto a fresh engine must resume the exact streams on the
    exact round clock."""
    eng = _engine()
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit([int(x) for x in
                    rng.integers(1, 250, size=int(rng.integers(1, 5)))],
                   max_new=int(rng.integers(2, 7)),
                   temperature=0.0 if i % 2 == 0 else 0.7,
                   top_k=0 if i % 2 == 0 else 20)
        if i % 2 == 1:
            eng.step()
    for _ in range(rounds):
        eng.step()
    arrays, manifest = recovery.snapshot_engine(eng)
    manifest = json.loads(json.dumps(manifest, default=recovery._np_item))
    clone = _engine()
    recovery.apply_snapshot(clone, arrays, manifest)
    assert eng.run_to_completion() == clone.run_to_completion()
    assert clone.stats.decode_steps == eng.stats.decode_steps


# ---------------------------------------------------------------------------
# DP-shard failover: a dead shard drains onto the survivors
# ---------------------------------------------------------------------------

def _need_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs {n} virtual devices "
                    f"(REPRO_FORCE_DEVICES, see conftest)")


def _mesh_run(faults=None, **kw):
    cfg, params = _setup()
    eng = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                        decode_block=2, mesh="2x1", faults=faults, **kw)
    rids = [eng.submit([i + 1, i + 2, i + 3], max_new=5)
            for i in range(6)]
    outs = eng.run_to_completion()
    return eng, rids, outs


def test_shard_crash_failover_completes_on_survivors():
    _need_devices(2)
    ref_eng, rids, ref = _mesh_run()
    eng, rids2, outs = _mesh_run(
        faults=FaultInjector(shard_crash_at=((4, 1),)))
    assert rids2 == rids
    assert eng.faults.counts()["shard_crash"] == 1
    assert eng.dead_shards == {1}
    assert eng.stats.shard_crashes == 1
    assert eng.stats.failover_requeued >= 1
    # an infrastructure fault burns none of the request's retry budget
    assert eng.stats.retried == 0
    assert all(eng.finished[r].status == COMPLETED for r in rids)
    assert [outs[r] for r in rids] == [ref[r] for r in rids]
    assert eng.stats.completed == eng.stats.submitted == len(rids)
    # per-shard slot-step identity holds with dead rows idling as waste
    assert eng.stats.snapshot()["shard_identities_ok"]
    assert eng.occupancy_report()["dead_shards"] == [1]
    # degraded serving costs rounds: the survivor pool is half the size
    assert eng.stats.decode_steps > ref_eng.stats.decode_steps


def test_meshed_snapshot_roundtrip_preserves_dead_shards():
    _need_devices(2)
    cfg, params = _setup()
    eng = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                        decode_block=2, mesh="2x1",
                        faults=FaultInjector(shard_crash_at=((2, 1),)))
    [eng.submit([i + 1, i + 2], max_new=6) for i in range(5)]
    for _ in range(3):
        eng.step()
    assert eng.dead_shards == {1}
    arrays, manifest = recovery.snapshot_engine(eng)
    manifest = json.loads(json.dumps(manifest, default=recovery._np_item))
    clone = ServingEngine(cfg, params, max_batch=4, max_len=MAX_LEN,
                          decode_block=2, mesh="2x1",
                          faults=FaultInjector(shard_crash_at=((2, 1),)))
    recovery.apply_snapshot(clone, arrays, manifest)
    assert clone.dead_shards == {1}
    # the loaded injector state remembers the shard already fired
    assert clone.faults._crashed_shards == {1}
    assert eng.run_to_completion() == clone.run_to_completion()
    assert clone.stats.decode_steps == eng.stats.decode_steps
