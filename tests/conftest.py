"""Shared test config.

Two things live here:

1. A vendored no-op-free fallback shim for ``hypothesis``: the tier-1 suite
   uses property-based tests, but the execution image does not ship the
   package.  When the real ``hypothesis`` is importable we use it untouched;
   otherwise a small deterministic stand-in is installed into
   ``sys.modules`` *before* the test modules are collected, so
   ``from hypothesis import given, settings, strategies as st`` works either
   way.  The stand-in draws a fixed number of pseudo-random examples per
   test (seeded from the test name, so runs are reproducible) and always
   includes the boundary values.

2. A module-scoped cache flush: a full tier-1 run jit-compiles hundreds of
   programs (interpret-mode Pallas kernels dominate), and jaxlib's CPU
   backend can segfault inside ``backend_compile`` late in the run once
   that many executables are live (reproducible at ~290 tests in, always
   while compiling a fresh ``lm.prefill`` shape; any single module passes
   in isolation).  Dropping the compiled-program caches between modules
   bounds the live-executable count -- each module re-jits only its own
   shapes, so the overhead is small next to the interpret-mode tests.

3. Multi-device CPU harness: the mesh-sharded serving tests
   (tests/test_mesh_serving.py, tests/test_spmd.py) need several devices,
   and XLA fixes the host-platform device count the moment the backend
   initialises -- AFTER that, no amount of flag-setting helps.  conftest
   is imported before any test module, so this is the one reliable place
   to append ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``.
   The ``REPRO_FORCE_DEVICES`` env knob controls the count (default 8;
   set it to ``0``/``1``/empty to opt out, e.g. to reproduce a
   single-device failure); an XLA_FLAGS that already forces a count is
   left alone.  Forcing N virtual CPU devices only *partitions* the host
   platform -- single-device tests still see device 0 and are unaffected.

4. The ``slow`` marker registration lives in ``pytest.ini``; nothing to do
   here beyond keeping imports cheap.
"""

from __future__ import annotations

import gc
import os
import random
import sys
import types

import pytest

_force = os.environ.get("REPRO_FORCE_DEVICES", "8")
if _force not in ("", "0", "1") and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # importing jax does not initialise the backend -- only the first
    # device/array op does -- so setting the flag here is early enough
    # even if a plugin already imported jax
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " "
        + f"--xla_force_host_platform_device_count={int(_force)}").strip()


@pytest.fixture(autouse=True, scope="module")
def _bound_live_jax_executables():
    """Flush jit caches after every test module (see module docstring, #2)."""
    yield
    import jax

    jax.clear_caches()
    gc.collect()

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A draw rule: boundary examples first, then seeded random draws."""

        def __init__(self, draw, boundary=()):
            self._draw = draw
            self._boundary = list(boundary)

        def example(self, rng, index):
            if index < len(self._boundary):
                return self._boundary[index]
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         boundary=[min_value, max_value])

    def _floats(min_value, max_value, **_kw):
        span = float(max_value) - float(min_value)
        return _Strategy(lambda rng: min_value + rng.random() * span,
                         boundary=[float(min_value), float(max_value), 0.0
                                   if min_value <= 0.0 <= max_value
                                   else float(min_value)])

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5,
                         boundary=[False, True])

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements),
                         boundary=elements[:2])

    def _lists(elem, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem._draw(rng) for _ in range(n)]
        return _Strategy(draw, boundary=[[elem.example(random.Random(0), 0)
                                          for _ in range(min_size)]])

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    class _SkipExample(Exception):
        pass

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            def wrapper(*call_args, **call_kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(fn.__name__)
                for i in range(n):
                    args = [s.example(rng, i) for s in arg_strategies]
                    kwargs = {k: s.example(rng, i)
                              for k, s in kw_strategies.items()}
                    kwargs.update(call_kwargs)
                    try:
                        fn(*call_args, *args, **kwargs)
                    except _SkipExample:
                        continue
                    except Exception as e:  # annotate the failing example
                        raise AssertionError(
                            f"falsifying example (shim): args={args} "
                            f"kwargs={kwargs}") from e
            # NB: no functools.wraps / __wrapped__ -- pytest would follow it
            # and treat the strategy parameters as fixture requests.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                             data_too_large="data_too_large")
    _hyp.assume = lambda cond: None if cond else (_ for _ in ()).throw(
        _SkipExample())
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
