"""Data pipeline invariants (hypothesis where the property is cheap)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import lm_corpus, rl_proxy, synthetic


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20), step=st.integers(0, 1000))
def test_selective_copy_structure(seed, step):
    b = synthetic.selective_copy_batch(seed, step, 4, seq_len=32, n_data=5)
    tokens, labels = b["tokens"], b["labels"]
    assert tokens.shape == labels.shape
    for i in range(4):
        answer = labels[i][labels[i] >= 0]
        assert len(answer) == 5
        data_tokens = tokens[i, :32][tokens[i, :32] > 0]
        np.testing.assert_array_equal(np.sort(answer), np.sort(data_tokens))
        # labels are next-token aligned
        for p in np.nonzero(labels[i] >= 0)[0][:-1]:
            assert labels[i, p] == tokens[i, p + 1]


def test_determinism_same_seed_step():
    a = synthetic.selective_copy_batch(7, 42, 4, seq_len=16, n_data=3)
    b = synthetic.selective_copy_batch(7, 42, 4, seq_len=16, n_data=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic.selective_copy_batch(7, 43, 4, seq_len=16, n_data=3)
    assert not np.array_equal(a["tokens"], c["tokens"])


@pytest.mark.parametrize("task", list(synthetic.CHOMSKY_TASKS))
def test_chomsky_labels_in_range(task):
    fn = synthetic.CHOMSKY_TASKS[task]
    b = fn(0, 0, 16)
    assert b["label"].min() >= 0
    assert b["label"].max() < b["n_classes"]
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < synthetic.CLS_VOCAB


def test_cycle_nav_ground_truth():
    b = synthetic.cycle_nav(0, 0, 8, min_len=5, max_len=10)
    moves = {1: 1, 2: -1, 3: 0}
    for i in range(8):
        toks = b["tokens"][i][b["tokens"][i] > 0]
        assert b["label"][i] == sum(moves[t] for t in toks) % 5


def test_listops_eval_correct():
    b = synthetic.listops(1, 0, 8, max_len=64, max_depth=2)
    assert (0 <= b["label"]).all() and (b["label"] < 10).all()


def test_lm_corpus_split_and_batch():
    train, test = lm_corpus.build_corpus(target_bytes=50_000)
    assert len(train) > 40_000 and len(test) > 4_000
    b = lm_corpus.lm_batch(train, 0, 0, 4, 64)
    assert b["tokens"].shape == (4, 64)
    # next-char alignment
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_rl_proxy_rtg_consistency():
    ds = rl_proxy.build_dataset("medium", n_episodes=4)
    rtg = ds["rtg"][:, :, 0]
    # rtg[t] - rtg[t+1] == reward at t; rtg decreasing toward episode end
    assert np.isfinite(rtg).all()
    assert (np.abs(rtg[:, -1]) <= np.abs(rtg[:, 0]) + 1e-3).all()


def test_rl_proxy_expert_beats_random():
    assert rl_proxy.expert_score() > rl_proxy.random_score() + 1.0
