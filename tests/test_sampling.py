"""Property tests for the on-device sampling module (serving.sampling).

Core properties: the greedy path is exact argmax, sampling approaches
greedy as T -> 0, top-k / top-p restrict the support to exactly the
documented sets, and everything is deterministic under a fixed key.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving import sampling

V = 64


def _logits(seed, batch=4, v=V, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (batch, v)) * scale


def _call(logits, seed=0, temperature=1.0, top_k=0, top_p=1.0):
    b = logits.shape[0]
    return sampling.sample_tokens(
        logits, sampling.make_keys(seed, b),
        jnp.full((b,), temperature, jnp.float32),
        jnp.full((b,), top_k, jnp.int32),
        jnp.full((b,), top_p, jnp.float32))


def test_greedy_is_exact_argmax():
    logits = _logits(0)
    toks, _ = _call(logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_temperature_to_zero_limit_is_greedy(seed):
    """As T -> 0 the categorical collapses onto the argmax."""
    logits = _logits(seed)
    toks, _ = _call(logits, seed=seed, temperature=1e-4)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20), k=st.integers(1, V))
def test_top_k_restricts_support(seed, k):
    logits = _logits(seed, batch=8)
    toks, _ = _call(logits, seed=seed, temperature=1.3, top_k=k)
    toks = np.asarray(toks)
    srt = np.sort(np.asarray(logits), axis=-1)[:, ::-1]
    for b in range(logits.shape[0]):
        kth = srt[b, k - 1]
        assert np.asarray(logits)[b, toks[b]] >= kth, (b, k)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20), p=st.floats(0.05, 1.0))
def test_top_p_restricts_support(seed, p):
    """Sampled token must lie in the smallest prefix of the sorted
    distribution whose mass reaches p (ties at the cutoff kept)."""
    logits = _logits(seed, batch=8)
    toks, _ = _call(logits, seed=seed, temperature=1.0, top_p=p)
    toks = np.asarray(toks)
    l_np = np.asarray(logits, np.float64)
    for b in range(logits.shape[0]):
        srt = np.sort(l_np[b])[::-1]
        probs = np.exp(srt - srt.max())
        probs /= probs.sum()
        csum = np.cumsum(probs)
        count = max(1, int(np.sum((csum - probs) < p)))
        cutoff = srt[count - 1]
        assert l_np[b, toks[b]] >= cutoff - 1e-6, (b, p)


def test_top_k_one_is_greedy_even_at_high_temperature():
    logits = _logits(3)
    toks, _ = _call(logits, temperature=5.0, top_k=1)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_deterministic_under_fixed_key(seed):
    logits = _logits(seed)
    t1, k1 = _call(logits, seed=seed, temperature=0.9, top_k=10, top_p=0.9)
    t2, k2 = _call(logits, seed=seed, temperature=0.9, top_k=10, top_p=0.9)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


def test_keys_advance_and_vary_across_steps():
    logits = _logits(7, batch=16, scale=0.3)   # flat-ish: sampling visible
    keys = sampling.make_keys(0, 16)
    temps = jnp.ones((16,), jnp.float32)
    topk = jnp.zeros((16,), jnp.int32)
    topp = jnp.ones((16,), jnp.float32)
    t1, keys2 = sampling.sample_tokens(logits, keys, temps, topk, topp)
    t2, keys3 = sampling.sample_tokens(logits, keys2, temps, topk, topp)
    assert not np.array_equal(np.asarray(keys), np.asarray(keys2))
    # same logits, advanced keys: draws differ somewhere with high prob
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))


def test_per_slot_controls_are_independent():
    """Greedy and sampled slots coexist in one call."""
    logits = _logits(11, batch=6, scale=0.2)
    keys = sampling.make_keys(0, 6)
    temps = jnp.asarray([0.0, 2.0, 0.0, 2.0, 0.0, 2.0], jnp.float32)
    topk = jnp.zeros((6,), jnp.int32)
    topp = jnp.ones((6,), jnp.float32)
    greedy = np.asarray(jnp.argmax(logits, -1))
    draws = []
    for trial in range(8):
        toks, keys = sampling.sample_tokens(logits, keys, temps, topk, topp)
        toks = np.asarray(toks)
        np.testing.assert_array_equal(toks[::2], greedy[::2])
        draws.append(toks[1::2].copy())
    # hot slots actually explore (flat logits, T=2): not all draws equal
    assert len({tuple(d) for d in draws}) > 1


def test_top_p_one_disables_nucleus_entirely():
    """top_p=1.0 must keep the FULL support even when the f32 cumsum
    saturates at 1.0 (one dominant token + tiny tail)."""
    logits = np.full((2, V), -20.0, np.float32)
    logits[:, 0] = 10.0                         # tail probs ~ e^-30
    masked = sampling._support_mask(jnp.asarray(logits),
                                    jnp.zeros((2,), jnp.int32),
                                    jnp.ones((2,), jnp.float32))
    np.testing.assert_array_equal(np.asarray(masked), logits)


def test_masked_vocab_tail_is_never_sampled():
    """Columns at -1e30 (padded vocab) have zero probability."""
    logits = np.array(_logits(13, batch=4, scale=0.1))
    logits[:, V // 2:] = -1e30
    for trial in range(5):
        toks, _ = _call(jnp.asarray(logits), seed=trial, temperature=3.0)
        assert np.all(np.asarray(toks) < V // 2)
