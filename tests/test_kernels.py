"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_mingru import ops as fg_ops
from repro.kernels.fused_mingru import ref as fg_ref
from repro.kernels.scan import ops as scan_ops
from repro.kernels.scan import ref as scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# chunked linear scan kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (1, 8, 128),          # exactly one tile
    (2, 64, 128),         # multiple time chunks
    (2, 100, 70),         # ragged T and D (padding path)
    (3, 7, 1),            # tiny
    (1, 300, 130),        # ragged both, > 1 tile each
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_kernel_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape)).astype(dtype)
    b = jax.random.normal(k2, shape).astype(dtype)
    h0 = jax.random.normal(k3, shape[:1] + shape[2:]).astype(dtype)
    out = scan_ops.linear_scan(a, b, h0, 64, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("block_t", [8, 32, 256])
def test_scan_kernel_block_sizes(block_t):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 96, 16)))
    b = jax.random.normal(k2, (2, 96, 16))
    h0 = jnp.zeros((2, 16))
    out = scan_ops.linear_scan(a, b, h0, block_t, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_scan_kernel_vjp_matches_ref_vjp():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 60, 20)))
    b = jax.random.normal(k2, (2, 60, 20))
    h0 = jax.random.normal(k3, (2, 20))

    def loss_k(args):
        return jnp.sum(scan_ops.linear_scan(*args, 32, 128, True) ** 2)

    def loss_r(args):
        return jnp.sum(scan_ref.linear_scan_ref(*args) ** 2)

    gk = jax.grad(loss_k)((a, b, h0))
    gr = jax.grad(loss_r)((a, b, h0))
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-4)


def test_scan_kernel_long_sequence():
    """Many sequential chunks exercise the VMEM carry path."""
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (1, 2048, 8)))
    b = jax.random.normal(k2, (1, 2048, 8))
    h0 = jnp.zeros((1, 8))
    out = scan_ops.linear_scan(a, b, h0, 128, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# fused minGRU kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (2, 32, 16, 128),     # (B, T, Dx, Dh) aligned
    (2, 50, 24, 40),      # ragged
    (1, 8, 8, 8),         # tiny
])
@pytest.mark.parametrize("mode", ["log", "linear"])
def test_fused_mingru_matches_ref(shape, mode):
    bsz, t, dx, dh = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bsz, t, dx))
    wz = jax.random.normal(ks[1], (dx, dh)) * 0.2
    wh = jax.random.normal(ks[2], (dx, dh)) * 0.2
    bz = jax.random.normal(ks[3], (dh,)) * 0.1
    bh = jnp.zeros((dh,))
    out = fg_ops.fused_mingru(x, wz, bz, wh, bh, mode=mode, interpret=True)
    ref = fg_ref.fused_mingru_ref(x, wz, bz, wh, bh, mode=mode)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mingru_dtypes(dtype):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (2, 16, 32)).astype(dtype)
    wz = (jax.random.normal(ks[1], (32, 128)) * 0.2).astype(dtype)
    wh = (jax.random.normal(ks[2], (32, 128)) * 0.2).astype(dtype)
    out = fg_ops.fused_mingru(x, wz, None, wh, None, interpret=True)
    ref = fg_ref.fused_mingru_ref(
        x.astype(jnp.float32), wz.astype(jnp.float32), jnp.zeros(128),
        wh.astype(jnp.float32), jnp.zeros(128))
    np.testing.assert_allclose(out.astype(jnp.float32), ref, **_tol(dtype))


def test_fused_mingru_matches_layer():
    """Kernel output == the model-layer (min_gru.parallel) output."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(4), 16, 24)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 20, 16))
    layer = min_gru.parallel(params, x, mode="log")
    out = fg_ops.fused_mingru(
        x, params["wz"]["kernel"], params["wz"]["bias"],
        params["wh"]["kernel"], params["wh"]["bias"], mode="log",
        interpret=True)
    np.testing.assert_allclose(out, layer, rtol=3e-4, atol=3e-4)


def test_mingru_layer_pallas_strategy_matches_associative():
    """The model-layer kernel path: min_gru.parallel(strategy='pallas')."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(6), 12, 20)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 33, 12))
    ref = min_gru.parallel(params, x, mode="linear",
                           scan_strategy="associative")
    out = min_gru.parallel(params, x, mode="linear", scan_strategy="pallas")
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_minlstm_layer_pallas_strategy_matches_associative():
    from repro.core import min_lstm
    params = min_lstm.init(jax.random.PRNGKey(8), 12, 20)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 33, 12))
    ref = min_lstm.parallel(params, x, mode="linear",
                            scan_strategy="associative")
    out = min_lstm.parallel(params, x, mode="linear",
                            scan_strategy="pallas")
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_pallas_scan_trains():
    """Gradient flows through the kernel's custom VJP in a real layer."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(10), 8, 8)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 16, 8))

    def loss(p):
        h = min_gru.parallel(p, x, mode="linear", scan_strategy="pallas")
        return jnp.mean(h ** 2)

    def loss_ref(p):
        h = min_gru.parallel(p, x, mode="linear",
                             scan_strategy="associative")
        return jnp.mean(h ** 2)

    g = jax.grad(loss)(params)
    g_ref = jax.grad(loss_ref)(params)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
