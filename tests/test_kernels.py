"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_mingru import ops as fg_ops
from repro.kernels.fused_mingru import ref as fg_ref
from repro.kernels.scan import ops as scan_ops
from repro.kernels.scan import ref as scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# chunked linear scan kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (1, 8, 128),          # exactly one tile
    (2, 64, 128),         # multiple time chunks
    (2, 100, 70),         # ragged T and D (padding path)
    (3, 7, 1),            # tiny
    (1, 300, 130),        # ragged both, > 1 tile each
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_kernel_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape)).astype(dtype)
    b = jax.random.normal(k2, shape).astype(dtype)
    h0 = jax.random.normal(k3, shape[:1] + shape[2:]).astype(dtype)
    out = scan_ops.linear_scan(a, b, h0, 64, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("block_t", [8, 32, 256])
def test_scan_kernel_block_sizes(block_t):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 96, 16)))
    b = jax.random.normal(k2, (2, 96, 16))
    h0 = jnp.zeros((2, 16))
    out = scan_ops.linear_scan(a, b, h0, block_t, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_scan_kernel_vjp_matches_ref_vjp():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 60, 20)))
    b = jax.random.normal(k2, (2, 60, 20))
    h0 = jax.random.normal(k3, (2, 20))

    def loss_k(args):
        return jnp.sum(scan_ops.linear_scan(*args, 32, 128, True) ** 2)

    def loss_r(args):
        return jnp.sum(scan_ref.linear_scan_ref(*args) ** 2)

    gk = jax.grad(loss_k)((a, b, h0))
    gr = jax.grad(loss_r)((a, b, h0))
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-4)


def test_scan_kernel_long_sequence():
    """Many sequential chunks exercise the VMEM carry path."""
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(k1, (1, 2048, 8)))
    b = jax.random.normal(k2, (1, 2048, 8))
    h0 = jnp.zeros((1, 8))
    out = scan_ops.linear_scan(a, b, h0, 128, 128, True)
    ref = scan_ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# fused minGRU kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (2, 32, 16, 128),     # (B, T, Dx, Dh) aligned
    (2, 50, 24, 40),      # ragged
    (1, 8, 8, 8),         # tiny
])
@pytest.mark.parametrize("mode", ["log", "linear"])
def test_fused_mingru_matches_ref(shape, mode):
    bsz, t, dx, dh = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bsz, t, dx))
    wz = jax.random.normal(ks[1], (dx, dh)) * 0.2
    wh = jax.random.normal(ks[2], (dx, dh)) * 0.2
    bz = jax.random.normal(ks[3], (dh,)) * 0.1
    bh = jnp.zeros((dh,))
    out = fg_ops.fused_mingru(x, wz, bz, wh, bh, mode=mode, interpret=True)
    ref = fg_ref.fused_mingru_ref(x, wz, bz, wh, bh, mode=mode)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mingru_dtypes(dtype):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (2, 16, 32)).astype(dtype)
    wz = (jax.random.normal(ks[1], (32, 128)) * 0.2).astype(dtype)
    wh = (jax.random.normal(ks[2], (32, 128)) * 0.2).astype(dtype)
    out = fg_ops.fused_mingru(x, wz, None, wh, None, interpret=True)
    ref = fg_ref.fused_mingru_ref(
        x.astype(jnp.float32), wz.astype(jnp.float32), jnp.zeros(128),
        wh.astype(jnp.float32), jnp.zeros(128))
    np.testing.assert_allclose(out.astype(jnp.float32), ref, **_tol(dtype))


def test_fused_mingru_matches_layer():
    """Kernel output == the model-layer (min_gru.parallel) output."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(4), 16, 24)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 20, 16))
    layer = min_gru.parallel(params, x, mode="log")
    out = fg_ops.fused_mingru(
        x, params["wz"]["kernel"], params["wz"]["bias"],
        params["wh"]["kernel"], params["wh"]["bias"], mode="log",
        interpret=True)
    np.testing.assert_allclose(out, layer, rtol=3e-4, atol=3e-4)


def test_mingru_layer_pallas_strategy_matches_associative():
    """The model-layer kernel path: min_gru.parallel(strategy='pallas')."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(6), 12, 20)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 33, 12))
    ref = min_gru.parallel(params, x, mode="linear",
                           scan_strategy="associative")
    out = min_gru.parallel(params, x, mode="linear", scan_strategy="pallas")
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_minlstm_layer_pallas_strategy_matches_associative():
    from repro.core import min_lstm
    params = min_lstm.init(jax.random.PRNGKey(8), 12, 20)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 33, 12))
    ref = min_lstm.parallel(params, x, mode="linear",
                            scan_strategy="associative")
    out = min_lstm.parallel(params, x, mode="linear",
                            scan_strategy="pallas")
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_pallas_scan_trains():
    """Gradient flows through the kernel's custom VJP in a real layer."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(10), 8, 8)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 16, 8))

    def loss(p):
        h = min_gru.parallel(p, x, mode="linear", scan_strategy="pallas")
        return jnp.mean(h ** 2)

    def loss_ref(p):
        h = min_gru.parallel(p, x, mode="linear",
                             scan_strategy="associative")
        return jnp.mean(h ** 2)

    g = jax.grad(loss)(params)
    g_ref = jax.grad(loss_ref)(params)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# log-space scan kernel (the default mode="log" hot path)
# ---------------------------------------------------------------------------

def _log_case(key, shape):
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape))
    b = jnp.exp(jax.random.normal(k2, shape) * 0.5)       # b > 0 (g())
    h0 = jnp.exp(jax.random.normal(k3, shape[:1] + shape[2:]) * 0.5)
    return jnp.log(a), jnp.log(b), jnp.log(h0)


@pytest.mark.parametrize("shape", [
    (1, 8, 128),          # exactly one tile
    (2, 64, 128),         # multiple time chunks
    (2, 100, 70),         # ragged T and D (identity (0,-inf) padding path)
    (3, 7, 1),            # tiny
    (1, 300, 130),        # ragged both, > 1 tile each
])
def test_log_scan_kernel_matches_scan_log_space(shape):
    from repro.core import scan as scan_lib
    la, lb, lh0 = _log_case(jax.random.PRNGKey(hash(shape) % 2**31), shape)
    out = scan_ops.log_space_scan(la, lb, lh0, 64, 128, True)
    ref = scan_lib.scan_log_space(la, lb, lh0)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_log_scan_kernel_zero_h0_is_neg_inf():
    """-inf log_h0 (h0 = 0) flows through the logaddexp ladder cleanly."""
    from repro.core import scan as scan_lib
    la, lb, _ = _log_case(jax.random.PRNGKey(0), (2, 50, 20))
    out = scan_ops.log_space_scan_auto(la, lb)           # fills -inf
    ref = scan_lib.scan_log_space(la, lb)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_log_scan_kernel_saturated_gates_stable():
    """Saturated gates (|preact| ~ 40): long products of a_t underflow any
    linear-space carry; the log-space carry must stay finite and match the
    associative Heinsen scan.

    Tolerance note: the Heinsen reference materialises the *global* cumsum
    of log_a (~ -40*T), whose fp32 ulp alone is ~2e-3 by T=512 -- the
    kernel only ever holds per-chunk cumulants, so it is the more accurate
    of the two; the comparison bounds their divergence, not kernel error.
    """
    from repro.core import scan as scan_lib
    k = jnp.full((1, 512, 8), 40.0)
    log_a = -jax.nn.softplus(k)          # log sigma(-k) ~ -40
    log_b = -jax.nn.softplus(-k) + 0.3
    out = scan_ops.log_space_scan_auto(log_a, log_b, block_t=64)
    ref = scan_lib.scan_log_space(log_a, log_b)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-2)


def test_log_scan_kernel_vjp_matches_scan_log_space_grad():
    from repro.core import scan as scan_lib
    la, lb, lh0 = _log_case(jax.random.PRNGKey(1), (2, 60, 20))

    def loss_k(args):
        return jnp.sum(scan_ops.log_space_scan(*args, 32, 128, True) ** 2)

    def loss_r(args):
        return jnp.sum(scan_lib.scan_log_space(*args) ** 2)

    gk = jax.grad(loss_k)((la, lb, lh0))
    gr = jax.grad(loss_r)((la, lb, lh0))
    # dlog_a couples to h_{t-1}, whose fp32 rounding differs between the
    # chunked kernel and the associative reference -- scale-relative 1e-3
    for x, y in zip(gk, gr):
        scale = np.maximum(np.abs(np.asarray(y)), 1.0)
        np.testing.assert_allclose(np.asarray(x) / scale,
                                   np.asarray(y) / scale,
                                   rtol=1e-3, atol=1e-3)


def test_mingru_layer_log_pallas_strategy_matches_associative():
    """mode='log' + strategy='pallas' routes through the log kernel."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(12), 12, 20)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 33, 12))
    ref = min_gru.parallel(params, x, mode="log",
                           scan_strategy="associative")
    out = min_gru.parallel(params, x, mode="log", scan_strategy="pallas")
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Numerical drift: why the log-space kernel exists (min_gru.gates() docs)
# ---------------------------------------------------------------------------

def test_log_vs_linear_bf16_drift_at_4096():
    """At T=4096 a bf16 linear-space scan of (1-z, z*g(v)) drifts visibly
    from the fp32 log-space reference, while the Pallas log kernel (fp32
    logaddexp ladder, log-space carry) stays tight -- the two
    parameterisations are mathematically identical (see min_gru.gates),
    so the gap is purely accumulated rounding, i.e. the kernel's
    rescaling is both needed and correct."""
    from repro.core import scan as scan_lib
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    shape = (1, 4096, 128)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape) * 0.5)
    b = jnp.exp(jax.random.normal(k2, shape) * 0.3)
    with _x64():     # fp64 sequential scan: the actual ground truth
        ref = np.asarray(scan_lib.scan_sequential(
            jnp.asarray(np.asarray(a), jnp.float64),
            jnp.asarray(np.asarray(b), jnp.float64)))

    lin_bf16 = np.asarray(scan_lib.scan_associative(
        a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)).astype(jnp.float32))
    pallas_log = np.asarray(scan_ops.log_space_scan_auto(jnp.log(a),
                                                         jnp.log(b)))

    err_bf16 = float(np.max(np.abs(lin_bf16 - ref) / (np.abs(ref) + 1)))
    err_pallas = float(np.max(np.abs(pallas_log - ref) / (np.abs(ref) + 1)))
    # measured: pallas ~2e-7, bf16 linear ~1e-2 (and even the fp32 Heinsen
    # associative form sits at ~4e-4 -- the chunked kernel never
    # materialises the global cumsum, so it beats both)
    assert err_pallas < 1e-5, err_pallas
    assert err_bf16 > 1e-3, err_bf16


# ---------------------------------------------------------------------------
# Gradchecks against jax.grad of the sequential oracle (fp64)
# ---------------------------------------------------------------------------

def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def test_linear_scan_gradcheck_vs_sequential_fp64():
    """Kernel VJP vs jax.grad of the fp64 sequential scan: odd T/D,
    nonzero h0."""
    from repro.core import scan as scan_lib
    with _x64():
        key = jax.random.PRNGKey(3)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        shape = (2, 37, 5)
        a = jax.nn.sigmoid(jax.random.normal(k1, shape, jnp.float64))
        b = jax.random.normal(k2, shape, jnp.float64)
        h0 = jax.random.normal(k3, shape[:1] + shape[2:], jnp.float64)
        ct = jax.random.normal(k4, shape, jnp.float64)

        def loss_k(args):
            return jnp.sum(scan_ops.linear_scan(*args, 16, 128, True) * ct)

        def loss_r(args):
            return jnp.sum(scan_lib.scan_sequential(*args) * ct)

        gk = jax.grad(loss_k)((a, b, h0))
        gr = jax.grad(loss_r)((a, b, h0))
        for x, y in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cell_name", ["mingru", "minlstm"])
@pytest.mark.parametrize("mode", ["log", "linear"])
def test_fused_gradcheck_vs_sequential_fp64(cell_name, mode):
    """Fused-kernel VJPs vs jax.grad of the fp64 sequential rollout: odd
    T/D, nonzero h0, gradients into params, x AND the carried h0."""
    from repro.core import min_gru, min_lstm, nn
    cell = {"mingru": min_gru, "minlstm": min_lstm}[cell_name]
    with _x64():
        params = cell.init(jax.random.PRNGKey(5), 7, 11)
        params = jax.tree.map(lambda p: p.astype(jnp.float64), params)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 23, 7),
                              jnp.float64)
        h0 = nn.g(jax.random.normal(jax.random.PRNGKey(7), (2, 11),
                                    jnp.float64))

        def loss_fused(p, x, h0):
            h = cell.parallel(p, x, h0, mode=mode, scan_strategy="fused")
            return jnp.mean(h ** 2)

        def loss_ref(p, x, h0):
            hs = []
            h = h0
            for t in range(x.shape[-2]):
                h = cell.step(p, x[..., t, :], h, mode=mode)
                hs.append(h)
            return jnp.mean(jnp.stack(hs, axis=-2) ** 2)

        gf = jax.grad(loss_fused, argnums=(0, 1, 2))(params, x, h0)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(params, x, h0)
        for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gr)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused minLSTM kernel
# ---------------------------------------------------------------------------

from repro.kernels.fused_minlstm import ops as fl_ops
from repro.kernels.fused_minlstm import ref as fl_ref


def _minlstm_case(key, bsz, t, dx, dh):
    ks = jax.random.split(key, 7)
    x = jax.random.normal(ks[0], (bsz, t, dx))
    ws = [jax.random.normal(k, (dx, dh)) * 0.2 for k in ks[1:4]]
    bs = [jax.random.normal(k, (dh,)) * 0.1 for k in ks[4:7]]
    return x, ws, bs


@pytest.mark.parametrize("shape", [
    (2, 32, 16, 128),     # (B, T, Dx, Dh) aligned
    (2, 50, 24, 40),      # ragged
    (1, 8, 8, 8),         # tiny
])
@pytest.mark.parametrize("mode", ["log", "linear"])
@pytest.mark.parametrize("normalize", [True, False])
def test_fused_minlstm_matches_ref(shape, mode, normalize):
    bsz, t, dx, dh = shape
    x, (wf, wi, wh), (bf, bi, bh) = _minlstm_case(
        jax.random.PRNGKey(hash(shape) % 2**31), bsz, t, dx, dh)
    out = fl_ops.fused_minlstm(x, wf, bf, wi, bi, wh, bh, mode=mode,
                               normalize=normalize, interpret=True)
    ref = fl_ref.fused_minlstm_ref(x, wf, bf, wi, bi, wh, bh, mode=mode,
                                   normalize=normalize)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_fused_minlstm_matches_layer():
    from repro.core import min_lstm
    params = min_lstm.init(jax.random.PRNGKey(14), 16, 24)
    x = jax.random.normal(jax.random.PRNGKey(15), (2, 20, 16))
    layer = min_lstm.parallel(params, x, mode="log")
    out = min_lstm.parallel(params, x, mode="log", scan_strategy="fused")
    np.testing.assert_allclose(out, layer, rtol=3e-4, atol=3e-4)


def test_fused_minlstm_normalize_saturated_gates_finite():
    """f/(f+i) hits 0/0 = NaN when both sigmoids underflow (pre-activations
    below ~-104 in fp32); the stable normalized_gates form must keep the
    fused default path finite and matching the log-space associative scan
    in both forward and backward."""
    from repro.core import min_lstm
    dx, dh = 4, 8
    x = jnp.ones((1, 12, dx))
    params = {
        "wf": {"kernel": jnp.zeros((dx, dh)), "bias": jnp.full((dh,), -480.0)},
        "wi": {"kernel": jnp.zeros((dx, dh)), "bias": jnp.full((dh,), -480.0)},
        "wh": {"kernel": jax.random.normal(jax.random.PRNGKey(0),
                                           (dx, dh)) * 0.2},
    }
    ref = min_lstm.parallel(params, x, mode="log",
                            scan_strategy="associative")
    out = min_lstm.parallel(params, x, mode="log", scan_strategy="fused")
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)

    def loss(p):
        return jnp.mean(min_lstm.parallel(p, x, mode="log",
                                          scan_strategy="fused") ** 2)

    grads = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


# ---------------------------------------------------------------------------
# fused vs unfused parity across tilings (both cells, both modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell_name", ["mingru", "minlstm"])
@pytest.mark.parametrize("mode", ["log", "linear"])
@pytest.mark.parametrize("block_t,block_dh", [
    (8, 128),
    (32, 128),
    (64, 256),
    (256, 128),       # default
])
def test_fused_vs_unfused_forward_parity_tilings(cell_name, mode, block_t,
                                                 block_dh):
    from repro.core import min_gru, min_lstm
    from repro.kernels.fused_mingru import ops as fg
    cell = {"mingru": min_gru, "minlstm": min_lstm}[cell_name]
    params = cell.init(jax.random.PRNGKey(block_t + block_dh), 10, 36)
    x = jax.random.normal(jax.random.PRNGKey(16), (2, 45, 10))
    ref = cell.parallel(params, x, mode=mode, scan_strategy="associative")
    if cell_name == "mingru":
        out = fg.fused_mingru(
            x, params["wz"]["kernel"], params["wz"]["bias"],
            params["wh"]["kernel"], params["wh"]["bias"], mode=mode,
            block_t=block_t, block_dh=block_dh, interpret=True)
    else:
        out = fl_ops.fused_minlstm(
            x, params["wf"]["kernel"], params["wf"]["bias"],
            params["wi"]["kernel"], params["wi"]["bias"],
            params["wh"]["kernel"], params["wh"]["bias"], mode=mode,
            block_t=block_t, block_dh=block_dh, interpret=True)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_fused_carried_h0_composes_like_chunked_prefill():
    """Fused(x[:s], h0) then fused(x[s:], carry) == fused(x) -- the chunked
    prefill / carried-state contract of the engine's prefill path."""
    from repro.core import min_gru
    params = min_gru.init(jax.random.PRNGKey(17), 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(18), (2, 24, 8))
    full = min_gru.parallel(params, x, mode="log", scan_strategy="fused")
    s = 11
    h_a = min_gru.parallel(params, x[:, :s], mode="log",
                           scan_strategy="fused")
    h_b = min_gru.parallel(params, x[:, s:], h_a[:, -1], mode="log",
                           scan_strategy="fused")
    np.testing.assert_allclose(jnp.concatenate([h_a, h_b], axis=1), full,
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# default dispatch: cfg.scan_strategy="auto" actually hits the kernels
# ---------------------------------------------------------------------------

def test_lm_default_dispatch_hits_fused_kernel(monkeypatch):
    """mingru_lm forward+backward run through the fused Pallas kernel by
    default (auto -> fused; interpret mode on CPU)."""
    from repro.configs import archs
    from repro.kernels.fused_mingru import ops as fg
    from repro.models import lm

    calls = {"n": 0}
    real = fg.fused_mingru

    def spy(*args, **kw):
        calls["n"] += 1
        return real(*args, **kw)

    monkeypatch.setattr(fg, "fused_mingru", spy)
    cfg = archs.smoke("mingru-lm")
    assert cfg.scan_strategy == "auto"
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jnp.zeros((1, 8), jnp.int32),
        "labels": jnp.zeros((1, 8), jnp.int32),
    }
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    assert calls["n"] > 0
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
