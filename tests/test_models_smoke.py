"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train step on CPU, shape + finite checks.
Also prefill/decode consistency for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.models import encdec, lm

# heavy tier: every arch x (forward, train step, prefill/decode roll-out);
# deselect with `pytest -m "not slow"` for the fast loop
pytestmark = pytest.mark.slow

ALL_ARCHS = archs.ASSIGNED + archs.PAPER_OWN + archs.EXTRAS

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens, cfg.frontend_dim))
    elif cfg.frontend == "patches":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_frontend_tokens, cfg.frontend_dim))
    return batch


def _model(cfg):
    return encdec if cfg.family == "encdec" else lm


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = archs.smoke(name)
    m = _model(cfg)
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    if cfg.family == "encdec":
        logits = m.forward(params, cfg, batch["frames"], batch["tokens"])
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits, aux = m.forward(params, cfg, batch["tokens"],
                                patch_embeds=batch.get("patch_embeds"))
        expect_s = S + (cfg.n_frontend_tokens
                        if cfg.frontend == "patches" else 0)
        assert logits.shape == (B, expect_s, cfg.vocab_size)
        assert bool(jnp.isfinite(aux))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step(name):
    cfg = archs.smoke(name)
    m = _model(cfg)
    params = m.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        return m.loss_fn(p, cfg, batch)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    # sanity: loss near ln(vocab) for random init
    assert 0.2 * np.log(cfg.vocab_size) < float(val) < \
        3.0 * np.log(cfg.vocab_size) + 2.0
    finite = all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
                 for g in jax.tree.leaves(grads))
    assert finite
    # apply a tiny SGD step; loss should stay finite
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
    assert bool(jnp.isfinite(loss(params2)))


@pytest.mark.parametrize("name", [n for n in ALL_ARCHS
                                  if archs.smoke(n).family != "encdec"])
def test_prefill_then_decode_matches_forward(name):
    """Parallel prefill + sequential decode == full parallel forward.

    The paper's central correctness property, checked per architecture."""
    cfg = archs.smoke(name)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    patch = None
    if cfg.frontend == "patches":
        patch = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens,
                                    cfg.frontend_dim))
    full_logits, _ = lm.forward(params, cfg, tokens, patch_embeds=patch)

    split = S // 2
    max_len = S + 8
    last, cache = lm.prefill(params, cfg, tokens[:, :split], max_len,
                             patch_embeds=patch)
    offset = cfg.n_frontend_tokens if cfg.frontend == "patches" else 0
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full_logits[:, offset + split - 1], np.float32),
        rtol=2e-3, atol=2e-3)

    # prefill consumed `split` positions (+ patches); fix pos bookkeeping
    for t in range(split, S):
        logits, cache = lm.decode_step(params, cfg, tokens[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, offset + t], np.float32),
            rtol=2e-3, atol=2e-3)


def test_whisper_prefill_decode_matches_forward():
    cfg = archs.smoke("whisper-base")
    params = encdec.init_params(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.n_frontend_tokens, cfg.frontend_dim))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    full = encdec.forward(params, cfg, frames, tokens)
    cache = encdec.init_cache(cfg, B, S + 4)
    cache = encdec.prefill(params, cfg, frames, cache)
    for t in range(S):
        logits, cache = encdec.decode_step(params, cfg, tokens[:, t], cache)
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["mingru-lm", "minlstm-lm"])
def test_paper_lm_loss_decreases(name):
    """A few Adam-free SGD steps on a repetitive sequence reduce loss."""
    cfg = archs.smoke(name)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.tile(jnp.arange(8), (B, 4))        # periodic -> learnable
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda q: lm.loss_fn(q, cfg, batch)[0])(p)
        return l, jax.tree.map(lambda a, b: a - 0.5 * b, p, g)

    losses = []
    for _ in range(30):
        l, params = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.7, losses[::10]
