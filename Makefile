# Tier-1 verify + convenience targets.  PYTHONPATH=src is the only setup;
# `hypothesis` is optional (tests/conftest.py ships a deterministic shim).

PYTEST = PYTHONPATH=src python -m pytest

.PHONY: test test-fast test-serving test-mesh bench-engine bench-train \
	bench-decode bench-serve bench-spec bench-chaos bench-crash \
	bench-mesh bench-autotune bench-timed example-serve

test:            ## full tier-1 suite (what CI runs)
	$(PYTEST) -q

test-fast:       ## skip the heavy model-smoke / multi-device tier
	$(PYTEST) -q -m "not slow"

test-serving:    ## engine + scheduler + sampling + faults + kernel-scan tests only
	$(PYTEST) -q tests/test_serving.py tests/test_scheduler.py \
		tests/test_sampling.py tests/test_faults.py tests/test_scan.py

test-mesh:       ## mesh-sharded serving parity + distributed-context tests (8 virtual devices via conftest)
	$(PYTEST) -q tests/test_mesh_serving.py tests/test_distributed_context.py

bench-engine:    ## superstep-vs-v1 serving throughput sweep
	PYTHONPATH=src python -m benchmarks.engine_throughput

bench-train:     ## train-step tokens/s across scan strategies -> BENCH_train.json
	PYTHONPATH=src python -m benchmarks.train_throughput

bench-decode:    ## decode tokens/s per decode-block size K -> BENCH_decode.json
	PYTHONPATH=src python -m benchmarks.engine_throughput --decode

bench-serve:     ## mixed arrival-trace: per-phase vs superstep, prompt-chunk sweep -> BENCH_serve.json
	PYTHONPATH=src python -m benchmarks.engine_throughput --mixed \
		--prompt-chunks 1 4 16

bench-spec:      ## bench-serve + speculative (draft-length x chunk) sweep -> BENCH_serve.json
	PYTHONPATH=src python -m benchmarks.engine_throughput --speculative \
		--prompt-chunks 1 4 16 --draft-lens 2 4 8

bench-chaos:     ## chaos + overload replay: fault-rate sweep + bounded-queue shedding -> BENCH_serve.json "robustness"
	PYTHONPATH=src python -m benchmarks.engine_throughput --faults

bench-crash:     ## kill/restore bit-exactness + DP-shard failover -> BENCH_serve.json "robustness"
	PYTHONPATH=src python -m benchmarks.engine_throughput --crash

bench-mesh:      ## DP/TP mesh sweep (forces virtual CPU devices) -> BENCH_serve.json "mesh"
	PYTHONPATH=src python -m benchmarks.engine_throughput \
		--mesh-shapes 1x1 2x1 4x1 1x2 2x2

bench-autotune:  ## (block_dh, C, K) sweep per smoke config -> checked-in TUNE_<config>.json plans
	PYTHONPATH=src python -m benchmarks.autotune --arch mingru-lm
	PYTHONPATH=src python -m benchmarks.autotune --arch minlstm-lm

bench-timed:     ## block-fused vs cell-fused decode: wall-clock + tier-aware structural -> BENCH_serve.json "block_fused"
	PYTHONPATH=src python -m benchmarks.engine_throughput --timed

example-serve:   ## continuous-batching demo
	PYTHONPATH=src python examples/serve_batched.py
