"""Continuous-batching serving demo: multiple requests of different
lengths are right-padded into ONE batched prefill, sampled on-device, and
share one decode batch; RNN-state caches make each decode step O(1).  The
long prompt below exercises chunked prefill: it is consumed in fixed-size
chunks interleaved with the other requests' decode rounds.  With
``--decode-block K`` the engine decodes K tokens per host round-trip
(``lm.decode_many``'s on-device step/sample/EOS-mask loop), so the stats
line reports well under one host round-trip per generated token.

    PYTHONPATH=src python examples/serve_batched.py --decode-block 4
"""

import argparse
import time

import jax

from repro.configs import archs
from repro.data.lm_corpus import decode_bytes
from repro.models import lm
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode-block", type=int, default=4,
                    help="tokens decoded per host round-trip (K)")
    args = ap.parse_args(argv)

    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=4, max_len=256,
                           prefill_chunk=16,
                           decode_block=args.decode_block)

    prompts = [b"To be, or not to be", b"Now is the winter",
               b"Friends, Romans, countrymen", b"All the world's a stage",
               b"If music be the food of love", b"Once more unto the breach",
               b"O for a Muse of fire, that would ascend the brightest "
               b"heaven of invention"]        # long: chunked prefill
    for i, p in enumerate(prompts):           # 7 requests, 4 slots: queueing
        # mix of greedy and sampled requests in the same decode batch
        engine.submit(list(p), max_new=16,
                      temperature=0.0 if i % 2 == 0 else 0.8,
                      top_k=0 if i % 2 == 0 else 40, top_p=0.95)

    t0 = time.time()
    outs = engine.run_to_completion()
    dt = time.time() - t0
    for rid in sorted(outs):
        print(f"req {rid}: {decode_bytes(outs[rid])!r}")
    n = sum(len(o) for o in outs.values())
    print(f"{len(outs)} requests, {n} tokens, {n / dt:.1f} tok/s")
    snap = engine.stats.snapshot()
    print(f"prefill calls: {snap['prefill_calls']}, "
          f"prefill tokens: {snap['prefill_tokens']} "
          f"(padding x{snap['padding_overhead']:.2f}), "
          f"decode steps: {snap['decode_steps']} in "
          f"{snap['decode_calls']} host round-trips "
          f"(K={args.decode_block}, "
          f"{snap['host_roundtrips_per_decode_token']:.2f} "
          f"round-trips/token), "
          f"queue peak: {snap['queue_peak']}")


if __name__ == "__main__":
    main()
