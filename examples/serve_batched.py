"""Continuous-batching serving demo: multiple requests of different
lengths share one decode batch; RNN-state caches make each step O(1).

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax

from repro.configs import archs
from repro.data.lm_corpus import decode_bytes
from repro.models import lm
from repro.serving.engine import ServingEngine


def main():
    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=4, max_len=256)

    prompts = [b"To be, or not to be", b"Now is the winter",
               b"Friends, Romans, countrymen", b"All the world's a stage",
               b"If music be the food of love", b"Once more unto the breach"]
    for p in prompts:                       # 6 requests, 4 slots: queueing
        engine.submit(list(p), max_new=16)

    t0 = time.time()
    outs = engine.run_to_completion()
    dt = time.time() - t0
    for rid in sorted(outs):
        print(f"req {rid}: {decode_bytes(outs[rid])!r}")
    n = sum(len(o) for o in outs.values())
    print(f"{len(outs)} requests, {n} tokens, {n / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
