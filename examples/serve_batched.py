"""Continuous-batching serving demo: the engine superstep.

Multiple requests of different lengths share one fixed-capacity device
batch.  Everything -- prompt consumption (teacher-forced prefill),
decode, sampling, EOS retirement and re-admission from per-slot staging
buffers -- happens inside ONE jitted device loop (``lm.superstep``) of
``--decode-block K`` rounds per host round-trip.  A long prompt simply
occupies one row while every other row keeps decoding: there is no
prefill phase and no barrier, and a slot that finishes mid-superstep is
re-armed from staging on the next device round (watch
``wasted_slot_steps`` stay near zero while the queue is non-empty).

    PYTHONPATH=src python examples/serve_batched.py --decode-block 4

``--trace N`` replays a synthetic N-request arrival trace instead of the
fixed prompt list: requests are submitted mid-flight (by device-round
arrival times) and per-request TTFT / inter-token latency is reported --
the continuous-admission regime the superstep engine is built for.

    PYTHONPATH=src python examples/serve_batched.py --trace 12

``--chaos`` arms the deterministic fault injector on top of either mode:
NaN state corruption, dropped staging uploads and straggler rounds are
injected at a seeded rate, the non-finite health guard quarantines and
retries poisoned requests, and the lifecycle summary shows every request
still reaching a terminal status.

    PYTHONPATH=src python examples/serve_batched.py --trace 12 --chaos

``--snapshot-dir DIR`` arms crash recovery: every submit/cancel/step is
write-ahead journaled and the full engine state is snapshotted every
``--snapshot-every`` rounds.  Kill the process mid-run, then
``--restore DIR`` rebuilds the engine from the latest snapshot, replays
the journal tail and drains the surviving requests to completion --
greedy streams are bit-identical to the uninterrupted run.

    PYTHONPATH=src python examples/serve_batched.py --trace 12 \\
        --snapshot-dir /tmp/serve_snap
    PYTHONPATH=src python examples/serve_batched.py --restore /tmp/serve_snap
"""

import argparse
import time

# must precede the jax/model imports: --mesh forces virtual CPU devices,
# and the device count is pinned the moment the backend initialises
from repro.distributed import devcount

devcount.force_host_devices_from_argv()

import jax
import numpy as np

from repro.configs import archs
from repro.data.lm_corpus import decode_bytes
from repro.distributed import serve_mesh
from repro.models import lm
from repro.serving.engine import ServingEngine, replay_trace
from repro.serving.faults import FaultInjector


def run_fixed(engine):
    prompts = [b"To be, or not to be", b"Now is the winter",
               b"Friends, Romans, countrymen", b"All the world's a stage",
               b"If music be the food of love", b"Once more unto the breach",
               b"O for a Muse of fire, that would ascend the brightest "
               b"heaven of invention"]        # long prompt: prefills in-loop
    for i, p in enumerate(prompts):           # 7 requests, 4 slots: queueing
        # mix of greedy and sampled requests in the same superstep batch
        engine.submit(list(p), max_new=16,
                      temperature=0.0 if i % 2 == 0 else 0.8,
                      top_k=0 if i % 2 == 0 else 40, top_p=0.95)
    t0 = time.time()
    outs = engine.run_to_completion()
    dt = time.time() - t0
    for rid in sorted(outs):
        print(f"req {rid}: {decode_bytes(outs[rid])!r}")
    return outs, dt


def run_trace(engine, n_requests, seed=0):
    """Replay a synthetic arrival trace: request i becomes visible once
    the engine has advanced past its arrival round, so admissions happen
    mid-flight (staged between supersteps, armed in-loop)."""
    rng = np.random.default_rng(seed)
    trace = [dict(arrival=int(rng.integers(0, 6 * n_requests)),
                  prompt=list(rng.integers(1, 250,
                                           size=int(rng.integers(3, 17)))),
                  max_new=int(rng.integers(8, 25)))
             for _ in range(n_requests)]
    trace.sort(key=lambda r: r["arrival"])
    t0 = time.time()
    replay_trace(engine, trace,
                 lambda i, r: engine.submit(r["prompt"],
                                            max_new=r["max_new"],
                                            temperature=0.8, top_k=40,
                                            top_p=0.95))
    dt = time.time() - t0
    for rid, req in sorted(engine.finished.items()):
        print(f"req {rid}: arrived@{req.submit_round} "
              f"ttft={req.first_round - req.submit_round + 1} rounds, "
              f"{len(req.out)} tokens")
    return {r: q.out for r, q in engine.finished.items()}, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode-block", type=int, default=None,
                    help="device rounds per host round-trip (K); default "
                         "4, or the --tune-file plan's K when one loads")
    ap.add_argument("--prompt-chunk", type=int, default=None,
                    help="prompt tokens a prefilling slot consumes per "
                         "device round (C): packed prefill streams the "
                         "weights once per C prompt tokens (default 1 = "
                         "unpacked, or the --tune-file plan's C)")
    ap.add_argument("--fuse-block", default="auto",
                    choices=["auto", "on", "off"],
                    help="whole-block decode megakernel "
                         "(kernels/block_step): one pallas_call per "
                         "layer per step; 'off' keeps the cell-only "
                         "kernel tier")
    ap.add_argument("--tune-file", default=None, metavar="PATH|auto",
                    help="autotune plan: a TUNE_<config>.json path "
                         "(shape-checked), or 'auto' for the discovery "
                         "order ($REPRO_TUNE_DIR, cwd, repo root); fills "
                         "block_dh and the K/C defaults")
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="replay a synthetic N-request arrival trace "
                         "instead of the fixed prompt list")
    ap.add_argument("--speculative", default=None, choices=["ngram"],
                    help="speculative decoding: n-gram self-drafts "
                         "verified in one chunk pass per round (streams "
                         "bit-identical; watch itl_rounds drop below 1)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="max draft tokens proposed per round (S)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject deterministic faults (NaN corruption, "
                         "dropped uploads, stragglers) and watch the "
                         "quarantine/retry layer keep every request "
                         "terminal")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="arm crash recovery: write-ahead journal + "
                         "periodic engine snapshots under DIR (starts a "
                         "NEW journal epoch, truncating any prior one)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="device rounds between snapshots (default 8)")
    ap.add_argument("--restore", default=None, metavar="DIR",
                    help="resume a crashed run from DIR: load the latest "
                         "good snapshot, replay the journal tail, then "
                         "drain the surviving requests (engine shape "
                         "comes from the journal header, not the CLI)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serving mesh, e.g. 2x1 (slot pool over 2 data "
                         "shards) or 2x2 (+ d_hidden over 2 model "
                         "shards); forces virtual CPU devices before jax "
                         "initialises")
    args = ap.parse_args(argv)
    if args.tune_file is None and args.decode_block is None:
        args.decode_block = 4           # the untuned demo default

    mesh_plan = serve_mesh.MeshPlan.parse(args.mesh)
    if mesh_plan is not None:
        serve_mesh.ensure_host_devices(mesh_plan.size)

    cfg = archs.smoke("mingru-lm")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    if args.restore:
        engine = ServingEngine.restore(args.restore, cfg, params)
        rep = engine.recovery_report
        print(f"restored from {args.restore}: snapshot "
              f"@{rep['snapshot_round']}, replayed "
              f"{rep['replayed_records']} journal records "
              f"({rep['replayed_rounds']} rounds) in "
              f"{rep['recovery_s']:.2f}s")
        t0 = time.time()
        outs = engine.run_to_completion()
        dt = time.time() - t0
        for rid in sorted(outs):
            print(f"req {rid}: {len(outs[rid])} tokens")
        args.chaos, mesh_plan = False, engine.mesh_plan
    else:
        faults = FaultInjector(seed=2, nan_rate=0.01, drop_rate=0.05,
                               straggler_rate=0.05, straggler_s=0.002) \
            if args.chaos else None
        engine = ServingEngine(cfg, params, max_batch=4, max_len=256,
                               decode_block=args.decode_block,
                               prompt_chunk=args.prompt_chunk,
                               speculative=args.speculative,
                               draft_len=args.draft_len,
                               faults=faults, max_retries=2,
                               mesh=mesh_plan,
                               fuse_block=args.fuse_block,
                               tune=args.tune_file,
                               recover_dir=args.snapshot_dir,
                               snapshot_every=args.snapshot_every)

    if not args.restore:
        if args.trace:
            outs, dt = run_trace(engine, args.trace)
        else:
            outs, dt = run_fixed(engine)
    n = sum(len(o) for o in outs.values())
    print(f"{len(outs)} requests, {n} tokens, {n / dt:.1f} tok/s")
    snap = engine.stats.snapshot()
    plan = engine.tune_plan
    print(f"kernel tier: {engine.kernel_tier} "
          f"(fuse_block={args.fuse_block}, "
          f"block_dh={engine.cfg.block_dh or 'default'}"
          + (f", plan {plan.get('source', '<dict>')}" if plan else "")
          + ")")
    print(f"prefill tokens (in-loop): {snap['prefill_tokens']} "
          f"over {snap['prefill_rounds']} rounds "
          f"(C={engine.prompt_chunk}), "
          f"decode rounds: {snap['decode_steps']} in "
          f"{snap['decode_calls']} host round-trips "
          f"(K={engine.decode_block}, "
          f"{snap['host_roundtrips_per_decode_token']:.2f} "
          f"round-trips/token), "
          f"wasted slot steps: {snap['wasted_slot_steps']} "
          f"({snap['wasted_slot_fraction']:.1%}), "
          f"queue peak: {snap['queue_peak']}")
    print(f"ttft mean: {snap['ttft_rounds_mean']:.1f} rounds "
          f"({snap['ttft_s_mean'] * 1e3:.1f}ms), "
          f"inter-token: {snap['itl_s_mean'] * 1e3:.1f}ms "
          f"({snap['itl_rounds_mean']:.2f} rounds/token)")
    if mesh_plan is not None:
        per = " | ".join(
            f"shard {i}: {s['decode_tokens']} tok, "
            f"{s['wasted_slot_steps']} wasted"
            for i, s in enumerate(snap["shards"]))
        print(f"mesh {mesh_plan}: {per} "
              f"(identities ok: {snap['shard_identities_ok']})")
    if args.chaos:
        print(f"chaos: injected {faults.counts()} -> "
              f"{snap['completed']}/{snap['submitted']} completed, "
              f"quarantined {snap['quarantined']}, "
              f"retried {snap['retried']}, failed {snap['failed']} "
              f"(every request terminal)")


if __name__ == "__main__":
    main()
