"""Paper Tables 1-2: the Selective Copying task (Gu & Dao 2024).

Trains minGRU / minLSTM classifying-LM models at 1/2/3 layers and prints
the layer-ablation accuracy table -- the paper's demonstration that
stacking restores the expressivity lost by dropping h_{t-1} from the gates.
CPU-scaled: seq 32, 4 data tokens, ~350 steps (paper: 4096/16/400k).

    PYTHONPATH=src python examples/selective_copy.py [--steps 300]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MinRNNConfig, ModelConfig
from repro.data import synthetic
from repro.models import lm
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib


def run(cell: str, n_layers: int, steps: int, seq_len: int = 32,
        seed: int = 0):
    cfg = ModelConfig(
        name=f"{cell}-{n_layers}l", block_kind="minrnn", n_layers=n_layers,
        d_model=64, d_ff=256, vocab_size=16, tie_embeddings=False,
        minrnn=MinRNNConfig(cell=cell, expansion=6.0, mode="log",
                            use_conv=False, use_mlp=False))
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=steps,
                               weight_decay=0.0)
    opt_state = opt_lib.init(ocfg, params)
    step = jax.jit(ts_lib.make_train_step(cfg, ocfg))
    for i in range(steps):
        batch = synthetic.selective_copy_batch(seed, i, 32, seq_len=seq_len,
                                               n_data=4)
        params, opt_state, metrics = step(params, opt_state, batch)
    # eval
    accs = []
    for i in range(8):
        batch = synthetic.selective_copy_batch(seed + 999, i, 32,
                                               seq_len=seq_len, n_data=4)
        logits, _ = lm.forward(params, cfg, jnp.asarray(batch["tokens"]))
        accs.append(synthetic.selective_copy_accuracy(
            np.asarray(logits), batch["labels"]))
    return float(np.mean(accs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=350)
    args = ap.parse_args()
    print(f"{'model':10s} {'layers':>6s} {'accuracy':>9s}")
    for cell in ("minlstm", "mingru"):
        for n_layers in (1, 2, 3):
            acc = run(cell, n_layers, args.steps)
            print(f"{cell:10s} {n_layers:6d} {acc:9.3f}")


if __name__ == "__main__":
    main()
