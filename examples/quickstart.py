"""Quickstart: the paper's minGRU in 40 lines.

Builds a minGRU language model, trains it briefly on embedded Shakespeare,
and generates text -- demonstrating the parallel-scan training mode and the
sequential decode mode side by side.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.data import lm_corpus
from repro.models import lm
from repro.serving.engine import generate_one
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib


def main():
    cfg = archs.smoke("mingru-lm")           # 3-layer minGRU LM (paper arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    ocfg = opt_lib.AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=100)
    opt_state = opt_lib.init(ocfg, params)
    step = jax.jit(ts_lib.make_train_step(cfg, ocfg))

    train, _ = lm_corpus.build_corpus()
    for i in range(100):
        batch = lm_corpus.lm_batch(train, seed=0, step=i, batch=8,
                                   seq_len=128)
        params, opt_state, metrics = step(params, opt_state, batch)
        if (i + 1) % 20 == 0:
            print(f"step {i + 1}: loss {float(metrics['loss']):.3f}")

    prompt = list(b"To be, or ")
    out = generate_one(cfg, params, prompt, max_new=48, max_len=256)
    print("prompt:    ", bytes(prompt).decode())
    print("generated: ", lm_corpus.decode_bytes(out))


if __name__ == "__main__":
    main()
