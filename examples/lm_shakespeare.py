"""End-to-end driver (paper Fig. 2 setting): train the ~100M-parameter
minGRU LM on the embedded Shakespeare corpus with the full production
stack -- AdamW + cosine schedule, checkpointing, fault-tolerant supervisor
-- then serve batched completions from the trained weights.

Full run (paper scale, needs accelerators):
    PYTHONPATH=src python examples/lm_shakespeare.py --steps 600 --batch 64

CPU demo (default): a handful of steps of the full 100M model.

    PYTHONPATH=src python examples/lm_shakespeare.py
"""

import argparse

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model instead of the 100M config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_shakespeare")
    args = ap.parse_args()

    train_args = ["--arch", "mingru-lm", "--task", "lm",
                  "--steps", str(args.steps), "--batch", str(args.batch),
                  "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir,
                  "--ckpt-every", "100"]
    if args.smoke:
        train_args.append("--smoke")
    train_cli.main(train_args)

    serve_args = ["--arch", "mingru-lm", "--ckpt-dir", args.ckpt_dir,
                  "--max-new", "24",
                  "--prompts", "To be, or not", "Friends, Romans"]
    if args.smoke:
        serve_args.append("--smoke")
    serve_cli.main(serve_args)


if __name__ == "__main__":
    main()
