"""Decoder-only LM assembly for the whole architecture zoo.

One model builder covers: dense GQA transformers (starcoder2, gemma,
deepseek-67b, pixtral backbone), MLA+MoE (deepseek-v3), fine-grained MoE
(deepseek-moe), SSD (mamba2), hybrid SSD+shared-attention (zamba2), and the
paper's own minGRU/minLSTM LMs.  ``cfg.seq_mixer`` swaps any attention
mixer for the paper's minRNN (DESIGN.md §5).

Layers run under ``lax.scan`` over stacked parameters (cfg.scan_layers) so
HLO size -- and dry-run compile time -- is O(1) in depth.  Every block kind
provides a parallel form (train / prefill, returning per-layer caches) and
a step form (decode, carrying caches).
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import blocks as minrnn_blocks
from repro.core import min_gru, min_lstm, nn
from repro.distributed.act_sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.models.mlp import mlp_apply, mlp_init

Array = jax.Array

_MIN_CELLS = {"mingru": min_gru, "minlstm": min_lstm}


# ===========================================================================
# Parameter init
# ===========================================================================

def init_params(key, cfg) -> Dict[str, Any]:
    dtype = cfg.pdtype
    k_embed, k_layers, k_out, k_front = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": {"table": nn.normal_init(
            k_embed, (cfg.padded_vocab, cfg.d_model), 0.02, dtype)},
        "final_norm": nn.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = nn.dense_init(k_out, cfg.d_model,
                                          cfg.padded_vocab,
                                          use_bias=False, dtype=dtype)
    if cfg.frontend == "patches":
        params["patch_proj"] = nn.dense_init(
            k_front, cfg.frontend_dim, cfg.d_model, use_bias=False,
            dtype=dtype)
    params["layers"] = _init_trunk(k_layers, cfg, dtype)
    return params


def _stack_init(init_one, keys):
    return jax.vmap(init_one)(keys)


def _init_trunk(key, cfg, dtype):
    if cfg.block_kind == "hybrid":
        return _init_hybrid(key, cfg, dtype)
    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)
        keys = jax.random.split(key, cfg.n_layers)
        return {"blocks": _stack_init(
            lambda k: minrnn_blocks.init(k, bc, dtype=dtype), keys)}
    if cfg.block_kind == "ssm":
        keys = jax.random.split(key, cfg.n_layers)
        return {"blocks": _stack_init(
            lambda k: _ssm_layer_init(k, cfg, dtype), keys)}
    # attention trunk, possibly with a leading dense segment before MoE
    n_dense_first = cfg.moe.first_dense_layers if cfg.moe else 0
    n_main = cfg.n_layers - n_dense_first
    out = {}
    if n_dense_first:
        keys = jax.random.split(jax.random.fold_in(key, 1), n_dense_first)
        out["dense_blocks"] = _stack_init(
            lambda k: _attn_layer_init(k, cfg, dtype, force_dense=True), keys)
    keys = jax.random.split(jax.random.fold_in(key, 2), n_main)
    out["blocks"] = _stack_init(
        lambda k: _attn_layer_init(k, cfg, dtype), keys)
    return out


def _minrnn_block_cfg(cfg):
    mr = cfg.minrnn
    return minrnn_blocks.MinRNNBlockConfig(
        d_model=cfg.d_model, cell=mr.cell, expansion=mr.expansion,
        use_conv=mr.use_conv, conv_kernel=mr.conv_kernel,
        use_mlp=mr.use_mlp, mlp_factor=cfg.d_ff / cfg.d_model,
        mode=mr.mode, norm=cfg.norm, scan_strategy=cfg.scan_strategy)


def _mixer_init(key, cfg, dtype):
    """The sequence mixer of an attention-style block."""
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        exp = cfg.minrnn.expansion if cfg.minrnn else 1.0
        dh = int(cfg.d_model * exp)
        k1, k2 = jax.random.split(key)
        return {"rnn": cell.init(k1, cfg.d_model, dh, dtype=dtype),
                "down": nn.dense_init(k2, dh, cfg.d_model, use_bias=False,
                                      dtype=dtype)}
    if cfg.attn_kind == "mla":
        return attn.mla_init(key, cfg, dtype=dtype)
    return attn.gqa_init(key, cfg, dtype=dtype)


def _attn_layer_init(key, cfg, dtype, force_dense: bool = False):
    ks = jax.random.split(key, 3)
    p = {
        "norm1": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "mixer": _mixer_init(ks[0], cfg, dtype),
        "norm2": nn.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.moe and not force_dense:
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype=dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                            gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                            dtype=dtype)
    return p


def _ssm_layer_init(key, cfg, dtype):
    return {
        "norm": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "mixer": ssd_lib.ssd_init(key, cfg, dtype=dtype),
    }


def _init_hybrid(key, cfg, dtype):
    """zamba2: n_layers SSD blocks + ONE shared attention block applied
    every ``hybrid_attn_every`` layers (params shared, activations not)."""
    k1, k2 = jax.random.split(key)
    keys = jax.random.split(k1, cfg.n_layers)
    return {
        "blocks": _stack_init(lambda k: _ssm_layer_init(k, cfg, dtype), keys),
        "shared_attn": _attn_layer_init(k2, cfg, dtype, force_dense=True),
    }


# ===========================================================================
# Block bodies (parallel form)
# ===========================================================================

def _remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _mixer_apply(p, cfg, x, positions):
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        mode = cfg.minrnn.mode if cfg.minrnn else "log"
        h = cell.parallel(p["rnn"], x, mode=mode, compute_dtype=cfg.cdtype,
                          scan_strategy=cfg.scan_strategy)
        return nn.dense_apply(p["down"], h, cfg.cdtype)
    if cfg.attn_kind == "mla":
        return attn.mla_apply(p, cfg, x, positions=positions, causal=True)
    return attn.gqa_apply(p, cfg, x, positions=positions, causal=True)


def _attn_block_apply(p, cfg, x, positions, *, has_moe):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm1"], x, **nk)
    x = x + _mixer_apply(p["mixer"], cfg, y, positions)
    y = nn.norm_apply(cfg.norm, p["norm2"], x, **nk)
    if has_moe:
        out, aux = moe_lib.moe_apply(p["moe"], cfg, y,
                                     activation=cfg.mlp_activation)
        return x + out, aux
    out = mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                    compute_dtype=cfg.cdtype)
    return x + out, jnp.zeros((), jnp.float32)


def _ssm_block_apply(p, cfg, x):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm"], x, **nk)
    return x + ssd_lib.ssd_block_apply(p["mixer"], cfg, y)


# ===========================================================================
# Trunk (parallel): scan over stacked layer params
# ===========================================================================

def _trunk_apply(params, cfg, x, positions) -> Tuple[Array, Array]:
    """Returns (x, aux_loss_sum)."""
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)

        def body(carry, p_l):
            h = minrnn_blocks.apply(p_l, bc, carry, compute_dtype=cfg.cdtype,
                                    scan_strategy=cfg.scan_strategy)
            return h, None

        x, _ = _scan_layers(cfg, body, x, params["layers"]["blocks"])
        return x, aux_total

    if cfg.block_kind == "ssm":
        def body(carry, p_l):
            return _ssm_block_apply(p_l, cfg, carry), None

        x, _ = _scan_layers(cfg, body, x, params["layers"]["blocks"])
        return x, aux_total

    if cfg.block_kind == "hybrid":
        return _hybrid_apply(params, cfg, x, positions), aux_total

    # attention trunk
    layers = params["layers"]
    if "dense_blocks" in layers:
        def body_d(carry, p_l):
            h, _ = _attn_block_apply(p_l, cfg, carry, positions,
                                     has_moe=False)
            return h, None

        x, _ = _scan_layers(cfg, body_d, x, layers["dense_blocks"])

    has_moe = cfg.moe is not None

    def body(carry, p_l):
        h, aux = _attn_block_apply(p_l, cfg, carry, positions,
                                   has_moe=has_moe)
        return h, aux

    x, auxs = _scan_layers(cfg, body, x, layers["blocks"])
    if auxs is not None:
        aux_total = aux_total + jnp.sum(auxs)
    return x, aux_total


def _iterate(cfg, body, x, scanned):
    """lax.scan over stacked leaves, or an unrolled python loop when
    cfg.scan_layers=False (the dry-run uses unrolled so cost_analysis
    counts every layer -- XLA tallies a while-loop body only once)."""
    if cfg.scan_layers:
        return lax.scan(body, x, scanned)
    n = jax.tree.leaves(scanned)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], scanned)
        x, y = body(x, sl)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return x, ys


def _scan_layers(cfg, body, x, stacked):
    return _iterate(cfg, _remat(cfg, body), x, stacked)


def _hybrid_apply(params, cfg, x, positions):
    """zamba2 trunk: scan over groups of (every k SSD layers + shared attn)."""
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    blocks = params["layers"]["blocks"]
    shared = params["layers"]["shared_attn"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), blocks)

    def group_body(carry, p_group):
        def inner(c, p_l):
            return _ssm_block_apply(p_l, cfg, c), None

        h, _ = _iterate(cfg, inner, carry, p_group)
        h, _ = _attn_block_apply(shared, cfg, h, positions, has_moe=False)
        return h, None

    x, _ = _iterate(cfg, _remat(cfg, group_body), x, grouped)
    return x


# ===========================================================================
# Embedding / logits / forward / loss
# ===========================================================================

def _embed(params, cfg, tokens, patch_embeds=None):
    x = params["embed"]["table"].astype(cfg.cdtype)[tokens]
    x = constrain(x, "dp", None, None)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
    if cfg.frontend == "patches" and patch_embeds is not None:
        pe = nn.dense_apply(params["patch_proj"], patch_embeds, cfg.cdtype)
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        table = params["embed"]["table"].astype(cfg.cdtype)
        logits = x @ table.T
    else:
        logits = nn.dense_apply(params["unembed"], x, cfg.cdtype)
    if cfg.logits_softcap:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    if cfg.padded_vocab != cfg.vocab_size:   # mask the pad columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def forward(params, cfg, tokens: Array, *, patch_embeds: Optional[Array] = None
            ) -> Tuple[Array, Array]:
    """tokens: (B, S) -> (logits (B, S*, V), aux_loss).  S* includes any
    frontend prefix tokens."""
    x = _embed(params, cfg, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = _trunk_apply(params, cfg, x, positions)
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x = nn.norm_apply(cfg.norm, params["final_norm"], x, **nk)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
    """batch: tokens (B, S), labels (B, S) with -1 = ignore, optional
    patch_embeds."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    logits, aux = forward(params, cfg, tokens,
                          patch_embeds=batch.get("patch_embeds"))
    if logits.shape[1] != labels.shape[1]:      # frontend prefix: drop it
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logits = constrain(logits.astype(jnp.float32), "dp", None, "tp")
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via one-hot contraction: shards cleanly over a
    # vocab-parallel logits tensor (take_along_axis would all-gather it)
    col = jnp.arange(logits.shape[-1])
    gold = jnp.sum(jnp.where(col == safe_labels[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    metrics = {"nll": loss, "ntokens": jnp.sum(mask)}
    if cfg.z_loss:
        zl = cfg.z_loss * jnp.sum((logz ** 2) * mask) / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_weight * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = loss
    return loss, metrics


# ===========================================================================
# Decode: cache init / prefill / step
# ===========================================================================

def init_cache(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked per-layer caches + shared position counter."""
    dt = cfg.cdtype
    cache: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    L = cfg.n_layers

    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)
        cache["h"] = jnp.zeros((L, batch, bc.d_hidden), dt)
        if bc.use_conv:
            cache["conv"] = jnp.zeros(
                (L, batch, bc.conv_kernel - 1, cfg.d_model), dt)
        return cache

    if cfg.block_kind == "ssm":
        s = cfg.ssm
        cache["conv"] = jnp.zeros(
            (L, batch, s.conv_kernel - 1,
             s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state), dt)
        cache["ssm"] = jnp.zeros(
            (L, batch, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
            jnp.float32)
        return cache

    if cfg.block_kind == "hybrid":
        s = cfg.ssm
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        cache["conv"] = jnp.zeros(
            (L, batch, s.conv_kernel - 1,
             s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state), dt)
        cache["ssm"] = jnp.zeros(
            (L, batch, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
            jnp.float32)
        cache["k"] = jnp.zeros(
            (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), dt)
        cache["v"] = jnp.zeros_like(cache["k"])
        return cache

    # attention trunk
    if cfg.seq_mixer in _MIN_CELLS:
        exp = cfg.minrnn.expansion if cfg.minrnn else 1.0
        cache["h"] = jnp.zeros((L, batch, int(cfg.d_model * exp)), dt)
    elif cfg.attn_kind == "mla":
        cache["ckv"] = jnp.zeros((L, batch, max_len, cfg.mla_kv_lora), dt)
        cache["krope"] = jnp.zeros((L, batch, max_len, cfg.mla_rope_dim), dt)
    else:
        cache["k"] = jnp.zeros(
            (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), dt)
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def decode_step(params, cfg, token: Array, cache: Dict[str, Any]
                ) -> Tuple[Array, Dict[str, Any]]:
    """token: (B,) -> (logits (B, V), new cache).  One step for every arch.

    Every trunk kind dispatches its layer stack as ONE ``lax.scan`` over
    stacked weights (``_iterate``), so the per-step HLO is O(1) in depth;
    the minRNN step body additionally runs its cell in the fused Pallas
    decode kernel under the default ``scan_strategy="auto"`` (see
    ``_minrnn_decode``).  ``decode_many`` wraps this step in a second
    on-device scan to decode K tokens per host call.
    """
    pos = cache["pos"]
    x = params["embed"]["table"].astype(cfg.cdtype)[token]
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)

    new_cache = dict(cache)

    if cfg.block_kind == "minrnn":
        x, outs = _minrnn_decode(params, cfg, x, cache)
        new_cache.update(outs)

    elif cfg.block_kind == "ssm":
        def body(carry, scanned):
            p_l, cache_l = scanned
            y = nn.norm_apply(cfg.norm, p_l["norm"], carry)
            out, state = ssd_lib.ssd_block_step(
                p_l["mixer"], cfg, y,
                {"conv": cache_l["conv"], "ssm": cache_l["ssm"]})
            return carry + out, state

        scanned = {"conv": cache["conv"], "ssm": cache["ssm"]}
        x, outs = _iterate(cfg, body, x,
                           (params["layers"]["blocks"], scanned))
        new_cache.update(outs)

    elif cfg.block_kind == "hybrid":
        x, outs = _hybrid_decode(params, cfg, x, cache)
        new_cache.update(outs)

    else:
        x, outs = _attn_decode(params, cfg, x, cache)
        new_cache.update(outs)

    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x = nn.norm_apply(cfg.norm, params["final_norm"], x, **nk)
    logits = _logits(params, cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _minrnn_decode(params, cfg, x, cache):
    """minRNN trunk single-token step: one stacked-weight ``lax.scan``
    whose body is ``blocks.step`` -- the cell GEMVs + gates + state update
    run in the fused Pallas decode kernel when ``cfg.scan_strategy``
    resolves to ``"fused"`` (the ``"auto"`` default)."""
    bc = _minrnn_block_cfg(cfg)

    def body(carry, scanned):
        p_l, cache_l = scanned
        state = {"h": cache_l["h"]}
        if bc.use_conv:
            state["conv"] = cache_l["conv"]
        y, state = minrnn_blocks.step(p_l, bc, carry, state,
                                      compute_dtype=cfg.cdtype)
        out_c = {"h": state["h"]}
        if bc.use_conv:
            out_c["conv"] = state["conv"]
        return y, out_c

    scanned = {"h": cache["h"]}
    if bc.use_conv:
        scanned["conv"] = cache["conv"]
    return _iterate(cfg, body, x, (params["layers"]["blocks"], scanned))


def decode_many(params, cfg, tok: Array, cache: Dict[str, Any], n: int,
                controls: Dict[str, Array]):
    """Decode ``n`` tokens per host round-trip, entirely on device.

    One ``lax.scan`` carries (token, cache, PRNG keys, liveness) through
    ``n`` iterations of step -> sample -> EOS/length-mask; the host sees
    only the final ``(B, n)`` token buffer instead of one transfer per
    token.  ``n`` must be static (the engine jits one program per block
    size).

    tok: (B,) int32 -- each slot's last sampled token.
    controls: device-side per-slot control state,
      ``temperature`` (B,) f32 / ``top_k`` (B,) i32 / ``top_p`` (B,) f32
          -- sampling controls (see serving.sampling);
      ``keys`` (B, 2) uint32 -- per-slot PRNG keys;
      ``eos`` (B,) i32 -- stop token, -1 = none;
      ``alive`` (B,) bool -- slots that should emit tokens;
      ``remaining`` (B,) i32 -- tokens each slot may still emit (length
          cap), so max_new enforcement never needs a host round-trip.

    Returns ``(tokens, new_cache, state)``: ``tokens`` is (B, n) int32
    with -1 marking positions after a slot went dead; ``state`` carries
    the advanced ``keys`` / ``alive`` / ``remaining`` and ``tok`` (each
    slot's final sampled token, the next call's input).

    Dead and never-admitted slots still *compute* (their rows keep
    stepping so the batch stays dense -- every cache row is independent,
    and admission prefill overwrites a freed row wholesale before it is
    read again) but emit -1 and keep their last token.  Keys advance for
    every slot every iteration, exactly like the per-step
    ``sampling.sample_tokens`` host loop this replaces, so K=1 streams
    are bit-identical to the old one-token ``engine.step()``.
    """
    # lazy import: models/ stays importable without the serving package
    # in minimal deployments; sampling itself only depends on jax
    from repro.serving import sampling

    eos = controls["eos"]

    def body(carry, _):
        tok, cache, keys, alive, remaining = carry
        logits, cache = decode_step(params, cfg, tok, cache)
        toks, keys = sampling.sample_tokens(
            logits, keys, controls["temperature"], controls["top_k"],
            controls["top_p"])
        emit = jnp.where(alive, toks, jnp.int32(-1))
        remaining = remaining - alive.astype(jnp.int32)
        hit_eos = (eos >= 0) & (toks == eos)
        alive = alive & jnp.logical_not(hit_eos) & (remaining > 0)
        tok = jnp.where(emit >= 0, toks, tok)
        return (tok, cache, keys, alive, remaining), emit

    carry0 = (tok.astype(jnp.int32), cache, controls["keys"],
              controls["alive"], controls["remaining"].astype(jnp.int32))
    (tok, cache, keys, alive, remaining), emitted = lax.scan(
        body, carry0, None, length=n)
    state = {"tok": tok, "keys": keys, "alive": alive,
             "remaining": remaining}
    return jnp.swapaxes(emitted, 0, 1), cache, state


def _attn_mixer_step(p, cfg, y, cache_l, pos):
    """Single-token mixer with cache. Returns (out, new mixer cache dict)."""
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        mode = cfg.minrnn.mode if cfg.minrnn else "log"
        h = cell.step(p["rnn"], y, cache_l["h"], mode=mode,
                      compute_dtype=cfg.cdtype,
                      scan_strategy=cfg.scan_strategy)
        return nn.dense_apply(p["down"], h, cfg.cdtype), {"h": h}
    if cfg.attn_kind == "mla":
        out, ckv, krope = attn.mla_decode_step(p, cfg, y, cache_l["ckv"],
                                               cache_l["krope"], pos)
        return out, {"ckv": ckv, "krope": krope}
    out, k, v = attn.gqa_decode_step(p, cfg, y, cache_l["k"], cache_l["v"],
                                     pos)
    return out, {"k": k, "v": v}


def _attn_block_step(p, cfg, x, cache_l, pos, *, has_moe):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm1"], x, **nk)
    out, mix_cache = _attn_mixer_step(p["mixer"], cfg, y, cache_l, pos)
    x = x + out
    y = nn.norm_apply(cfg.norm, p["norm2"], x, **nk)
    if has_moe:
        out, _ = moe_lib.moe_apply(p["moe"], cfg, y[:, None, :],
                                   activation=cfg.mlp_activation)
        out = out[:, 0]
    else:
        out = mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                        compute_dtype=cfg.cdtype)
    return x + out, mix_cache


def _attn_decode(params, cfg, x, cache):
    pos = cache["pos"]
    layers = params["layers"]
    mixer_keys = [k for k in ("h", "ckv", "krope", "k", "v") if k in cache]

    n_dense = 0
    if "dense_blocks" in layers:
        n_dense = jax.tree.leaves(layers["dense_blocks"])[0].shape[0]

        def body_d(carry, scanned):
            p_l, cache_l = scanned
            y, mc = _attn_block_step(p_l, cfg, carry, cache_l, pos,
                                     has_moe=False)
            return y, mc

        sub = {k: cache[k][:n_dense] for k in mixer_keys}
        x, outs_d = _iterate(cfg, body_d, x, (layers["dense_blocks"], sub))
    has_moe = cfg.moe is not None

    def body(carry, scanned):
        p_l, cache_l = scanned
        y, mc = _attn_block_step(p_l, cfg, carry, cache_l, pos,
                                 has_moe=has_moe)
        return y, mc

    sub = {k: cache[k][n_dense:] for k in mixer_keys}
    x, outs = _iterate(cfg, body, x, (layers["blocks"], sub))
    if n_dense:
        outs = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                            outs_d, outs)
    return x, outs


def _hybrid_decode(params, cfg, x, cache):
    pos = cache["pos"]
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    blocks = params["layers"]["blocks"]
    shared = params["layers"]["shared_attn"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), blocks)
    g_conv = cache["conv"].reshape((n_groups, every) + cache["conv"].shape[1:])
    g_ssm = cache["ssm"].reshape((n_groups, every) + cache["ssm"].shape[1:])

    def group_body(carry, scanned):
        p_group, conv_g, ssm_g, k_g, v_g = scanned

        def inner(c, s):
            p_l, conv_l, ssm_l = s
            y = nn.norm_apply(cfg.norm, p_l["norm"], c)
            out, state = ssd_lib.ssd_block_step(
                p_l["mixer"], cfg, y, {"conv": conv_l, "ssm": ssm_l})
            return c + out, (state["conv"], state["ssm"])

        h, (conv_new, ssm_new) = _iterate(cfg, inner, carry,
                                          (p_group, conv_g, ssm_g))
        h, mc = _attn_block_step(shared, cfg, h, {"k": k_g, "v": v_g}, pos,
                                 has_moe=False)
        return h, (conv_new, ssm_new, mc["k"], mc["v"])

    x, (conv_new, ssm_new, k_new, v_new) = _iterate(
        cfg, group_body, x,
        (grouped, g_conv, g_ssm, cache["k"], cache["v"]))
    return x, {
        "conv": conv_new.reshape(cache["conv"].shape),
        "ssm": ssm_new.reshape(cache["ssm"].shape),
        "k": k_new, "v": v_new,
    }


# ===========================================================================
# Prefill: parallel pass over the prompt that seeds the decode caches
# ===========================================================================

def _attn_block_prefill(p, cfg, x, positions, *, has_moe, lengths=None):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm1"], x, **nk)
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        mode = cfg.minrnn.mode if cfg.minrnn else "log"
        h = cell.parallel(p["mixer"]["rnn"], y, mode=mode,
                          compute_dtype=cfg.cdtype,
                          scan_strategy=cfg.scan_strategy)
        out = nn.dense_apply(p["mixer"]["down"], h, cfg.cdtype)
        mix_cache = {"h": h[:, -1] if lengths is None
                     else nn.gather_last(h, lengths)}
    elif cfg.attn_kind == "mla":
        out, ckv, krope = attn.mla_prefill(p["mixer"], cfg, y,
                                           positions=positions)
        mix_cache = {"ckv": ckv, "krope": krope}
    else:
        out, k, v = attn.gqa_prefill(p["mixer"], cfg, y, positions=positions)
        mix_cache = {"k": k, "v": v}
    x = x + out
    y = nn.norm_apply(cfg.norm, p["norm2"], x, **nk)
    if has_moe:
        out, _ = moe_lib.moe_apply(p["moe"], cfg, y,
                                   activation=cfg.mlp_activation)
    else:
        out = mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                        compute_dtype=cfg.cdtype)
    return x + out, mix_cache


def _seed_kv(full, max_len):
    """(L, B, T, ...) prompt kv -> (L, B, max_len, ...) zero-padded cache."""
    t = full.shape[2]
    pad = [(0, 0)] * full.ndim
    pad[2] = (0, max_len - t)
    return jnp.pad(full, pad)


def supports_chunked_prefill(cfg) -> bool:
    """True when ``prefill`` can resume from a carried cache, i.e. the whole
    decode state is a constant-size recurrence (the paper's minRNN family).
    KV/SSD caches would need offset-aware attention / state-resumed chunk
    scans; those archs prefill whole-prompt instead."""
    return cfg.block_kind == "minrnn"


def prefill(params, cfg, tokens: Array, max_len: int, *,
            patch_embeds: Optional[Array] = None,
            lengths: Optional[Array] = None,
            cache: Optional[Dict[str, Any]] = None
            ) -> Tuple[Array, Dict[str, Any]]:
    """Parallel prompt processing.  Returns (last-token logits (B, V), cache
    ready for decode_step).  This is the paper's headline win: the prompt is
    one parallel scan, not T sequential cell evaluations.

    ``lengths`` (B,) int32 enables *batched* prefill of right-padded
    variable-length prompts: row b's logits/state are taken at its true
    terminal position ``lengths[b]-1``.  Every mixer is causal, so positions
    before the pad are bit-identical to an unpadded run; recurrent states
    are gathered per-row (SSD additionally masks dt so padded steps are
    inert), while KV caches may hold garbage beyond ``lengths[b]`` -- decode
    masks attention by the per-slot ``pos`` and overwrites those positions
    in place before they ever become visible.

    ``cache`` resumes prefill from a previous prefill's cache (chunked
    prefill); only supported for ``supports_chunked_prefill`` configs.
    """
    if cache is not None and not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"chunked prefill resume not supported for block_kind="
            f"{cfg.block_kind!r}")
    if lengths is not None and cfg.frontend == "patches":
        raise NotImplementedError("variable-length prefill with a patch "
                                  "frontend prefix is not supported")
    x = _embed(params, cfg, tokens, patch_embeds)
    bsz, t = x.shape[0], x.shape[1]
    positions = jnp.arange(t)[None, :]
    consumed = jnp.full((bsz,), t, jnp.int32) if lengths is None \
        else lengths.astype(jnp.int32)
    pos0 = cache["pos"] if cache is not None else 0
    new_cache: Dict[str, Any] = {"pos": pos0 + consumed}

    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)

        if cache is not None:
            state0 = {"h": cache["h"]}
            if bc.use_conv:
                state0["conv"] = cache["conv"]

            def body_r(carry, scanned):
                p_l, st_l = scanned
                h, state = minrnn_blocks.apply(p_l, bc, carry, state0=st_l,
                                               lengths=lengths,
                                               compute_dtype=cfg.cdtype,
                                               scan_strategy=cfg.scan_strategy,
                                               return_state=True)
                return h, state

            x, states = _scan_layers(cfg, body_r, x,
                                     (params["layers"]["blocks"], state0))
        else:
            def body(carry, p_l):
                h, state = minrnn_blocks.apply(p_l, bc, carry,
                                               lengths=lengths,
                                               compute_dtype=cfg.cdtype,
                                               scan_strategy=cfg.scan_strategy,
                                               return_state=True)
                return h, state

            x, states = _scan_layers(cfg, body, x,
                                     params["layers"]["blocks"])
        new_cache["h"] = states["h"]
        if bc.use_conv:
            new_cache["conv"] = states["conv"]

    elif cfg.block_kind == "ssm":
        def body(carry, p_l):
            nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
            y = nn.norm_apply(cfg.norm, p_l["norm"], carry, **nk)
            out, state = ssd_lib.ssd_block_apply(p_l["mixer"], cfg, y,
                                                 return_state=True,
                                                 lengths=lengths)
            return carry + out, state

        x, states = _scan_layers(cfg, body, x, params["layers"]["blocks"])
        new_cache["conv"] = states["conv"]
        new_cache["ssm"] = states["ssm"]

    elif cfg.block_kind == "hybrid":
        x, cache_h = _hybrid_prefill(params, cfg, x, positions, max_len,
                                     lengths=lengths)
        new_cache.update(cache_h)

    else:
        layers = params["layers"]
        has_moe = cfg.moe is not None
        mix_caches = []

        if "dense_blocks" in layers:
            def body_d(carry, p_l):
                return _attn_block_prefill(p_l, cfg, carry, positions,
                                           has_moe=False, lengths=lengths)

            x, mc_d = _scan_layers(cfg, body_d, x, layers["dense_blocks"])
            mix_caches.append(mc_d)

        def body(carry, p_l):
            return _attn_block_prefill(p_l, cfg, carry, positions,
                                       has_moe=has_moe, lengths=lengths)

        x, mc = _scan_layers(cfg, body, x, layers["blocks"])
        mix_caches.append(mc)
        if len(mix_caches) == 2:
            mc = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                              mix_caches[0], mix_caches[1])
        else:
            mc = mix_caches[0]
        if "h" in mc:
            new_cache["h"] = mc["h"]
        elif "ckv" in mc:
            new_cache["ckv"] = _seed_kv(mc["ckv"], max_len)
            new_cache["krope"] = _seed_kv(mc["krope"], max_len)
        else:
            new_cache["k"] = _seed_kv(mc["k"], max_len)
            new_cache["v"] = _seed_kv(mc["v"], max_len)

    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x_last = x[:, -1] if lengths is None else nn.gather_last(x, lengths)
    x_last = nn.norm_apply(cfg.norm, params["final_norm"], x_last, **nk)
    return _logits(params, cfg, x_last), new_cache


def _hybrid_prefill(params, cfg, x, positions, max_len, lengths=None):
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    blocks = params["layers"]["blocks"]
    shared = params["layers"]["shared_attn"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), blocks)

    def group_body(carry, p_group):
        def inner(c, p_l):
            nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
            y = nn.norm_apply(cfg.norm, p_l["norm"], c, **nk)
            out, state = ssd_lib.ssd_block_apply(p_l["mixer"], cfg, y,
                                                 return_state=True,
                                                 lengths=lengths)
            return c + out, state

        h, states = _iterate(cfg, inner, carry, p_group)
        h, mc = _attn_block_prefill(shared, cfg, h, positions, has_moe=False,
                                    lengths=lengths)
        return h, (states, mc)

    x, (states, mc) = _iterate(cfg, _remat(cfg, group_body), x, grouped)
    conv = states["conv"].reshape((-1,) + states["conv"].shape[2:])
    ssm = states["ssm"].reshape((-1,) + states["ssm"].shape[2:])
    return x, {"conv": conv, "ssm": ssm,
               "k": _seed_kv(mc["k"], max_len),
               "v": _seed_kv(mc["v"], max_len)}
