"""Decoder-only LM assembly for the whole architecture zoo.

One model builder covers: dense GQA transformers (starcoder2, gemma,
deepseek-67b, pixtral backbone), MLA+MoE (deepseek-v3), fine-grained MoE
(deepseek-moe), SSD (mamba2), hybrid SSD+shared-attention (zamba2), and the
paper's own minGRU/minLSTM LMs.  ``cfg.seq_mixer`` swaps any attention
mixer for the paper's minRNN (DESIGN.md §5).

Layers run under ``lax.scan`` over stacked parameters (cfg.scan_layers) so
HLO size -- and dry-run compile time -- is O(1) in depth.  Every block kind
provides a parallel form (train / batch-eval ``prefill``, returning
per-layer caches) and a step form (decode, carrying caches).  Serving
drives the step form exclusively: ``superstep`` scans K rounds of
token-select -> ``decode_step`` -> sample-or-teacher-force -> retire ->
re-admission over device-resident per-slot state (``init_slot_state``),
so prefilling and decoding requests share one code path and one kernel.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import blocks as minrnn_blocks
from repro.core import min_gru, min_lstm, nn
from repro.distributed.act_sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.models.mlp import mlp_apply, mlp_init

Array = jax.Array

_MIN_CELLS = {"mingru": min_gru, "minlstm": min_lstm}


# ===========================================================================
# Parameter init
# ===========================================================================

def init_params(key, cfg) -> Dict[str, Any]:
    dtype = cfg.pdtype
    k_embed, k_layers, k_out, k_front = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": {"table": nn.normal_init(
            k_embed, (cfg.padded_vocab, cfg.d_model), 0.02, dtype)},
        "final_norm": nn.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = nn.dense_init(k_out, cfg.d_model,
                                          cfg.padded_vocab,
                                          use_bias=False, dtype=dtype)
    if cfg.frontend == "patches":
        params["patch_proj"] = nn.dense_init(
            k_front, cfg.frontend_dim, cfg.d_model, use_bias=False,
            dtype=dtype)
    params["layers"] = _init_trunk(k_layers, cfg, dtype)
    return params


def _stack_init(init_one, keys):
    return jax.vmap(init_one)(keys)


def _init_trunk(key, cfg, dtype):
    if cfg.block_kind == "hybrid":
        return _init_hybrid(key, cfg, dtype)
    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)
        keys = jax.random.split(key, cfg.n_layers)
        return {"blocks": _stack_init(
            lambda k: minrnn_blocks.init(k, bc, dtype=dtype), keys)}
    if cfg.block_kind == "ssm":
        keys = jax.random.split(key, cfg.n_layers)
        return {"blocks": _stack_init(
            lambda k: _ssm_layer_init(k, cfg, dtype), keys)}
    # attention trunk, possibly with a leading dense segment before MoE
    n_dense_first = cfg.moe.first_dense_layers if cfg.moe else 0
    n_main = cfg.n_layers - n_dense_first
    out = {}
    if n_dense_first:
        keys = jax.random.split(jax.random.fold_in(key, 1), n_dense_first)
        out["dense_blocks"] = _stack_init(
            lambda k: _attn_layer_init(k, cfg, dtype, force_dense=True), keys)
    keys = jax.random.split(jax.random.fold_in(key, 2), n_main)
    out["blocks"] = _stack_init(
        lambda k: _attn_layer_init(k, cfg, dtype), keys)
    return out


def _minrnn_block_cfg(cfg):
    mr = cfg.minrnn
    return minrnn_blocks.MinRNNBlockConfig(
        d_model=cfg.d_model, cell=mr.cell, expansion=mr.expansion,
        use_conv=mr.use_conv, conv_kernel=mr.conv_kernel,
        use_mlp=mr.use_mlp, mlp_factor=cfg.d_ff / cfg.d_model,
        mode=mr.mode, norm=cfg.norm, scan_strategy=cfg.scan_strategy,
        fuse_block=cfg.fuse_block, block_dh=cfg.block_dh)


def _mixer_init(key, cfg, dtype):
    """The sequence mixer of an attention-style block."""
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        exp = cfg.minrnn.expansion if cfg.minrnn else 1.0
        dh = int(cfg.d_model * exp)
        k1, k2 = jax.random.split(key)
        return {"rnn": cell.init(k1, cfg.d_model, dh, dtype=dtype),
                "down": nn.dense_init(k2, dh, cfg.d_model, use_bias=False,
                                      dtype=dtype)}
    if cfg.attn_kind == "mla":
        return attn.mla_init(key, cfg, dtype=dtype)
    return attn.gqa_init(key, cfg, dtype=dtype)


def _attn_layer_init(key, cfg, dtype, force_dense: bool = False):
    ks = jax.random.split(key, 3)
    p = {
        "norm1": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "mixer": _mixer_init(ks[0], cfg, dtype),
        "norm2": nn.norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.moe and not force_dense:
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype=dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                            gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                            dtype=dtype)
    return p


def _ssm_layer_init(key, cfg, dtype):
    return {
        "norm": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "mixer": ssd_lib.ssd_init(key, cfg, dtype=dtype),
    }


def _init_hybrid(key, cfg, dtype):
    """zamba2: n_layers SSD blocks + ONE shared attention block applied
    every ``hybrid_attn_every`` layers (params shared, activations not)."""
    k1, k2 = jax.random.split(key)
    keys = jax.random.split(k1, cfg.n_layers)
    return {
        "blocks": _stack_init(lambda k: _ssm_layer_init(k, cfg, dtype), keys),
        "shared_attn": _attn_layer_init(k2, cfg, dtype, force_dense=True),
    }


# ===========================================================================
# Block bodies (parallel form)
# ===========================================================================

def _remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _mixer_apply(p, cfg, x, positions):
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        mode = cfg.minrnn.mode if cfg.minrnn else "log"
        h = cell.parallel(p["rnn"], x, mode=mode, compute_dtype=cfg.cdtype,
                          scan_strategy=cfg.scan_strategy)
        return nn.dense_apply(p["down"], h, cfg.cdtype)
    if cfg.attn_kind == "mla":
        return attn.mla_apply(p, cfg, x, positions=positions, causal=True)
    return attn.gqa_apply(p, cfg, x, positions=positions, causal=True)


def _attn_block_apply(p, cfg, x, positions, *, has_moe):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm1"], x, **nk)
    x = x + _mixer_apply(p["mixer"], cfg, y, positions)
    y = nn.norm_apply(cfg.norm, p["norm2"], x, **nk)
    if has_moe:
        out, aux = moe_lib.moe_apply(p["moe"], cfg, y,
                                     activation=cfg.mlp_activation)
        return x + out, aux
    out = mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                    compute_dtype=cfg.cdtype)
    return x + out, jnp.zeros((), jnp.float32)


def _ssm_block_apply(p, cfg, x):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm"], x, **nk)
    return x + ssd_lib.ssd_block_apply(p["mixer"], cfg, y)


# ===========================================================================
# Trunk (parallel): scan over stacked layer params
# ===========================================================================

def _trunk_apply(params, cfg, x, positions) -> Tuple[Array, Array]:
    """Returns (x, aux_loss_sum)."""
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)

        def body(carry, p_l):
            h = minrnn_blocks.apply(p_l, bc, carry, compute_dtype=cfg.cdtype,
                                    scan_strategy=cfg.scan_strategy)
            return h, None

        x, _ = _scan_layers(cfg, body, x, params["layers"]["blocks"])
        return x, aux_total

    if cfg.block_kind == "ssm":
        def body(carry, p_l):
            return _ssm_block_apply(p_l, cfg, carry), None

        x, _ = _scan_layers(cfg, body, x, params["layers"]["blocks"])
        return x, aux_total

    if cfg.block_kind == "hybrid":
        return _hybrid_apply(params, cfg, x, positions), aux_total

    # attention trunk
    layers = params["layers"]
    if "dense_blocks" in layers:
        def body_d(carry, p_l):
            h, _ = _attn_block_apply(p_l, cfg, carry, positions,
                                     has_moe=False)
            return h, None

        x, _ = _scan_layers(cfg, body_d, x, layers["dense_blocks"])

    has_moe = cfg.moe is not None

    def body(carry, p_l):
        h, aux = _attn_block_apply(p_l, cfg, carry, positions,
                                   has_moe=has_moe)
        return h, aux

    x, auxs = _scan_layers(cfg, body, x, layers["blocks"])
    if auxs is not None:
        aux_total = aux_total + jnp.sum(auxs)
    return x, aux_total


def _iterate(cfg, body, x, scanned):
    """lax.scan over stacked leaves, or an unrolled python loop when
    cfg.scan_layers=False (the dry-run uses unrolled so cost_analysis
    counts every layer -- XLA tallies a while-loop body only once)."""
    if cfg.scan_layers:
        return lax.scan(body, x, scanned)
    n = jax.tree.leaves(scanned)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], scanned)
        x, y = body(x, sl)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return x, ys


def _scan_layers(cfg, body, x, stacked):
    return _iterate(cfg, _remat(cfg, body), x, stacked)


def _hybrid_apply(params, cfg, x, positions):
    """zamba2 trunk: scan over groups of (every k SSD layers + shared attn)."""
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    blocks = params["layers"]["blocks"]
    shared = params["layers"]["shared_attn"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), blocks)

    def group_body(carry, p_group):
        def inner(c, p_l):
            return _ssm_block_apply(p_l, cfg, c), None

        h, _ = _iterate(cfg, inner, carry, p_group)
        h, _ = _attn_block_apply(shared, cfg, h, positions, has_moe=False)
        return h, None

    x, _ = _iterate(cfg, _remat(cfg, group_body), x, grouped)
    return x


# ===========================================================================
# Embedding / logits / forward / loss
# ===========================================================================

def _embed(params, cfg, tokens, patch_embeds=None):
    x = params["embed"]["table"].astype(cfg.cdtype)[tokens]
    x = constrain(x, "dp", None, None)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
    if cfg.frontend == "patches" and patch_embeds is not None:
        pe = nn.dense_apply(params["patch_proj"], patch_embeds, cfg.cdtype)
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        table = params["embed"]["table"].astype(cfg.cdtype)
        logits = x @ table.T
    else:
        logits = nn.dense_apply(params["unembed"], x, cfg.cdtype)
    if cfg.logits_softcap:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    if cfg.padded_vocab != cfg.vocab_size:   # mask the pad columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    return logits


def forward(params, cfg, tokens: Array, *, patch_embeds: Optional[Array] = None
            ) -> Tuple[Array, Array]:
    """tokens: (B, S) -> (logits (B, S*, V), aux_loss).  S* includes any
    frontend prefix tokens."""
    x = _embed(params, cfg, tokens, patch_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = _trunk_apply(params, cfg, x, positions)
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x = nn.norm_apply(cfg.norm, params["final_norm"], x, **nk)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
    """batch: tokens (B, S), labels (B, S) with -1 = ignore, optional
    patch_embeds."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    logits, aux = forward(params, cfg, tokens,
                          patch_embeds=batch.get("patch_embeds"))
    if logits.shape[1] != labels.shape[1]:      # frontend prefix: drop it
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logits = constrain(logits.astype(jnp.float32), "dp", None, "tp")
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via one-hot contraction: shards cleanly over a
    # vocab-parallel logits tensor (take_along_axis would all-gather it)
    col = jnp.arange(logits.shape[-1])
    gold = jnp.sum(jnp.where(col == safe_labels[..., None], logits, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    metrics = {"nll": loss, "ntokens": jnp.sum(mask)}
    if cfg.z_loss:
        zl = cfg.z_loss * jnp.sum((logz ** 2) * mask) / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_weight * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = loss
    return loss, metrics


# ===========================================================================
# Decode: cache init / prefill / step
# ===========================================================================

def init_cache(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked per-layer caches + shared position counter."""
    dt = cfg.cdtype
    cache: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    L = cfg.n_layers

    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)
        cache["h"] = jnp.zeros((L, batch, bc.d_hidden), dt)
        if bc.use_conv:
            cache["conv"] = jnp.zeros(
                (L, batch, bc.conv_kernel - 1, cfg.d_model), dt)
        return cache

    if cfg.block_kind == "ssm":
        s = cfg.ssm
        cache["conv"] = jnp.zeros(
            (L, batch, s.conv_kernel - 1,
             s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state), dt)
        cache["ssm"] = jnp.zeros(
            (L, batch, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
            jnp.float32)
        return cache

    if cfg.block_kind == "hybrid":
        s = cfg.ssm
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        cache["conv"] = jnp.zeros(
            (L, batch, s.conv_kernel - 1,
             s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state), dt)
        cache["ssm"] = jnp.zeros(
            (L, batch, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
            jnp.float32)
        cache["k"] = jnp.zeros(
            (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), dt)
        cache["v"] = jnp.zeros_like(cache["k"])
        return cache

    # attention trunk
    if cfg.seq_mixer in _MIN_CELLS:
        exp = cfg.minrnn.expansion if cfg.minrnn else 1.0
        cache["h"] = jnp.zeros((L, batch, int(cfg.d_model * exp)), dt)
    elif cfg.attn_kind == "mla":
        cache["ckv"] = jnp.zeros((L, batch, max_len, cfg.mla_kv_lora), dt)
        cache["krope"] = jnp.zeros((L, batch, max_len, cfg.mla_rope_dim), dt)
    else:
        cache["k"] = jnp.zeros(
            (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), dt)
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def decode_step(params, cfg, token: Array, cache: Dict[str, Any]
                ) -> Tuple[Array, Dict[str, Any]]:
    """token: (B,) -> (logits (B, V), new cache).  One step for every arch.

    Every trunk kind dispatches its layer stack as ONE ``lax.scan`` over
    stacked weights (``_iterate``), so the per-step HLO is O(1) in depth;
    the minRNN step body additionally runs its cell in the fused Pallas
    decode kernel under the default ``scan_strategy="auto"`` (see
    ``_minrnn_decode``).  This is the single model entry point of the
    serving engine: ``superstep`` wraps it in a second on-device scan
    that drives prefill (teacher-forced prompt tokens) and decode
    (sampled tokens) through the same step, K rounds per host call.
    """
    pos = cache["pos"]
    x = params["embed"]["table"].astype(cfg.cdtype)[token]
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)

    new_cache = dict(cache)

    if cfg.block_kind == "minrnn":
        x, outs = _minrnn_decode(params, cfg, x, cache)
        new_cache.update(outs)

    elif cfg.block_kind == "ssm":
        def body(carry, scanned):
            p_l, cache_l = scanned
            y = nn.norm_apply(cfg.norm, p_l["norm"], carry)
            out, state = ssd_lib.ssd_block_step(
                p_l["mixer"], cfg, y,
                {"conv": cache_l["conv"], "ssm": cache_l["ssm"]})
            return carry + out, state

        scanned = {"conv": cache["conv"], "ssm": cache["ssm"]}
        x, outs = _iterate(cfg, body, x,
                           (params["layers"]["blocks"], scanned))
        new_cache.update(outs)

    elif cfg.block_kind == "hybrid":
        x, outs = _hybrid_decode(params, cfg, x, cache)
        new_cache.update(outs)

    else:
        x, outs = _attn_decode(params, cfg, x, cache)
        new_cache.update(outs)

    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x = nn.norm_apply(cfg.norm, params["final_norm"], x, **nk)
    logits = _logits(params, cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _minrnn_decode(params, cfg, x, cache):
    """minRNN trunk single-token step: one stacked-weight ``lax.scan``
    whose body is ``blocks.step`` -- the cell GEMVs + gates + state update
    run in the fused Pallas decode kernel when ``cfg.scan_strategy``
    resolves to ``"fused"`` (the ``"auto"`` default)."""
    bc = _minrnn_block_cfg(cfg)

    def body(carry, scanned):
        p_l, cache_l = scanned
        state = {"h": cache_l["h"]}
        if bc.use_conv:
            state["conv"] = cache_l["conv"]
        y, state = minrnn_blocks.step(p_l, bc, carry, state,
                                      compute_dtype=cfg.cdtype)
        out_c = {"h": state["h"]}
        if bc.use_conv:
            out_c["conv"] = state["conv"]
        return y, out_c

    scanned = {"h": cache["h"]}
    if bc.use_conv:
        scanned["conv"] = cache["conv"]
    return _iterate(cfg, body, x, (params["layers"]["blocks"], scanned))


def supports_prompt_packing(cfg) -> bool:
    """True when the superstep can consume C > 1 prompt tokens per round
    (``decode_chunk``): requires the whole decode state to be a constant-
    size recurrence, i.e. the paper's minRNN family -- same condition as
    chunked prefill."""
    return supports_chunked_prefill(cfg)


def decode_chunk(params, cfg, tokens: Array, valid: Array,
                 cache: Dict[str, Any]) -> Tuple[Array, Dict[str, Any]]:
    """Packed varlen step: tokens (B, C), valid (B,) int32 in [1, C] ->
    (logits (B, V) at each row's position ``valid[b]-1``, new cache).

    The prompt-packing core: row b consumes its first ``valid[b]`` tokens
    in one device round -- per-token arithmetic identical to ``valid[b]``
    sequential ``decode_step`` calls (the cell rides the fused Pallas
    chunk kernels under ``scan_strategy="auto"``, streaming each layer's
    weights from HBM once per chunk instead of once per token), with the
    recurrent state frozen per-row at ``valid[b]``.  Logits (final norm +
    unembed) are computed once per row at its last valid position, not C
    times.  Only recurrence-cached archs can do this
    (``supports_prompt_packing``); KV/SSD caches would need per-position
    cache scatter."""
    if cfg.block_kind != "minrnn":
        raise NotImplementedError(
            f"packed decode_chunk requires a constant-size recurrent "
            f"state (block_kind='minrnn'), got {cfg.block_kind!r}")
    bc = _minrnn_block_cfg(cfg)
    x = params["embed"]["table"].astype(cfg.cdtype)[tokens]   # (B, C, D)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)

    def body(carry, scanned):
        p_l, cache_l = scanned
        state = {"h": cache_l["h"]}
        if bc.use_conv:
            state["conv"] = cache_l["conv"]
        y, state = minrnn_blocks.step_chunk(p_l, bc, carry, state, valid,
                                            compute_dtype=cfg.cdtype)
        out_c = {"h": state["h"]}
        if bc.use_conv:
            out_c["conv"] = state["conv"]
        return y, out_c

    scanned = {"h": cache["h"]}
    if bc.use_conv:
        scanned["conv"] = cache["conv"]
    x, outs = _iterate(cfg, body, x, (params["layers"]["blocks"], scanned))

    new_cache = dict(cache)
    new_cache.update(outs)
    x_last = nn.gather_last(x, valid)                 # (B, D) at valid-1
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x_last = nn.norm_apply(cfg.norm, params["final_norm"], x_last, **nk)
    logits = _logits(params, cfg, x_last)
    new_cache["pos"] = cache["pos"] + valid.astype(jnp.int32)
    return logits, new_cache


def decode_verify(params, cfg, tokens: Array, valid: Array,
                  cache: Dict[str, Any]):
    """Speculative-verify pass: tokens (B, W), valid (B,) int32 in
    [1, W] -> (logits (B, W, V), per-position states).

    ``decode_chunk``'s sibling for speculative decoding: the same masked
    varlen replay through the fused chunk kernels (one weight stream per
    round, per-token arithmetic identical to sequential ``decode_step``
    calls, rows frozen at ``valid[b]``), but keeping what verification
    needs and ``decode_chunk`` throws away -- the logits at EVERY
    position (to judge each draft token) and the carried recurrent
    state after every position: ``{"h": (L, B, W, d_hidden)[, "conv":
    (L, B, W, K-1, d_model)]}``.  The caller commits a per-row prefix of
    ``valid_eff[b] <= valid[b]`` positions by gathering the state at
    ``valid_eff[b] - 1`` and advancing ``pos`` by ``valid_eff`` -- the
    recompute-free O(d_hidden)-per-slot rollback the paper's constant-
    size state makes trivial (a Transformer would instead truncate and
    re-page its KV cache).  The returned cache is untouched; positions
    ``>= valid[b]`` re-emit the frozen state so any gather index in
    ``[valid_eff-1, W)`` is safe."""
    if cfg.block_kind != "minrnn":
        raise NotImplementedError(
            f"decode_verify requires a constant-size recurrent state "
            f"(block_kind='minrnn'), got {cfg.block_kind!r}")
    bc = _minrnn_block_cfg(cfg)
    x = params["embed"]["table"].astype(cfg.cdtype)[tokens]   # (B, W, D)
    if cfg.embedding_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)

    def body(carry, scanned):
        p_l, cache_l = scanned
        state = {"h": cache_l["h"]}
        if bc.use_conv:
            state["conv"] = cache_l["conv"]
        y, _, pos_states = minrnn_blocks.step_chunk(
            p_l, bc, carry, state, valid, compute_dtype=cfg.cdtype,
            return_positions=True)
        return y, pos_states

    scanned = {"h": cache["h"]}
    if bc.use_conv:
        scanned["conv"] = cache["conv"]
    x, states = _iterate(cfg, body, x, (params["layers"]["blocks"], scanned))

    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x = nn.norm_apply(cfg.norm, params["final_norm"], x, **nk)
    return _logits(params, cfg, x), states


# ===========================================================================
# Superstep: unified prefill + decode + sampling + re-admission on device
# ===========================================================================

# cache leaves that are *read back* by the recurrence and must be zeroed
# when a slot is re-armed for a new request.  KV-style leaves (k / v /
# ckv / krope) are deliberately NOT reset: decode masks attention by the
# per-slot ``pos`` counter and overwrites position p before attending to
# it, so stale rows beyond ``pos`` are never visible (the same invariant
# batched padded prefill already relies on).
_RECURRENT_CACHE_KEYS = ("h", "conv", "ssm")


def init_slot_state(cfg, batch: int, max_len: int, *, seed: int = 0,
                    draft=None) -> Dict[str, Any]:
    """Device-resident per-slot serving state for ``superstep``.

    One fixed-shape pytree holds everything the device loop needs to run
    admission, prefill, decode and sampling without host intervention:

      * ``cache``       -- the decode cache (``init_cache``);
      * active request: ``tok`` (last sampled token), ``prompt`` (B,
        max_len) staged prompt tokens, ``prompt_len`` / ``prompt_pos``,
        ``rid`` (host request tag riding along so the (B, n) output
        buffer can be demuxed even when one slot serves two requests in
        a single superstep), ``remaining`` / ``eos`` and the per-slot
        sampling controls ``temperature`` / ``top_k`` / ``top_p``;
      * ``alive``       -- slot has a request in flight (prefilling or
        decoding);
      * ``keys``        -- per-*slot* PRNG keys (slot-persistent and
        emission-aligned: a row's key advances only on rounds it emits,
        so a request's k-th output token uses the k-th key in its
        slot's chain regardless of ``prompt_chunk`` or how many
        teacher-forced rounds its prompt took);
      * staging buffer  -- ``s_*`` mirrors of the request fields plus
        ``s_valid``: the host parks the next queued request here and the
        scan body arms it into the row the moment the row goes dead.

    ``draft`` (a ``serving.draft`` source) adds the speculative-decoding
    state: ``n_out`` (emitted tokens appended to the prompt buffer as
    drafting history) plus whatever the source itself carries per slot
    (``draft.extra_state`` -- e.g. the draft model's decode cache).
    """
    # lazy import: models/ stays importable without the serving package
    # in minimal deployments; sampling itself only depends on jax
    from repro.serving import sampling

    i32 = jnp.int32

    def iv(fill=0):
        return jnp.full((batch,), fill, i32)

    state: Dict[str, Any] = {
        "cache": init_cache(cfg, batch, max_len),
        "tok": iv(), "alive": jnp.zeros((batch,), bool),
        "keys": sampling.make_keys(seed, batch),
        "prompt": jnp.zeros((batch, max_len), i32),
        "prompt_len": iv(), "prompt_pos": iv(),
        "rid": iv(-1), "remaining": iv(), "eos": iv(-1),
        "temperature": jnp.zeros((batch,), jnp.float32),
        "top_k": iv(), "top_p": jnp.ones((batch,), jnp.float32),
        "s_valid": jnp.zeros((batch,), bool),
        "s_prompt": jnp.zeros((batch, max_len), i32),
        "s_prompt_len": iv(), "s_rid": iv(-1), "s_remaining": iv(),
        "s_eos": iv(-1),
        "s_temperature": jnp.zeros((batch,), jnp.float32),
        "s_top_k": iv(), "s_top_p": jnp.ones((batch,), jnp.float32),
    }
    if draft is not None:
        state["n_out"] = iv()
        state.update(draft.extra_state(batch, max_len))
    return state


def _reset_slot_rows(cache: Dict[str, Any], mask: Array) -> Dict[str, Any]:
    """Re-arm rows ``mask``: zero the recurrent state and position counter
    so the row starts a fresh request (see _RECURRENT_CACHE_KEYS for why
    KV leaves are left in place)."""
    out = dict(cache)
    out["pos"] = jnp.where(mask, 0, cache["pos"])
    for name in _RECURRENT_CACHE_KEYS:
        if name in cache:
            leaf = cache[name]
            m = mask.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            out[name] = jnp.where(m, jnp.zeros((), leaf.dtype), leaf)
    return out


# request fields swapped wholesale from the staging buffer when a row arms
_ARM_FIELDS = ("prompt_len", "rid", "remaining", "eos", "temperature",
               "top_k", "top_p")


def superstep(params, cfg, state: Dict[str, Any], n: int, *,
              prompt_chunk: int = 1, draft=None, draft_params=None):
    """Run ``n`` rounds of the unified serving loop entirely on device.

    ONE ``lax.scan`` whose body is, for every slot simultaneously:

      1. **re-admission** -- dead rows with a staged request arm it:
         recurrent cache rows zeroed, ``pos``/``prompt_pos`` reset,
         request fields swapped in from the ``s_*`` staging buffer;
      2. **token select** -- prefilling rows (``prompt_pos <
         prompt_len``) consume their next prompt token (the next *C*
         prompt tokens when ``prompt_chunk=C > 1``), decoding rows feed
         back their last sampled token;
      3. **fused block step** -- one ``decode_step`` for the whole
         batch: prefilling and decoding rows ride the same fused Pallas
         cell kernel in the same round.  Under ``prompt_chunk=C > 1``
         this is ``decode_chunk`` instead: prefilling rows advance
         through up to C prompt tokens via the masked varlen chunk
         kernels (one weight stream amortised over C prompt tokens --
         the weight-bound-regime packing win) while decoding and dead
         rows ride the same call with a valid length of 1; emitted
         greedy and seeded streams are bit-exact with the C=1 path;
      4. **sample-or-teacher-force** -- every row samples, but only
         rows whose logits are real output logits emit: decoding rows,
         and prefilling rows whose round reached their *last* prompt
         token (their sample is the request's first output token).
         Keys advance only on rows that emit, so a request's k-th
         output token uses the k-th key in its slot's chain regardless
         of ``prompt_chunk`` -- seeded streams are bit-exact across C
         for a given slot assignment.  Teacher-forced rows discard the
         sample and emit -1;
      5. **EOS / retire** -- emitting rows that hit their stop token or
         length cap go dead; the next round's step 1 re-arms them from
         staging with zero idle rounds.

    Returns ``(tokens, rids, state, counters)``: ``tokens`` (B, n) int32
    with -1 at non-emitting positions, ``rids`` (B, n) int32 tagging
    each emitted token with its request id (one row may emit for two
    requests within a single call), the advanced slot state, and
    ``counters`` with ``prefill_steps`` (prompt tokens consumed -- up to
    C per slot-round when packing), ``prefill_rounds`` (slot-rounds
    spent prefilling; equals ``prefill_steps`` at C=1) and
    ``wasted_slot_steps`` (rows stepped while dead with nothing staged
    -- the idle waste this loop exists to eliminate; rows keep stepping
    regardless so the batch stays dense and shapes stay static).

    **Numerical health guard**: every round, each row's fresh logits
    (and recurrent state, for recurrent-cache archs) are reduced to a
    per-slot finite/non-finite bit.  A row that goes non-finite is
    killed THAT round -- its emission is suppressed so garbage never
    reaches the output buffers, and the next round's re-admission
    re-arms it through the same state-zeroing path a normal retirement
    uses.  ``counters['nonfinite']`` is the per-slot-per-round flag
    plane (B, n) the host uses to attribute the kill to a request, and
    ``counters['nonfinite_decode_rounds']`` counts suppressed rounds on
    non-prefilling rows (the slot-step identity's correction term: such
    a round is neither a prefill round nor an emitted token).  On a
    healthy batch the guard is the identity -- every select masks with
    an all-False flag -- so fault-free streams stay bit-exact.

    ``n`` and ``prompt_chunk`` must be static (the engine jits one
    program per block size); ``prompt_chunk > 1`` requires
    ``supports_prompt_packing(cfg)``.

    ``draft`` (a ``serving.draft`` source, with its weights -- if any --
    passed as ``draft_params`` so they stay traced) switches the loop to
    **speculative decoding**: decoding rows propose up to
    ``draft.draft_len`` draft tokens per round and verify them in ONE
    pass through the varlen chunk kernels (``decode_verify``), emitting
    every accepted token plus the verifier's own next token -- up to
    ``draft_len + 1`` tokens per slot-round, so the emit buffers grow a
    per-round plane: ``tokens``/``rids`` become (B, n, draft_len + 1).
    Rejection rolls the slot state back to the last accepted position
    with one O(d_hidden) gather of the chunk's per-position states (no
    recompute, no host round-trip).  Emission stays EXACT: every token
    is computed precisely as the non-speculative path would (greedy
    argmax, or categorical under the same emission-aligned key chain --
    position i of a round uses the slot's i-th chained key), so greedy
    AND seeded streams are bit-identical to ``draft=None`` and drafting
    only ever changes latency.  ``counters`` gains ``draft_proposed`` /
    ``draft_accepted`` (sum of drafts offered / accepted on decoding
    rows) and ``emit_rounds`` (emitting slot-rounds == tokens the non-
    speculative path contributes: ``decode_tokens == draft_accepted +
    emit_rounds`` exactly).  Requires ``supports_prompt_packing(cfg)``.
    """
    from repro.serving import sampling

    if prompt_chunk > 1 and not supports_prompt_packing(cfg):
        raise NotImplementedError(
            f"prompt_chunk={prompt_chunk} requires a recurrent-state arch "
            f"(block_kind='minrnn'), got block_kind={cfg.block_kind!r}")
    if draft is not None:
        if not supports_prompt_packing(cfg):
            raise NotImplementedError(
                f"speculative decoding requires a recurrent-state arch "
                f"(block_kind='minrnn'), got block_kind={cfg.block_kind!r}")
        return _superstep_spec(params, cfg, state, n,
                               prompt_chunk=prompt_chunk, draft=draft,
                               draft_params=draft_params)

    batch = state["tok"].shape[0]
    p_cap = state["prompt"].shape[1]
    chunk = int(prompt_chunk)

    def body(carry, _):
        st, prefill_ct, round_ct, waste_ct, nf_ct = carry
        st = dict(st)

        # 1. re-admission from the staging buffer
        arm = jnp.logical_not(st["alive"]) & st["s_valid"]
        for f in _ARM_FIELDS:
            st[f] = jnp.where(arm, st["s_" + f], st[f])
        st["prompt"] = jnp.where(arm[:, None], st["s_prompt"], st["prompt"])
        st["prompt_pos"] = jnp.where(arm, 0, st["prompt_pos"])
        st["alive"] = st["alive"] | arm
        st["s_valid"] = st["s_valid"] & jnp.logical_not(arm)
        st["cache"] = _reset_slot_rows(st["cache"], arm)

        alive = st["alive"]
        waste_ct = waste_ct + jnp.sum(
            jnp.logical_not(alive).astype(jnp.int32))
        prefilling = alive & (st["prompt_pos"] < st["prompt_len"])
        round_ct = round_ct + jnp.sum(prefilling.astype(jnp.int32))

        if chunk == 1:
            take = prefilling.astype(jnp.int32)
            prefill_ct = prefill_ct + jnp.sum(take)

            # 2. per-slot token select
            nxt = st["prompt"][jnp.arange(batch),
                               jnp.clip(st["prompt_pos"], 0, p_cap - 1)]
            in_tok = jnp.where(prefilling, nxt, st["tok"])

            # 3. fused block step, all rows in one batch
            logits, st["cache"] = decode_step(params, cfg, in_tok,
                                              st["cache"])
        else:
            # 2. packed token select: up to C prompt tokens per
            # prefilling row, the fed-back sample for decoding rows
            left = st["prompt_len"] - st["prompt_pos"]
            take = jnp.where(prefilling,
                             jnp.minimum(left, chunk), 0).astype(jnp.int32)
            prefill_ct = prefill_ct + jnp.sum(take)
            valid = jnp.maximum(take, 1)        # non-prefilling rows: 1

            # 3. packed varlen block step, all rows in one batch -- but
            # only when some row is actually prefilling: steady-state
            # decode-only rounds take the plain single-token step (the
            # exact C=1 program) instead of paying the C-wide chunk
            # compute for 1 useful token per row
            def packed_step(cache):
                idx = st["prompt_pos"][:, None] + jnp.arange(chunk)[None]
                gathered = jnp.take_along_axis(
                    st["prompt"], jnp.clip(idx, 0, p_cap - 1), axis=1)
                tok_blk = jnp.where(prefilling[:, None], gathered,
                                    st["tok"][:, None])
                return decode_chunk(params, cfg, tok_blk, valid, cache)

            def plain_step(cache):
                # no prefilling rows: valid == 1 everywhere, so this is
                # bit-identical state-wise (pos + 1, one token per row)
                return decode_step(params, cfg, st["tok"], cache)

            logits, st["cache"] = lax.cond(jnp.any(prefilling),
                                           packed_step, plain_step,
                                           st["cache"])

        # 3b. numerical health guard: reduce this round's logits (and
        # the recurrent state, when the arch carries one) to a per-slot
        # finite bit.  Poisoned rows are killed this round with their
        # emission suppressed; re-admission re-zeroes their state.  On a
        # healthy batch ``bad`` is all-False and every masked op below
        # is the identity, so fault-free streams are bit-exact.
        ok = jnp.all(jnp.isfinite(logits), axis=-1)
        if "h" in st["cache"]:
            h = st["cache"]["h"]
            ok = ok & jnp.all(jnp.isfinite(h), axis=tuple(
                a for a in range(h.ndim) if a != 1))
        bad = alive & jnp.logical_not(ok)
        nf_ct = nf_ct + jnp.sum(
            (bad & jnp.logical_not(prefilling)).astype(jnp.int32))

        # 4. sample-or-teacher-force
        toks, new_keys = sampling.sample_tokens(
            logits, st["keys"], st["temperature"], st["top_k"], st["top_p"])
        pos_next = st["prompt_pos"] + take
        emitting = alive & jnp.logical_not(bad) \
            & (pos_next >= st["prompt_len"])
        st["keys"] = jnp.where(emitting[:, None], new_keys, st["keys"])
        emit = jnp.where(emitting, toks, jnp.int32(-1))
        emit_rid = jnp.where(emitting, st["rid"], jnp.int32(-1))

        # 5. EOS / length-cap retire (a non-finite row dies too)
        st["remaining"] = st["remaining"] - emitting.astype(jnp.int32)
        hit_eos = emitting & (st["eos"] >= 0) & (toks == st["eos"])
        died = hit_eos | (emitting & (st["remaining"] <= 0))
        st["alive"] = alive & jnp.logical_not(died | bad)
        st["tok"] = jnp.where(emitting, toks, st["tok"])
        st["prompt_pos"] = pos_next
        return (st, prefill_ct, round_ct, waste_ct, nf_ct), \
            (emit, emit_rid, bad)

    zero = jnp.zeros((), jnp.int32)
    (state, prefill_ct, round_ct, waste_ct, nf_ct), \
        (emitted, rids, nonfinite) = lax.scan(
            body, (state, zero, zero, zero, zero), None, length=n)
    counters = {"prefill_steps": prefill_ct,
                "prefill_rounds": round_ct,
                "wasted_slot_steps": waste_ct,
                "nonfinite_decode_rounds": nf_ct,
                "nonfinite": jnp.swapaxes(nonfinite, 0, 1)}
    return (jnp.swapaxes(emitted, 0, 1), jnp.swapaxes(rids, 0, 1),
            state, counters)


def _superstep_spec(params, cfg, state: Dict[str, Any], n: int, *,
                    prompt_chunk: int, draft, draft_params):
    """The speculative form of :func:`superstep` (see its docstring for
    the contract).  Per round, for every slot simultaneously:

      1. **re-admission** as in the plain loop, additionally resetting
         the drafting history (``n_out``) and the draft source's own
         per-slot state;
      2. **propose** -- the draft source offers up to S continuation
         tokens per row; only decoding rows keep theirs (capped at
         ``remaining - 1``: the round's guaranteed token covers the
         rest);
      3. **verify** -- ONE ``decode_verify`` chunk pass over
         ``[tok, d_1..d_S]`` for decoding rows (prefilling rows ride the
         same call with their next C prompt tokens, dead rows with
         valid=1), producing per-position logits and per-position
         states;
      4. **accept** -- position i's exact token x_i (greedy argmax or
         categorical under chained key i) is compared to draft d_{i+1}:
         the committed length is e = (leading run of matches) + 1,
         truncated at the first emitted EOS.  Tokens x_0..x_{e-1} emit
         into planes 0..e-1; the slot's key advances e splits, its fed-
         back token becomes x_{e-1};
      5. **rollback / commit** -- the recurrent state is gathered at the
         last committed position (prefilling rows: their packed take;
         dead rows: 1) and ``pos`` advances by exactly the committed
         length -- O(d_hidden) per slot, no recompute;
      6. **EOS / retire** exactly as the plain loop (an EOS can only sit
         at the last emitted plane, by the truncation in 4).
    """
    from repro.serving import sampling

    batch = state["tok"].shape[0]
    p_cap = state["prompt"].shape[1]
    chunk = int(prompt_chunk)
    s_len = int(draft.draft_len)
    n_emit_planes = s_len + 1                   # E: emit planes per round
    width = max(chunk, s_len + 1)               # W: verify chunk width
    b_idx = jnp.arange(batch)
    i32 = jnp.int32

    def body(carry, _):
        st, ct = carry
        st, ct = dict(st), dict(ct)

        # 1. re-admission from the staging buffer
        arm = jnp.logical_not(st["alive"]) & st["s_valid"]
        for f in _ARM_FIELDS:
            st[f] = jnp.where(arm, st["s_" + f], st[f])
        st["prompt"] = jnp.where(arm[:, None], st["s_prompt"], st["prompt"])
        st["prompt_pos"] = jnp.where(arm, 0, st["prompt_pos"])
        st["n_out"] = jnp.where(arm, 0, st["n_out"])
        st["alive"] = st["alive"] | arm
        st["s_valid"] = st["s_valid"] & jnp.logical_not(arm)
        st["cache"] = _reset_slot_rows(st["cache"], arm)
        if "draft_cache" in st:
            st["draft_cache"] = _reset_slot_rows(st["draft_cache"], arm)

        alive = st["alive"]
        ct["wasted_slot_steps"] += jnp.sum(
            jnp.logical_not(alive).astype(i32))
        prefilling = alive & (st["prompt_pos"] < st["prompt_len"])
        decoding = alive & jnp.logical_not(prefilling)
        ct["prefill_rounds"] += jnp.sum(prefilling.astype(i32))

        left = st["prompt_len"] - st["prompt_pos"]
        take = jnp.where(prefilling,
                         jnp.minimum(left, chunk), 0).astype(i32)
        ct["prefill_steps"] += jnp.sum(take)

        # 2. draft proposal; decoding rows only, capped so the proposal
        # never overshoots the length budget (the verify round's own
        # token is always emitted)
        drafts, n_draft = draft.propose(draft_params, st)
        n_draft = jnp.where(
            decoding,
            jnp.clip(jnp.minimum(n_draft, st["remaining"] - 1), 0, s_len),
            0).astype(i32)
        ct["draft_proposed"] += jnp.sum(n_draft)

        # 3. one verify pass for the whole batch: prefilling rows carry
        # their next C prompt tokens, decoding rows [tok, d_1..d_S]
        idx = st["prompt_pos"][:, None] + jnp.arange(width)[None]
        gathered = jnp.take_along_axis(
            st["prompt"], jnp.clip(idx, 0, p_cap - 1), axis=1)
        dec_blk = jnp.concatenate([st["tok"][:, None], drafts], axis=1)
        if width > s_len + 1:
            dec_blk = jnp.concatenate(
                [dec_blk, jnp.zeros((batch, width - s_len - 1), i32)],
                axis=1)
        tok_blk = jnp.where(prefilling[:, None], gathered, dec_blk)
        valid_in = jnp.where(prefilling, jnp.maximum(take, 1),
                             1 + n_draft).astype(i32)
        logits_all, pstates = decode_verify(params, cfg, tok_blk,
                                            valid_in, st["cache"])

        # 3b. numerical health guard (see the plain loop): per-slot
        # finite bit over the verify pass's logits and per-position
        # recurrent states; poisoned rows emit nothing this round and
        # die, all-False on a healthy batch so streams stay bit-exact
        ok = jnp.all(jnp.isfinite(logits_all), axis=(1, 2))
        if "h" in pstates:
            ph = pstates["h"]
            ok = ok & jnp.all(jnp.isfinite(ph), axis=tuple(
                a for a in range(ph.ndim) if a != 1))
        bad = alive & jnp.logical_not(ok)
        ct["nonfinite_decode_rounds"] += jnp.sum(
            (bad & jnp.logical_not(prefilling)).astype(i32))

        # 4a. exact per-position tokens under the chained key schedule
        # (decoding rows); position i IS what the i-th non-speculative
        # round would sample, so acceptance never changes content
        x_toks, keys_chain = sampling.sample_chain(
            logits_all[:, :n_emit_planes], st["keys"], st["temperature"],
            st["top_k"], st["top_p"])
        # prefilling rows emit (at most) their first output token, from
        # the logits at their LAST consumed prompt position with the
        # slot's current key -- exactly the plain packed path
        last_logits = jnp.take_along_axis(
            logits_all, (valid_in - 1)[:, None, None], axis=1)[:, 0]
        tok_first, _ = sampling.sample_tokens(
            last_logits, st["keys"], st["temperature"], st["top_k"],
            st["top_p"])

        # 4b. acceptance: leading run of drafts matching the exact
        # tokens, +1 for the verifier's own token, truncated at EOS
        m = (x_toks[:, :s_len] == tok_blk[:, 1:s_len + 1]) \
            & (jnp.arange(s_len)[None] < n_draft[:, None])
        lead = jnp.sum(jnp.cumprod(m.astype(i32), axis=1), axis=1)
        is_eos = (st["eos"] >= 0)[:, None] & (x_toks == st["eos"][:, None])
        first_eos = jnp.min(
            jnp.where(is_eos, jnp.arange(n_emit_planes)[None],
                      n_emit_planes), axis=1)
        e = jnp.minimum(lead + 1, first_eos + 1)
        ct["draft_accepted"] += jnp.sum(
            jnp.where(decoding & jnp.logical_not(bad), e - 1, 0))

        pos_next = st["prompt_pos"] + take
        pf_emit = prefilling & (pos_next >= st["prompt_len"])
        emitting = (pf_emit | decoding) & jnp.logical_not(bad)
        ct["emit_rounds"] += jnp.sum(emitting.astype(i32))
        n_emit = jnp.where(bad, 0,
                           jnp.where(decoding, e, pf_emit.astype(i32)))

        # 4c. multi-emit planes: -1 beyond each row's committed length
        plane = jnp.arange(n_emit_planes)[None]
        emit_tok = jnp.where(decoding[:, None], x_toks,
                             tok_first[:, None])
        live_plane = plane < n_emit[:, None]
        emit = jnp.where(live_plane, emit_tok, jnp.int32(-1))
        emit_rid = jnp.where(live_plane, st["rid"][:, None],
                             jnp.int32(-1))

        # keys advance one split per emitted token (keys_chain[:, 0] is
        # the single-split advance, so pf_emit rows get the plain path's
        # key); tok becomes the last emitted token
        kidx = jnp.clip(n_emit - 1, 0, n_emit_planes - 1)
        keys_adv = jnp.take_along_axis(
            keys_chain, kidx[:, None, None], axis=1)[:, 0]
        st["keys"] = jnp.where(emitting[:, None], keys_adv, st["keys"])
        last_tok = jnp.take_along_axis(emit_tok, kidx[:, None],
                                       axis=1)[:, 0]
        st["tok"] = jnp.where(emitting, last_tok, st["tok"])

        # drafting history: append the emitted tokens to the prompt
        # buffer (the n-gram source self-drafts from it); writes past
        # the buffer (only ever a request's final token) are dropped
        hist = st["prompt_len"] + st["n_out"]
        w_idx = jnp.where(live_plane, hist[:, None] + plane, p_cap)
        st["prompt"] = st["prompt"].at[b_idx[:, None], w_idx].set(
            jnp.maximum(emit, 0), mode="drop")
        st["n_out"] = st["n_out"] + n_emit

        # 5. rollback/commit: gather the recurrent state at each row's
        # last committed position, advance pos by the committed length
        valid_eff = jnp.where(prefilling, jnp.maximum(take, 1),
                              jnp.where(decoding, e, 1)).astype(i32)
        g_idx = (valid_eff - 1).astype(i32)
        new_cache = dict(st["cache"])
        new_cache["h"] = jnp.take_along_axis(
            pstates["h"], g_idx[None, :, None, None], axis=2)[:, :, 0]
        if "conv" in pstates:
            new_cache["conv"] = jnp.take_along_axis(
                pstates["conv"], g_idx[None, :, None, None, None],
                axis=2)[:, :, 0]
        new_cache["pos"] = st["cache"]["pos"] + valid_eff
        st["cache"] = new_cache
        st.update(draft.commit(draft_params, st, tok_blk, valid_eff))

        # 6. EOS / length-cap retire (truncation in 4b guarantees an
        # emitted EOS sits at the last plane)
        st["remaining"] = st["remaining"] - n_emit
        hit_eos = emitting & (st["eos"] >= 0) & (last_tok == st["eos"])
        died = hit_eos | (emitting & (st["remaining"] <= 0))
        st["alive"] = alive & jnp.logical_not(died | bad)
        st["prompt_pos"] = pos_next
        return (st, ct), (emit, emit_rid, bad)

    zero = jnp.zeros((), i32)
    counters0 = {k: zero for k in (
        "prefill_steps", "prefill_rounds", "wasted_slot_steps",
        "draft_proposed", "draft_accepted", "emit_rounds",
        "nonfinite_decode_rounds")}
    (state, counters), (emitted, rids, nonfinite) = lax.scan(
        body, (state, counters0), None, length=n)
    counters["nonfinite"] = jnp.swapaxes(nonfinite, 0, 1)
    return (jnp.moveaxis(emitted, 0, 1), jnp.moveaxis(rids, 0, 1),
            state, counters)


def _attn_mixer_step(p, cfg, y, cache_l, pos):
    """Single-token mixer with cache. Returns (out, new mixer cache dict)."""
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        mode = cfg.minrnn.mode if cfg.minrnn else "log"
        h = cell.step(p["rnn"], y, cache_l["h"], mode=mode,
                      compute_dtype=cfg.cdtype,
                      scan_strategy=cfg.scan_strategy)
        return nn.dense_apply(p["down"], h, cfg.cdtype), {"h": h}
    if cfg.attn_kind == "mla":
        out, ckv, krope = attn.mla_decode_step(p, cfg, y, cache_l["ckv"],
                                               cache_l["krope"], pos)
        return out, {"ckv": ckv, "krope": krope}
    out, k, v = attn.gqa_decode_step(p, cfg, y, cache_l["k"], cache_l["v"],
                                     pos)
    return out, {"k": k, "v": v}


def _attn_block_step(p, cfg, x, cache_l, pos, *, has_moe):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm1"], x, **nk)
    out, mix_cache = _attn_mixer_step(p["mixer"], cfg, y, cache_l, pos)
    x = x + out
    y = nn.norm_apply(cfg.norm, p["norm2"], x, **nk)
    if has_moe:
        out, _ = moe_lib.moe_apply(p["moe"], cfg, y[:, None, :],
                                   activation=cfg.mlp_activation)
        out = out[:, 0]
    else:
        out = mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                        compute_dtype=cfg.cdtype)
    return x + out, mix_cache


def _attn_decode(params, cfg, x, cache):
    pos = cache["pos"]
    layers = params["layers"]
    mixer_keys = [k for k in ("h", "ckv", "krope", "k", "v") if k in cache]

    n_dense = 0
    if "dense_blocks" in layers:
        n_dense = jax.tree.leaves(layers["dense_blocks"])[0].shape[0]

        def body_d(carry, scanned):
            p_l, cache_l = scanned
            y, mc = _attn_block_step(p_l, cfg, carry, cache_l, pos,
                                     has_moe=False)
            return y, mc

        sub = {k: cache[k][:n_dense] for k in mixer_keys}
        x, outs_d = _iterate(cfg, body_d, x, (layers["dense_blocks"], sub))
    has_moe = cfg.moe is not None

    def body(carry, scanned):
        p_l, cache_l = scanned
        y, mc = _attn_block_step(p_l, cfg, carry, cache_l, pos,
                                 has_moe=has_moe)
        return y, mc

    sub = {k: cache[k][n_dense:] for k in mixer_keys}
    x, outs = _iterate(cfg, body, x, (layers["blocks"], sub))
    if n_dense:
        outs = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                            outs_d, outs)
    return x, outs


def _hybrid_decode(params, cfg, x, cache):
    pos = cache["pos"]
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    blocks = params["layers"]["blocks"]
    shared = params["layers"]["shared_attn"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), blocks)
    g_conv = cache["conv"].reshape((n_groups, every) + cache["conv"].shape[1:])
    g_ssm = cache["ssm"].reshape((n_groups, every) + cache["ssm"].shape[1:])

    def group_body(carry, scanned):
        p_group, conv_g, ssm_g, k_g, v_g = scanned

        def inner(c, s):
            p_l, conv_l, ssm_l = s
            y = nn.norm_apply(cfg.norm, p_l["norm"], c)
            out, state = ssd_lib.ssd_block_step(
                p_l["mixer"], cfg, y, {"conv": conv_l, "ssm": ssm_l})
            return c + out, (state["conv"], state["ssm"])

        h, (conv_new, ssm_new) = _iterate(cfg, inner, carry,
                                          (p_group, conv_g, ssm_g))
        h, mc = _attn_block_step(shared, cfg, h, {"k": k_g, "v": v_g}, pos,
                                 has_moe=False)
        return h, (conv_new, ssm_new, mc["k"], mc["v"])

    x, (conv_new, ssm_new, k_new, v_new) = _iterate(
        cfg, group_body, x,
        (grouped, g_conv, g_ssm, cache["k"], cache["v"]))
    return x, {
        "conv": conv_new.reshape(cache["conv"].shape),
        "ssm": ssm_new.reshape(cache["ssm"].shape),
        "k": k_new, "v": v_new,
    }


# ===========================================================================
# Prefill: parallel pass over the prompt that seeds the decode caches
# ===========================================================================

def _attn_block_prefill(p, cfg, x, positions, *, has_moe, lengths=None):
    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    y = nn.norm_apply(cfg.norm, p["norm1"], x, **nk)
    if cfg.seq_mixer in _MIN_CELLS:
        cell = _MIN_CELLS[cfg.seq_mixer]
        mode = cfg.minrnn.mode if cfg.minrnn else "log"
        h = cell.parallel(p["mixer"]["rnn"], y, mode=mode,
                          compute_dtype=cfg.cdtype,
                          scan_strategy=cfg.scan_strategy)
        out = nn.dense_apply(p["mixer"]["down"], h, cfg.cdtype)
        mix_cache = {"h": h[:, -1] if lengths is None
                     else nn.gather_last(h, lengths)}
    elif cfg.attn_kind == "mla":
        out, ckv, krope = attn.mla_prefill(p["mixer"], cfg, y,
                                           positions=positions)
        mix_cache = {"ckv": ckv, "krope": krope}
    else:
        out, k, v = attn.gqa_prefill(p["mixer"], cfg, y, positions=positions)
        mix_cache = {"k": k, "v": v}
    x = x + out
    y = nn.norm_apply(cfg.norm, p["norm2"], x, **nk)
    if has_moe:
        out, _ = moe_lib.moe_apply(p["moe"], cfg, y,
                                   activation=cfg.mlp_activation)
    else:
        out = mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                        compute_dtype=cfg.cdtype)
    return x + out, mix_cache


def _seed_kv(full, max_len):
    """(L, B, T, ...) prompt kv -> (L, B, max_len, ...) zero-padded cache."""
    t = full.shape[2]
    pad = [(0, 0)] * full.ndim
    pad[2] = (0, max_len - t)
    return jnp.pad(full, pad)


def supports_chunked_prefill(cfg) -> bool:
    """True when ``prefill`` can resume from a carried cache, i.e. the whole
    decode state is a constant-size recurrence (the paper's minRNN family).
    KV/SSD caches would need offset-aware attention / state-resumed chunk
    scans; those archs prefill whole-prompt instead."""
    return cfg.block_kind == "minrnn"


def prefill(params, cfg, tokens: Array, max_len: int, *,
            patch_embeds: Optional[Array] = None,
            lengths: Optional[Array] = None,
            cache: Optional[Dict[str, Any]] = None
            ) -> Tuple[Array, Dict[str, Any]]:
    """Parallel prompt processing.  Returns (last-token logits (B, V), cache
    ready for decode_step).  This is the paper's headline win: the prompt is
    one parallel scan, not T sequential cell evaluations.

    ``lengths`` (B,) int32 enables *batched* prefill of right-padded
    variable-length prompts: row b's logits/state are taken at its true
    terminal position ``lengths[b]-1``.  Every mixer is causal, so positions
    before the pad are bit-identical to an unpadded run; recurrent states
    are gathered per-row (SSD additionally masks dt so padded steps are
    inert), while KV caches may hold garbage beyond ``lengths[b]`` -- decode
    masks attention by the per-slot ``pos`` and overwrites those positions
    in place before they ever become visible.

    ``cache`` resumes prefill from a previous prefill's cache (chunked
    prefill); only supported for ``supports_chunked_prefill`` configs.
    """
    if cache is not None and not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"chunked prefill resume not supported for block_kind="
            f"{cfg.block_kind!r}")
    if lengths is not None and cfg.frontend == "patches":
        raise NotImplementedError("variable-length prefill with a patch "
                                  "frontend prefix is not supported")
    x = _embed(params, cfg, tokens, patch_embeds)
    bsz, t = x.shape[0], x.shape[1]
    positions = jnp.arange(t)[None, :]
    consumed = jnp.full((bsz,), t, jnp.int32) if lengths is None \
        else lengths.astype(jnp.int32)
    pos0 = cache["pos"] if cache is not None else 0
    new_cache: Dict[str, Any] = {"pos": pos0 + consumed}

    if cfg.block_kind == "minrnn":
        bc = _minrnn_block_cfg(cfg)

        if cache is not None:
            state0 = {"h": cache["h"]}
            if bc.use_conv:
                state0["conv"] = cache["conv"]

            def body_r(carry, scanned):
                p_l, st_l = scanned
                h, state = minrnn_blocks.apply(p_l, bc, carry, state0=st_l,
                                               lengths=lengths,
                                               compute_dtype=cfg.cdtype,
                                               scan_strategy=cfg.scan_strategy,
                                               return_state=True)
                return h, state

            x, states = _scan_layers(cfg, body_r, x,
                                     (params["layers"]["blocks"], state0))
        else:
            def body(carry, p_l):
                h, state = minrnn_blocks.apply(p_l, bc, carry,
                                               lengths=lengths,
                                               compute_dtype=cfg.cdtype,
                                               scan_strategy=cfg.scan_strategy,
                                               return_state=True)
                return h, state

            x, states = _scan_layers(cfg, body, x,
                                     params["layers"]["blocks"])
        new_cache["h"] = states["h"]
        if bc.use_conv:
            new_cache["conv"] = states["conv"]

    elif cfg.block_kind == "ssm":
        def body(carry, p_l):
            nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
            y = nn.norm_apply(cfg.norm, p_l["norm"], carry, **nk)
            out, state = ssd_lib.ssd_block_apply(p_l["mixer"], cfg, y,
                                                 return_state=True,
                                                 lengths=lengths)
            return carry + out, state

        x, states = _scan_layers(cfg, body, x, params["layers"]["blocks"])
        new_cache["conv"] = states["conv"]
        new_cache["ssm"] = states["ssm"]

    elif cfg.block_kind == "hybrid":
        x, cache_h = _hybrid_prefill(params, cfg, x, positions, max_len,
                                     lengths=lengths)
        new_cache.update(cache_h)

    else:
        layers = params["layers"]
        has_moe = cfg.moe is not None
        mix_caches = []

        if "dense_blocks" in layers:
            def body_d(carry, p_l):
                return _attn_block_prefill(p_l, cfg, carry, positions,
                                           has_moe=False, lengths=lengths)

            x, mc_d = _scan_layers(cfg, body_d, x, layers["dense_blocks"])
            mix_caches.append(mc_d)

        def body(carry, p_l):
            return _attn_block_prefill(p_l, cfg, carry, positions,
                                       has_moe=has_moe, lengths=lengths)

        x, mc = _scan_layers(cfg, body, x, layers["blocks"])
        mix_caches.append(mc)
        if len(mix_caches) == 2:
            mc = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                              mix_caches[0], mix_caches[1])
        else:
            mc = mix_caches[0]
        if "h" in mc:
            new_cache["h"] = mc["h"]
        elif "ckv" in mc:
            new_cache["ckv"] = _seed_kv(mc["ckv"], max_len)
            new_cache["krope"] = _seed_kv(mc["krope"], max_len)
        else:
            new_cache["k"] = _seed_kv(mc["k"], max_len)
            new_cache["v"] = _seed_kv(mc["v"], max_len)

    nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
    x_last = x[:, -1] if lengths is None else nn.gather_last(x, lengths)
    x_last = nn.norm_apply(cfg.norm, params["final_norm"], x_last, **nk)
    return _logits(params, cfg, x_last), new_cache


def _hybrid_prefill(params, cfg, x, positions, max_len, lengths=None):
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    blocks = params["layers"]["blocks"]
    shared = params["layers"]["shared_attn"]
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), blocks)

    def group_body(carry, p_group):
        def inner(c, p_l):
            nk = dict(zero_centered=True) if cfg.norm_zero_centered else {}
            y = nn.norm_apply(cfg.norm, p_l["norm"], c, **nk)
            out, state = ssd_lib.ssd_block_apply(p_l["mixer"], cfg, y,
                                                 return_state=True,
                                                 lengths=lengths)
            return c + out, state

        h, states = _iterate(cfg, inner, carry, p_group)
        h, mc = _attn_block_prefill(shared, cfg, h, positions, has_moe=False,
                                    lengths=lengths)
        return h, (states, mc)

    x, (states, mc) = _iterate(cfg, _remat(cfg, group_body), x, grouped)
    conv = states["conv"].reshape((-1,) + states["conv"].shape[2:])
    ssm = states["ssm"].reshape((-1,) + states["ssm"].shape[2:])
    return x, {"conv": conv, "ssm": ssm,
               "k": _seed_kv(mc["k"], max_len),
               "v": _seed_kv(mc["v"], max_len)}
