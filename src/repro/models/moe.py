"""Mixture-of-Experts layer (deepseek-moe-16b / deepseek-v3-671b).

Expert parallelism maps experts onto the ``model`` mesh axis with activations
replicated across it (Megatron-style TP semantics): inside a shard_map each
model-rank routes the full local token shard, processes only its E/TP
experts with capacity-bounded scatter dispatch, and a single psum over
``model`` combines contributions -- the only collective the layer needs
(same bytes as one TP all-reduce; see EXPERIMENTS.md §Roofline).

Dispatch is scatter-based (GShard-style one-hot cumsum positions) but
iterates the top-k assignments one slot at a time so the transient gather
buffer is (N, d), not (N*k, d) -- at deepseek-v3 scale that is the
difference between 0.9 GB and 7.5 GB per device per layer.

The no-mesh path runs the identical body with one expert group, so EP
correctness is testable on a single device.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import nn
from repro.distributed import context as mesh_ctx

Array = jax.Array


def moe_init(key, cfg, *, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": nn.dense_init(ks[0], d, m.n_experts, use_bias=False,
                                dtype=jnp.float32),   # router kept fp32
        "gate_w": _expert_init(ks[1], m.n_experts, d, m.d_expert, dtype),
        "up_w": _expert_init(ks[2], m.n_experts, d, m.d_expert, dtype),
        "down_w": _expert_init(ks[3], m.n_experts, m.d_expert, d, dtype),
    }
    if m.n_shared:
        from repro.models.mlp import mlp_init
        p["shared"] = mlp_init(ks[4], d, m.d_shared, gated=True, dtype=dtype)
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    import math
    std = math.sqrt(1.0 / d_in)
    return {"kernel": (std * jax.random.truncated_normal(
        key, -2.0, 2.0, (e, d_in, d_out))).astype(dtype)}


# ---------------------------------------------------------------------------
# Expert-group body (runs per model-rank under shard_map, or standalone)
# ---------------------------------------------------------------------------

def _moe_body(router_w, gate_w, up_w, down_w, x, *, cfg, n_local: int,
              e_offset, activation: str, psum_axis: Optional[str],
              dp_axes: Tuple[str, ...], fsdp_axis: Optional[str] = None):
    """x: (N, d) local tokens; expert weights are this rank's shard."""
    m = cfg.moe
    n, d = x.shape
    k = m.top_k
    cap = max(1, int(m.capacity_factor * n * k / m.n_experts))

    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (N, E)
    topk_p, topk_i = lax.top_k(probs, k)                       # (N, k)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)  # renormalize
    topk_p = topk_p.astype(x.dtype)

    # --- auxiliary load-balance loss (computed on the full router) ---------
    # pmean the per-expert statistics over dp FIRST so the EP aux equals
    # the single-device (global-batch) computation exactly
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(topk_i, m.n_experts), axis=1),
                   axis=0)                                     # (E,)
    p_e = jnp.mean(probs, axis=0)
    if psum_axis is not None and dp_axes:
        f_e = lax.pmean(f_e, dp_axes)
        p_e = lax.pmean(p_e, dp_axes)
    aux = m.n_experts * jnp.sum(f_e * p_e) / k

    # --- dispatch: one top-k slot at a time ---------------------------------
    local_i = topk_i - e_offset                                # (N, k)
    is_local = (local_i >= 0) & (local_i < n_local)
    safe_i = jnp.where(is_local, local_i, n_local)             # junk bucket
    # position of each assignment inside its expert, counted over (slot, token)
    onehot = jax.nn.one_hot(safe_i, n_local + 1, dtype=jnp.int32)  # (N,k,E+1)
    flat_oh = onehot.reshape(n * k, n_local + 1)
    pos = (jnp.cumsum(flat_oh, axis=0) * flat_oh).sum(-1).reshape(n, k) - 1
    keep = is_local & (pos < cap)
    dump_e = jnp.where(keep, safe_i, n_local)                  # junk expert
    dump_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((n_local + 1, cap, d), x.dtype)
    for slot in range(k):
        buf = buf.at[dump_e[:, slot], dump_c[:, slot]].add(
            jnp.where(keep[:, slot, None], x, 0))
    buf = buf[:n_local]                                        # drop junk

    # --- expert FFN (batched over the local expert group, MXU) -------------
    # fsdp_axis set => 2D expert parallelism: weights stay sharded over
    # (expert=model, d=data); each data-rank contracts its d-slice and the
    # (E_l, cap, f)-sized partials are psum'd -- activation-sized
    # collectives instead of re-gathering the weights every call (the
    # gather was 1.4 GB/layer vs 168 MB of activations at deepseek-v3
    # train, and catastrophic at decode -- EXPERIMENTS.md §Perf).
    act = nn.ACTIVATIONS[activation]
    gw = gate_w.astype(x.dtype)
    uw = up_w.astype(x.dtype)
    dw = down_w.astype(x.dtype)
    if fsdp_axis is not None:
        # all-to-all transpose: (E_l, C_local, d) batch-sharded rows ->
        # (E_l, C_local*Dd, d/Dd) -- every data-rank sees ALL dispatched
        # rows but only its d-slice, matching the weight sharding
        buf2 = lax.all_to_all(buf, fsdp_axis, split_axis=2, concat_axis=1,
                              tiled=True)
        gate_h = lax.psum(jnp.einsum("ecd,edf->ecf", buf2, gw), fsdp_axis)
        up_h = lax.psum(jnp.einsum("ecd,edf->ecf", buf2, uw), fsdp_axis)
        h = act(gate_h) * up_h
        out_slice = jnp.einsum("ecf,efd->ecd", h, dw)  # (E_l, C*Dd, d/Dd)
        out_buf = lax.all_to_all(out_slice, fsdp_axis, split_axis=1,
                                 concat_axis=2, tiled=True)
    else:
        h = act(jnp.einsum("ecd,edf->ecf", buf, gw)) * \
            jnp.einsum("ecd,edf->ecf", buf, uw)
        out_buf = jnp.einsum("ecf,efd->ecd", h, dw)            # (E_l, cap, d)

    # --- combine ------------------------------------------------------------
    y = jnp.zeros((n, d), x.dtype)
    for slot in range(k):
        rows = out_buf[jnp.where(keep[:, slot], safe_i[:, slot], 0),
                       dump_c[:, slot]]
        y = y + jnp.where(keep[:, slot, None],
                          rows * topk_p[:, slot, None], 0)
    if psum_axis is not None:
        y = lax.psum(y, psum_axis)
    return y, aux


def moe_apply(params, cfg, x: Array, *, activation: str = "silu"
              ) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).  EP over the mesh 'model' axis."""
    m = cfg.moe
    bsz, s, d = x.shape
    mesh = mesh_ctx.current_mesh()
    ep = mesh_ctx.axis_size(mesh, "model")
    use_ep = (ep > 1 and m.n_experts % ep == 0
              and not mesh_ctx.pure_dp())

    router_w = params["router"]["kernel"]

    if not use_ep:
        y, aux = _moe_body(router_w, params["gate_w"]["kernel"],
                           params["up_w"]["kernel"],
                           params["down_w"]["kernel"],
                           x.reshape(-1, d), cfg=cfg,
                           n_local=m.n_experts, e_offset=0,
                           activation=activation, psum_axis=None, dp_axes=())
        y = y.reshape(bsz, s, d)
    else:
        dp = mesh_ctx.dp_axes(mesh)
        n_local = m.n_experts // ep
        # 2D EP when the fsdp axis divides d: expert weights stay sharded
        # (expert -> model, d -> data); never re-gathered.  "auto" enables
        # it when the dispatched-row all-to-all is cheaper than the weight
        # gather -- empirically cap*4 < d_expert (decode: cap ~ 1; train at
        # 1M tokens: cap ~ 1280 where the gather wins; §Perf D)
        d_size = mesh_ctx.axis_size(mesh, "data")
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        n_tok = max(1, bsz * s // dp_size)    # tokens per rank
        cap_est = max(1, int(m.capacity_factor * n_tok * m.top_k
                             / m.n_experts))
        if m.ep_2d == "on":
            want_2d = True
        elif m.ep_2d == "off":
            want_2d = False
        else:
            want_2d = cap_est * 4 < m.d_expert
        use_2d = (want_2d and d_size > 1 and d % d_size == 0)
        fsdp_axis = "data" if use_2d else None
        gw_spec = P("model", "data" if use_2d else None, None)
        dw_spec = P("model", None, "data" if use_2d else None)

        def body(router_w, gw, uw, dw, x_loc):
            n_loc = x_loc.shape[0] * x_loc.shape[1]
            e_off = lax.axis_index("model") * n_local
            y, aux = _moe_body(router_w, gw, uw, dw,
                               x_loc.reshape(n_loc, d), cfg=cfg,
                               n_local=n_local, e_offset=e_off,
                               activation=activation, psum_axis="model",
                               dp_axes=dp, fsdp_axis=fsdp_axis)
            return y.reshape(x_loc.shape), aux

        y, aux = mesh_ctx.shard_map(
            body, mesh=mesh,
            in_specs=(P(), gw_spec, gw_spec, dw_spec,
                      P(dp, None, None)),
            out_specs=(P(dp, None, None), P()),
        )(router_w, params["gate_w"]["kernel"], params["up_w"]["kernel"],
          params["down_w"]["kernel"], x)

    if m.n_shared:
        from repro.models.mlp import mlp_apply
        y = y + mlp_apply(params["shared"], x, activation=activation,
                          compute_dtype=cfg.cdtype)
    return y, aux
