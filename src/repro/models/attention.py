"""Attention mixers: GQA (blocked / flash-style) and MLA (deepseek-v3).

Blocked attention keeps activation memory O(T * chunk) instead of O(T^2):
the query axis is tiled (python loop -> unrolled HLO; layers are scanned so
this stays compact) and each query tile runs an online-softmax scan over
only the kv tiles it can see -- strictly-causal tiles are never computed,
so HLO FLOPs track the true T^2/2 cost (roofline honesty, DESIGN §4).

MLA follows deepseek-v3: low-rank q/kv compression, decoupled rope head,
and the *absorbed* decode path that attends directly in the compressed
latent space (cache = kv_lora + rope_dim per token, not heads * head_dim).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import nn
from repro.distributed.act_sharding import constrain
from repro.models.rope import apply_rope

Array = jax.Array


# ---------------------------------------------------------------------------
# GQA parameters
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, *, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    bias = cfg.attn_bias
    return {
        "wq": nn.dense_init(ks[0], d, h * hd, use_bias=bias, dtype=dtype),
        "wk": nn.dense_init(ks[1], d, kv * hd, use_bias=bias, dtype=dtype),
        "wv": nn.dense_init(ks[2], d, kv * hd, use_bias=bias, dtype=dtype),
        "wo": nn.dense_init(ks[3], h * hd, d, use_bias=bias, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Blocked multi-head attention core
# ---------------------------------------------------------------------------

def _attend_tiles(q: Array, k: Array, v: Array, mask_bias: Optional[Array],
                  scale: float) -> Tuple[Array, Array, Array]:
    """One (q-tile, kv-tile) step of online softmax.

    q: (B, Tq, K, G, D); k, v: (B, Tk, K, D).  Returns (m, l, o) updates.
    """
    s = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32) * scale
    if mask_bias is not None:
        s = s + mask_bias                      # (Tq, Tk) broadcast
    m = jnp.max(s, axis=-1)                    # (B, K, G, Tq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgts,bskd->bkgtd", p.astype(v.dtype), v)
    return m, l, o


def blocked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      q_offset: int = 0) -> Array:
    """q: (B, Tq, H, D); k, v: (B, Tk, KV, D) -> (B, Tq, H, D).

    ``q_offset`` positions q relative to k (prefill continuation / decode).
    """
    bsz, tq, h, d = q.shape
    tk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    q = q.reshape(bsz, tq, kv, g, d)

    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    # pad kv to a tile multiple; padded keys are masked via k_ids < tk
    pad_k = (-tk) % kv_chunk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = -(-tq // q_chunk)
    neg = jnp.float32(-1e30)

    out_tiles = []
    for qi in range(nq):
        q0 = qi * q_chunk
        q_tile = lax.slice_in_dim(q, q0, min(q0 + q_chunk, tq), axis=1)
        tq_t = q_tile.shape[1]
        q_pos_end = q_offset + q0 + tq_t        # exclusive
        # kv tiles this q tile can see
        nk_vis = -(-min(tk, q_pos_end) // kv_chunk) if causal \
            else -(-tk // kv_chunk)
        nk_vis = max(nk_vis, 1)

        def kv_step(carry, ki):
            m_run, l_run, o_run = carry
            k_tile = lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            v_tile = lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            k_ids = ki * kv_chunk + jnp.arange(kv_chunk)
            valid = (k_ids < tk)[None, :]
            if causal:
                q_ids = q_offset + q0 + jnp.arange(tq_t)
                valid = valid & (q_ids[:, None] >= k_ids[None, :])
            bias = jnp.where(valid, 0.0, neg).astype(jnp.float32)
            m_new, l_new, o_new = _attend_tiles(q_tile, k_tile, v_tile,
                                                bias, scale)
            m_tot = jnp.maximum(m_run, m_new)
            c_run = jnp.exp(m_run - m_tot)
            c_new = jnp.exp(m_new - m_tot)
            l_tot = l_run * c_run + l_new * c_new
            o_tot = (o_run * c_run[..., None].astype(o_run.dtype)
                     + o_new * c_new[..., None].astype(o_new.dtype))
            return (m_tot, l_tot, o_tot), None

        m0 = jnp.full((bsz, kv, g, tq_t), -1e30, jnp.float32)
        l0 = jnp.zeros((bsz, kv, g, tq_t), jnp.float32)
        o0 = jnp.zeros((bsz, kv, g, tq_t, d), v.dtype)
        # remat the kv-tile body: backward recomputes the (Tq, Tk) score
        # tile instead of saving it -- the flash-attention memory trade,
        # O(T * tile) activations instead of O(T^2)
        (m_f, l_f, o_f), _ = lax.scan(jax.checkpoint(kv_step), (m0, l0, o0),
                                      jnp.arange(nk_vis))
        o_f = o_f / jnp.maximum(l_f, 1e-20)[..., None].astype(o_f.dtype)
        out_tiles.append(o_f)                  # (B, KV, G, Tq_t, D)

    out = jnp.concatenate(out_tiles, axis=3)   # (B, KV, G, Tq, D)
    return jnp.moveaxis(out, 3, 1).reshape(bsz, tq, h, d)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     length: Array) -> Array:
    """Single-token attention. q: (B, H, D); caches: (B, S, KV, D)."""
    bsz, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(bsz, kv, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    s = jnp.where(pos[None, None, None, :] < length[:, None, None, None],
                  s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return o.reshape(bsz, h, d)


# ---------------------------------------------------------------------------
# GQA apply (parallel / decode)
# ---------------------------------------------------------------------------

def gqa_apply(params, cfg, x: Array, *, positions: Array, causal: bool,
              kv: Optional[Tuple[Array, Array]] = None,
              q_offset: int = 0) -> Array:
    """Full-sequence attention. kv != None -> cross attention over kv."""
    bsz, t, _ = x.shape
    h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    cd = cfg.cdtype
    q = constrain(nn.dense_apply(params["wq"], x, cd
                                  ).reshape(bsz, t, h, hd),
                  "dp", None, "tp", None)
    if kv is None:
        k = constrain(nn.dense_apply(params["wk"], x, cd
                                     ).reshape(bsz, t, n_kv, hd),
                      "dp", None, "tp", None)
        v = constrain(nn.dense_apply(params["wv"], x, cd
                                     ).reshape(bsz, t, n_kv, hd),
                      "dp", None, "tp", None)
        if cfg.rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv
    o = blocked_attention(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk, q_offset=q_offset)
    return nn.dense_apply(params["wo"], o.reshape(bsz, t, h * hd), cd)


def gqa_project_kv(params, cfg, x: Array, positions: Optional[Array] = None):
    """Project k, v for caching (self) or cross-attention (encoder out)."""
    bsz, t, _ = x.shape
    n_kv, hd = cfg.n_kv_heads, cfg.head_dim_
    cd = cfg.cdtype
    k = constrain(nn.dense_apply(params["wk"], x, cd
                                 ).reshape(bsz, t, n_kv, hd),
                  "dp", None, "tp", None)
    v = constrain(nn.dense_apply(params["wv"], x, cd
                                 ).reshape(bsz, t, n_kv, hd),
                  "dp", None, "tp", None)
    if cfg.rope and positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_prefill(params, cfg, x: Array, *, positions: Array):
    """Causal self-attention over the prompt; returns (out, k, v) so the
    caches can be seeded for decode."""
    bsz, t, _ = x.shape
    h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    cd = cfg.cdtype
    q = constrain(nn.dense_apply(params["wq"], x, cd
                                  ).reshape(bsz, t, h, hd),
                  "dp", None, "tp", None)
    k = constrain(nn.dense_apply(params["wk"], x, cd
                                 ).reshape(bsz, t, n_kv, hd),
                  "dp", None, "tp", None)
    v = constrain(nn.dense_apply(params["wv"], x, cd
                                 ).reshape(bsz, t, n_kv, hd),
                  "dp", None, "tp", None)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = blocked_attention(q, k, v, causal=True, q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk)
    out = nn.dense_apply(params["wo"], o.reshape(bsz, t, h * hd), cd)
    return out, k, v


def mla_prefill(params, cfg, x: Array, *, positions: Array):
    """MLA prefill; returns (out, c_kv, k_rope) latent caches."""
    bsz, t, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.mla_qk_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    cd = cfg.cdtype
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope = nn.dense_apply(params["wk_b"], c_kv, cd).reshape(bsz, t, h, nope)
    v = nn.dense_apply(params["wv_b"], c_kv, cd).reshape(bsz, t, h, vd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (bsz, t, h, rope_d))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = blocked_attention(q, k,
                          jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                      (0, nope + rope_d - vd))),
                          causal=True, q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk)[..., :vd]
    out = nn.dense_apply(params["wo"], o.reshape(bsz, t, h * vd), cd)
    return out, c_kv, k_rope


def gqa_decode_step(params, cfg, x_t: Array, k_cache: Array, v_cache: Array,
                    pos: Array):
    """x_t: (B, d_model); caches (B, S, KV, D); pos: (B,) current index.

    Returns (out_t, k_cache, v_cache) with the new token inserted.
    """
    bsz = x_t.shape[0]
    h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    cd = cfg.cdtype
    q = constrain(nn.dense_apply(params["wq"], x_t, cd
                                  ).reshape(bsz, h, hd), "dp", "tp", None)
    k = constrain(nn.dense_apply(params["wk"], x_t, cd
                                 ).reshape(bsz, n_kv, hd), "dp", "tp", None)
    v = constrain(nn.dense_apply(params["wv"], x_t, cd
                                 ).reshape(bsz, n_kv, hd), "dp", "tp", None)
    if cfg.rope:
        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k_cache = _cache_insert(k_cache, k, pos)
    v_cache = _cache_insert(v_cache, v, pos)
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    out = nn.dense_apply(params["wo"], o.reshape(bsz, h * hd), cd)
    return out, k_cache, v_cache


def _cache_insert(cache: Array, new: Array, pos: Array) -> Array:
    """cache: (B, S, ...); new: (B, ...); pos: (B,) -- scatter at [b, pos[b]]."""
    onehot = jax.nn.one_hot(pos, cache.shape[1], dtype=cache.dtype)
    expand = (...,) + (None,) * (cache.ndim - 2)
    return cache * (1.0 - onehot[expand]).astype(cache.dtype) + \
        onehot[expand] * new[:, None]


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, *, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.mla_q_lora, cfg.mla_kv_lora
    nope, rope_d, vd = cfg.mla_qk_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": nn.dense_init(ks[0], d, qr, use_bias=False, dtype=dtype),
        "q_norm": nn.rmsnorm_init(qr, dtype),
        "wq_b": nn.dense_init(ks[1], qr, h * (nope + rope_d), use_bias=False,
                              dtype=dtype),
        "wkv_a": nn.dense_init(ks[2], d, kvr + rope_d, use_bias=False,
                               dtype=dtype),
        "kv_norm": nn.rmsnorm_init(kvr, dtype),
        "wk_b": nn.dense_init(ks[3], kvr, h * nope, use_bias=False,
                              dtype=dtype),
        "wv_b": nn.dense_init(ks[4], kvr, h * vd, use_bias=False, dtype=dtype),
        "wo": nn.dense_init(ks[5], h * vd, d, use_bias=False, dtype=dtype),
    }


def _mla_qkv(params, cfg, x, positions):
    bsz, t, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.mla_qk_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kvr = cfg.mla_kv_lora
    cd = cfg.cdtype
    q = constrain(
        nn.dense_apply(params["wq_b"],
                       nn.rmsnorm_apply(params["q_norm"],
                                        nn.dense_apply(params["wq_a"], x, cd)),
                       cd).reshape(bsz, t, h, nope + rope_d),
        "dp", None, "tp", None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = nn.dense_apply(params["wkv_a"], x, cd)
    c_kv = nn.rmsnorm_apply(params["kv_norm"], kv[..., :kvr])
    k_rope = apply_rope(kv[..., kvr:], positions, cfg.rope_theta)  # (B,T,rd)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params, cfg, x: Array, *, positions: Array,
              causal: bool = True) -> Array:
    """Training / prefill MLA: expand the latent and run blocked attention."""
    bsz, t, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.mla_qk_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    cd = cfg.cdtype
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope = nn.dense_apply(params["wk_b"], c_kv, cd).reshape(bsz, t, h, nope)
    v = nn.dense_apply(params["wv_b"], c_kv, cd).reshape(bsz, t, h, vd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (bsz, t, h, rope_d))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v to qk head dim so the blocked kernel sees one head size
    o = blocked_attention(q, k,
                          jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                      (0, nope + rope_d - vd))),
                          causal=causal, q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk)[..., :vd]
    return nn.dense_apply(params["wo"], o.reshape(bsz, t, h * vd), cd)


def mla_decode_step(params, cfg, x_t: Array, ckv_cache: Array,
                    krope_cache: Array, pos: Array):
    """Absorbed-latent decode: attend in the compressed kv space.

    ckv_cache: (B, S, kv_lora); krope_cache: (B, S, rope_dim).
    """
    bsz = x_t.shape[0]
    h = cfg.n_heads
    nope, rope_d, vd = cfg.mla_qk_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kvr = cfg.mla_kv_lora
    cd = cfg.cdtype
    x = x_t[:, None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, pos[:, None])
    ckv_cache = _cache_insert(ckv_cache, c_kv[:, 0], pos)
    krope_cache = _cache_insert(krope_cache, k_rope[:, 0], pos)

    # absorb k_up into q: q_lat (B, H, kvr)
    wk_b = params["wk_b"]["kernel"].astype(cd).reshape(kvr, h, nope)
    q_lat = jnp.einsum("bhn,khn->bhk", q_nope[:, 0], wk_b)
    scale = 1.0 / math.sqrt(nope + rope_d)
    s = (jnp.einsum("bhk,bsk->bhs", q_lat, ckv_cache)
         + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0], krope_cache)
         ).astype(jnp.float32) * scale
    s = jnp.where(jnp.arange(ckv_cache.shape[1])[None, None, :]
                  < (pos + 1)[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(cd)
    o_lat = jnp.einsum("bhs,bsk->bhk", p, ckv_cache)
    wv_b = params["wv_b"]["kernel"].astype(cd).reshape(kvr, h, vd)
    o = jnp.einsum("bhk,khv->bhv", o_lat, wv_b)
    out = nn.dense_apply(params["wo"], o.reshape(bsz, h * vd), cd)
    return out, ckv_cache, krope_cache
