"""Mamba2 / SSD (state-space duality) sequence mixer.

The SSD recurrence is the matrix-valued generalization of the paper's
minGRU recurrence:

    H_t = a_t * H_{t-1} + dt_t * B_t (x) x_t        H: (heads, hd, d_state)
    y_t = C_t . H_t + D * x_t

with scalar-per-head decay a_t = exp(-softplus-free A * dt_t).  Training
uses the chunked dual form (Dao & Gu 2024) adapted to the TPU MXU: the
intra-chunk part is (C B^T ⊙ decay-mask) @ X -- attention-like matmuls --
and the inter-chunk part is exactly the paper's linear scan over the chunk
states, reusing ``repro.core.scan`` (DESIGN.md §5: mamba2 is scan-family).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import nn
from repro.core import scan as scan_lib

Array = jax.Array


def ssd_init(key, cfg, *, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nh = s.n_heads(d)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    p = {
        "in_proj": nn.dense_init(ks[0], d, proj_out, use_bias=False,
                                 dtype=dtype),
        "conv": nn.causal_conv_init(
            ks[1], d_in + 2 * s.n_groups * s.d_state, s.conv_kernel, dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[2], (nh,), minval=math.log(1e-3), maxval=math.log(1e-1))))
        ).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": nn.rmsnorm_init(d_in, dtype),
        "out_proj": nn.dense_init(ks[3], d_in, d, use_bias=False,
                                  dtype=dtype),
    }
    return p


def _split_proj(cfg, zxbcdt: Array):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    gs = s.n_groups * s.d_state
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    b = zxbcdt[..., 2 * d_in:2 * d_in + gs]
    c = zxbcdt[..., 2 * d_in + gs:2 * d_in + 2 * gs]
    dt = zxbcdt[..., 2 * d_in + 2 * gs:]
    return z, x, b, c, dt


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                d_skip: Array, chunk: int, return_state: bool = False,
                form: str = "masked"):
    """Chunked SSD.

    x:  (B, T, H, P)   heads x head_dim
    dt: (B, T, H)      softplus-ed step sizes
    b, c: (B, T, G, N) groups broadcast over heads
    returns y: (B, T, H, P)
    """
    bsz, t, h, p = x.shape
    g, n = b.shape[-2], b.shape[-1]
    rep = h // g
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = x.shape[1]
    nc = tt // chunk

    # log decay per step: log a_t = -exp(a_log) * dt
    log_a = (-jnp.exp(a_log)[None, None, :] * dt).astype(jnp.float32)

    def ch(v):      # (B, T, ...) -> (B, nc, L, ...)
        return v.reshape((bsz, nc, chunk) + v.shape[2:])

    xc, dtc, bc, cc = ch(x), ch(dt), ch(b), ch(c)
    lac = ch(log_a)                                   # (B, nc, L, H)
    cum = jnp.cumsum(lac, axis=2)                     # within-chunk cumsum
    total = cum[:, :, -1]                             # (B, nc, H)
    xdt = xc * dtc[..., None]                         # (B,nc,L,H,P)

    if form == "masked":
        # ---- intra-chunk, masked dual form (Dao & Gu 2024 as published) --
        # M[i,j] = exp(cum[i] - cum[j]) for i >= j  (segment decay)
        # materializes (B,nc,L,L,H) fp32 -- the baseline's memory hot spot
        seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
        ii = jnp.arange(chunk)
        causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
        # double-where: exp(seg>0) on the masked triangle overflows and its
        # inf cotangent x 0 poisons training with NaNs (seen at fig2 step
        # ~150); clamp inside the mask so the gradient path stays finite
        m = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
        cb = jnp.einsum("bclgn,bcsgn->bclsg", cc, bc)     # (B,nc,L,L,G)
        cb = jnp.repeat(cb, rep, axis=-1)                 # -> heads
        y_intra = jnp.einsum("bclsh,bclsh,bcshp->bclhp",
                             cb, m.astype(cb.dtype), xdt)
    elif form == "compact":
        # ---- compact masked form (beyond-paper; EXPERIMENTS.md §Perf) ----
        # identical math; minimizes traffic over the (B,nc,L,L,H) weight:
        #   * ONE dtype cast on the small (B,nc,L,H) cum tensor, so every
        #     (L,L,H)-sized op runs in the compute dtype (bf16 at scale);
        #   * the causal mask is folded into the (L,L,G) CB^T tensor BEFORE
        #     the head broadcast (an (L,L,H) select never exists);
        #   * chain on (L,L,H): sub -> exp -> mul = 3 ops + the dot read,
        #     vs the baseline's f32 seg/exp/select/mul/convert chain.
        # (A clamped *factored* variant -- no (L,L,H) tensor at all -- was
        # tried first and REFUTED: with per-chunk decay > e^30 the
        # near-diagonal terms, whose true factor is ~1, lose all precision.
        # See §Perf iteration log.)
        cdt = x.dtype
        cum16 = cum.astype(cdt)
        ii = jnp.arange(chunk)
        causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
        cb = jnp.einsum("bclgn,bcsgn->bclsg", cc, bc)     # (B,nc,L,L,G)
        cb = jnp.where(causal, cb, 0.0)                   # mask pre-repeat
        seg = cum16[:, :, :, None, :] - cum16[:, :, None, :, :]
        # exp(seg) on the upper triangle can overflow (seg > 0 is masked
        # out by cb=0 anyway): clamp at 0 -- true decays are always <= 0
        w = jnp.exp(jnp.minimum(seg, 0)) * (
            jnp.repeat(cb, rep, axis=-1) if rep > 1 else cb)
        y_intra = jnp.einsum("bclsh,bcshp->bclhp", w, xdt.astype(cdt))
    else:
        raise ValueError(f"unknown SSD dual form {form!r}")

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)    # (B,nc,L,H)
    if form == "compact":
        # group-space contraction: never materialize the (B,nc,L,H,N)
        # head-repeated b/c (20x the group tensor at mamba2's g=1, H=32) --
        # EXPERIMENTS.md §Perf iteration 4
        v_g = (xdt * decay_to_end[..., None]).reshape(
            (bsz, nc, chunk, g, rep, p))
        states = jnp.einsum("bcsgn,bcsgrp->bcgrpn", bc, v_g)
        states = states.reshape(bsz, nc, h, p, n)
    else:
        b_heads = jnp.repeat(bc, rep, axis=-2) if rep > 1 else bc
        states = jnp.einsum("bcshn,bcshp->bchpn",
                            b_heads, xdt * decay_to_end[..., None])

    # ---- inter-chunk: the paper's linear scan over chunk states -----------
    a_chunk = jnp.exp(total)                              # (B, nc, H)
    flat_states = states.reshape(bsz, nc, h * p * n)
    a_bc = jnp.repeat(a_chunk, p * n, axis=-1)
    carried = scan_lib.scan_associative(a_bc, flat_states, axis=-2)
    carried = carried.reshape(bsz, nc, h, p, n)
    final_state = carried[:, -1]                          # (B, H, P, N)
    prev = jnp.concatenate(
        [jnp.zeros_like(carried[:, :1]), carried[:, :-1]], axis=1)

    # ---- inter-chunk contribution ------------------------------------------
    if form == "compact":
        prev_g = prev.reshape(bsz, nc, g, rep, p, n)
        y_inter = jnp.einsum("bclgn,bcgrpn->bclgrp", cc, prev_g
                             ).reshape(bsz, nc, chunk, h, p)
        y_inter = y_inter * jnp.exp(cum)[..., None].astype(y_inter.dtype)
    else:
        c_heads = jnp.repeat(cc, rep, axis=-2) if rep > 1 else cc
        y_inter = jnp.einsum("bclhn,bchpn->bclhp", c_heads, prev) * \
            jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(bsz, tt, h, p)[:, :t]
    y = y + x[:, :t] * d_skip[None, None, :, None].astype(x.dtype)
    if return_state:
        # padding is inert (a=1, update=0), so the last carried chunk state
        # is exactly the state after position t-1
        return y, final_state
    return y


def ssd_sequential(x, dt, a_log, b, c, d_skip,
                   h0: Optional[Array] = None):
    """Sequential reference (oracle + decode roll-out). Shapes as above."""
    bsz, t, h, p = x.shape
    g, n = b.shape[-2], b.shape[-1]
    rep = h // g
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        y_t, state = ssd_step(x_t, dt_t, a_log, b_t, c_t, d_skip, state)
        return state, y_t

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def ssd_step(x_t, dt_t, a_log, b_t, c_t, d_skip, state):
    """One decode step.  x_t: (B,H,P); b_t,c_t: (B,G,N); state: (B,H,P,N)."""
    h, p = x_t.shape[-2:]
    g = b_t.shape[-2]
    rep = h // g
    a_t = jnp.exp(-jnp.exp(a_log) * dt_t)                 # (B, H)
    b_heads = jnp.repeat(b_t, rep, axis=-2)               # (B, H, N)
    c_heads = jnp.repeat(c_t, rep, axis=-2)
    upd = (dt_t[..., None] * x_t)[..., None] * b_heads[..., None, :]
    state = a_t[..., None, None] * state + upd.astype(state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", state.astype(x_t.dtype), c_heads)
    return y + x_t * d_skip[None, :, None].astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# Full mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def ssd_block_apply(params, cfg, u: Array, *, chunk: Optional[int] = None,
                    return_state: bool = False,
                    lengths: Optional[Array] = None):
    """u: (B, T, d_model) -> (B, T, d_model) [, decode state].

    ``lengths`` (B,) supports right-padded variable-length batches: padded
    positions get dt = 0 (decay a = 1, update 0 -- an inert recurrence
    step, the same trick ``ssd_chunked`` uses for its own chunk padding),
    so the returned ssm state is exactly the state after ``lengths[b]``
    tokens; the conv window is gathered at each row's true position.
    """
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    cd = cfg.cdtype
    zxbcdt = nn.dense_apply(params["in_proj"], u, cd)
    z, x, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    conv_state = None
    if return_state:
        kk = s.conv_kernel - 1
        if lengths is not None:
            conv_state = nn.gather_conv_window(xbc, lengths, kk)
        else:
            pad = max(kk - xbc.shape[-2], 0)
            win = xbc[..., -kk:, :]
            if pad:
                win = jnp.concatenate(
                    [jnp.zeros(xbc.shape[:-2] + (pad, xbc.shape[-1]),
                               xbc.dtype), win], axis=-2)
            conv_state = win
    xbc = jax.nn.silu(nn.causal_conv_apply(params["conv"], xbc))
    x, b, c = (xbc[..., :d_in],
               xbc[..., d_in:d_in + s.n_groups * s.d_state],
               xbc[..., d_in + s.n_groups * s.d_state:])
    bsz, t, _ = x.shape
    x = x.reshape(bsz, t, nh, s.head_dim)
    b = b.reshape(bsz, t, s.n_groups, s.d_state)
    c = c.reshape(bsz, t, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    if lengths is not None:
        valid = (jnp.arange(t)[None, :] < lengths[:, None])
        dt = dt * valid[..., None]
        x = x * valid[..., None, None].astype(x.dtype)
    out = ssd_chunked(x, dt, params["a_log"], b, c, params["d_skip"],
                      chunk or s.chunk, return_state=return_state,
                      form=s.dual_form)
    if return_state:
        y, ssm_state = out
    else:
        y = out
    y = y.reshape(bsz, t, d_in)
    y = nn.rmsnorm_apply(params["out_norm"], y * jax.nn.silu(z))
    y = nn.dense_apply(params["out_proj"], y, cd)
    if return_state:
        return y, {"conv": conv_state, "ssm": ssm_state}
    return y


def ssd_block_init_state(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1,
                           d_in + 2 * s.n_groups * s.d_state), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def ssd_block_step(params, cfg, u_t: Array, state):
    """u_t: (B, d_model) single-token decode."""
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    cd = cfg.cdtype
    zxbcdt = nn.dense_apply(params["in_proj"], u_t, cd)
    z, x, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, b, c], axis=-1)
    xbc, conv_state = nn.causal_conv_step(params["conv"], xbc, state["conv"])
    xbc = jax.nn.silu(xbc)
    x, b, c = (xbc[..., :d_in],
               xbc[..., d_in:d_in + s.n_groups * s.d_state],
               xbc[..., d_in + s.n_groups * s.d_state:])
    bsz = x.shape[0]
    x = x.reshape(bsz, nh, s.head_dim)
    b = b.reshape(bsz, s.n_groups, s.d_state)
    c = c.reshape(bsz, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])
    y, ssm_state = ssd_step(x, dt, params["a_log"], b, c, params["d_skip"],
                            state["ssm"])
    y = y.reshape(bsz, d_in)
    y = nn.rmsnorm_apply(params["out_norm"], y * jax.nn.silu(z))
    out = nn.dense_apply(params["out_proj"], y, cd)
    return out, {"conv": conv_state, "ssm": ssm_state}
