"""Rotary position embeddings (RoPE)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(head_dim: int, theta: float) -> Array:
    """Inverse frequencies, (head_dim // 2,)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., T, H, D) or (..., T, D); positions: (..., T) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, d/2)
    if x.ndim == angles.ndim + 1:                      # (..., T, H, D)
        angles = angles[..., None, :]
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
