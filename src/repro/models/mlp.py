"""MLP variants: plain (starcoder2), GeGLU (gemma), SwiGLU (llama family)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import nn

Array = jax.Array


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool, bias: bool = False,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "up": nn.dense_init(ks[0], d_model, d_ff, use_bias=bias, dtype=dtype),
        "down": nn.dense_init(ks[1], d_ff, d_model, use_bias=bias,
                              dtype=dtype),
    }
    if gated:
        p["gate"] = nn.dense_init(ks[2], d_model, d_ff, use_bias=bias,
                                  dtype=dtype)
    return p


def mlp_apply(params, x: Array, *, activation: str = "silu",
              compute_dtype=None) -> Array:
    act = nn.ACTIVATIONS[activation]
    up = nn.dense_apply(params["up"], x, compute_dtype)
    if "gate" in params:
        gate = nn.dense_apply(params["gate"], x, compute_dtype)
        h = act(gate) * up
    else:
        h = act(up)
    return nn.dense_apply(params["down"], h, compute_dtype)


def mlp_flops(d_model: int, d_ff: int, gated: bool) -> int:
    """Matmul FLOPs per token (forward)."""
    n_mats = 3 if gated else 2
    return 2 * n_mats * d_model * d_ff
