"""Task heads over the minRNN trunk: sequence classification (selective
copy / Chomsky / LRA benches) and Decision-Transformer-style offline RL
(paper Table 3: minRNN -> MLP replacing self-attention in the DT frame).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import blocks as minrnn_blocks
from repro.core import nn

Array = jax.Array


# ---------------------------------------------------------------------------
# Sequence classifier: embed -> [blocks] -> last-position head
# ---------------------------------------------------------------------------

def classifier_init(key, *, vocab: int, n_classes: int, d_model: int,
                    n_layers: int, block_cfg: minrnn_blocks.MinRNNBlockConfig,
                    dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], n_layers)
    return {
        "embed": {"table": nn.normal_init(ks[1], (vocab, d_model), 0.02,
                                          dtype)},
        "blocks": jax.vmap(
            lambda k: minrnn_blocks.init(k, block_cfg, dtype=dtype)
        )(layer_keys),
        "final_norm": nn.norm_init(block_cfg.norm, d_model, dtype),
        "head": nn.dense_init(ks[2], d_model, n_classes, dtype=dtype),
    }


def classifier_apply(params, block_cfg, tokens: Array, *,
                     lengths=None) -> Array:
    """tokens: (B, T) -> logits (B, n_classes).  Pools at `lengths`-1 (the
    last real position) or at T-1."""
    x = params["embed"]["table"][tokens]

    def body(carry, p_l):
        return minrnn_blocks.apply(p_l, block_cfg, carry), None

    x, _ = lax.scan(body, x, params["blocks"])
    x = nn.norm_apply(block_cfg.norm, params["final_norm"], x)
    if lengths is None:
        pooled = x[:, -1]
    else:
        idx = jnp.maximum(lengths - 1, 0)
        pooled = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return nn.dense_apply(params["head"], pooled)


def classifier_loss(params, block_cfg, batch) -> Tuple[Array, Dict]:
    logits = classifier_apply(params, block_cfg, batch["tokens"],
                              lengths=batch.get("lengths"))
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    acc = jnp.mean((logits.argmax(-1) == labels).astype(jnp.float32))
    return jnp.mean(nll), {"loss": jnp.mean(nll), "acc": acc}


# ---------------------------------------------------------------------------
# Decision-Transformer-style trajectory model (paper App. C.2: minRNN->MLP)
# interleaves (rtg_t, s_t, a_t) tokens; predicts a_t from the s_t position.
# ---------------------------------------------------------------------------

def dt_init(key, *, state_dim: int, act_dim: int, d_model: int,
            n_layers: int, block_cfg: minrnn_blocks.MinRNNBlockConfig,
            dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], n_layers)
    return {
        "embed_s": nn.dense_init(ks[1], state_dim, d_model, dtype=dtype),
        "embed_a": nn.dense_init(ks[2], act_dim, d_model, dtype=dtype),
        "embed_r": nn.dense_init(ks[3], 1, d_model, dtype=dtype),
        "blocks": jax.vmap(
            lambda k: minrnn_blocks.init(k, block_cfg, dtype=dtype)
        )(layer_keys),
        "final_norm": nn.norm_init(block_cfg.norm, d_model, dtype),
        "head": nn.dense_init(ks[4], d_model, act_dim, dtype=dtype),
    }


def dt_apply(params, block_cfg, states: Array, actions: Array,
             rtg: Array) -> Array:
    """states (B,H,S), actions (B,H,A), rtg (B,H,1) -> predicted actions
    (B,H,A) from each state position (causal: a_t sees (R<=t, s<=t, a<t))."""
    b, h, _ = states.shape
    es = nn.dense_apply(params["embed_s"], states)
    ea = nn.dense_apply(params["embed_a"], actions)
    er = nn.dense_apply(params["embed_r"], rtg)
    # interleave (r_t, s_t, a_t): (B, 3H, D)
    x = jnp.stack([er, es, ea], axis=2).reshape(b, 3 * h, es.shape[-1])

    def body(carry, p_l):
        return minrnn_blocks.apply(p_l, block_cfg, carry), None

    x, _ = lax.scan(body, x, params["blocks"])
    x = nn.norm_apply(block_cfg.norm, params["final_norm"], x)
    s_positions = x[:, 1::3]                   # outputs at the s_t tokens
    return jnp.tanh(nn.dense_apply(params["head"], s_positions))


def dt_loss(params, block_cfg, batch) -> Tuple[Array, Dict]:
    pred = dt_apply(params, block_cfg, batch["states"], batch["actions"],
                    batch["rtg"])
    mse = jnp.mean((pred - batch["actions"]) ** 2)
    return mse, {"loss": mse}
