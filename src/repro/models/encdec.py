"""Whisper-style encoder-decoder (whisper-base backbone).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, frontend_dim).  The transformer
backbone is faithful: LayerNorm, GELU MLP, biased attention, learned
positional embeddings, bidirectional encoder, causal decoder with
cross-attention.  Decode caches self-attention kv per step and precomputes
cross-attention kv once at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import nn
from repro.distributed.act_sharding import constrain
from repro.models import attention as attn
from repro.models.mlp import mlp_apply, mlp_init


def _iterate(cfg, body, x, scanned):
    if cfg.scan_layers:
        return lax.scan(body, x, scanned)
    n = jax.tree.leaves(scanned)[0].shape[0]
    ys = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], scanned)
        x, y = body(x, sl)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return x, ys


def _remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn

Array = jax.Array

N_AUDIO_FRAMES = 1500        # whisper's 30 s / 20 ms frame count


def _mask_pad_vocab(cfg, logits):
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    col = jnp.arange(cfg.padded_vocab)
    return jnp.where(col < cfg.vocab_size, logits, -1e30)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm1": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attn.gqa_init(ks[0], cfg, dtype=dtype),
        "norm2": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                        bias=cfg.mlp_bias, dtype=dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm1": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "self_attn": attn.gqa_init(ks[0], cfg, dtype=dtype),
        "norm_x": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "cross_attn": attn.gqa_init(ks[1], cfg, dtype=dtype),
        "norm2": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                        bias=cfg.mlp_bias, dtype=dtype),
    }


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = cfg.pdtype
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frame_proj": nn.dense_init(ks[2], cfg.frontend_dim, cfg.d_model,
                                    dtype=dtype),
        "enc_pos": {"table": nn.normal_init(
            ks[3], (cfg.n_frontend_tokens, cfg.d_model), 0.01, dtype)},
        "embed": {"table": nn.normal_init(
            ks[4], (cfg.padded_vocab, cfg.d_model), 0.02, dtype)},
        "dec_pos": {"table": nn.normal_init(
            ks[5], (cfg.max_seq_len, cfg.d_model), 0.01, dtype)},
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype)
                            )(enc_keys),
        "enc_norm": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype)
                            )(dec_keys),
        "final_norm": nn.norm_init(cfg.norm, cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, cfg, frames: Array) -> Array:
    """frames: (B, T_enc, frontend_dim) stub embeddings -> (B, T_enc, d)."""
    x = nn.dense_apply(params["frame_proj"], frames, cfg.cdtype)
    x = x + params["enc_pos"]["table"][None, :x.shape[1]].astype(x.dtype)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, p_l):
        y = nn.norm_apply(cfg.norm, p_l["norm1"], carry)
        carry = carry + attn.gqa_apply(p_l["attn"], cfg, y,
                                       positions=positions, causal=False)
        y = nn.norm_apply(cfg.norm, p_l["norm2"], carry)
        carry = carry + mlp_apply(p_l["mlp"], y,
                                  activation=cfg.mlp_activation,
                                  compute_dtype=cfg.cdtype)
        return carry, None

    x, _ = _iterate(cfg, _remat(cfg, body), x, params["encoder"])
    return nn.norm_apply(cfg.norm, params["enc_norm"], x)


# ---------------------------------------------------------------------------
# decoder (parallel / teacher-forced)
# ---------------------------------------------------------------------------

def _dec_block_apply(p, cfg, x, enc_kv, positions):
    y = nn.norm_apply(cfg.norm, p["norm1"], x)
    x = x + attn.gqa_apply(p["self_attn"], cfg, y, positions=positions,
                           causal=True)
    y = nn.norm_apply(cfg.norm, p["norm_x"], x)
    x = x + attn.gqa_apply(p["cross_attn"], cfg, y, positions=positions,
                           causal=False, kv=enc_kv)
    y = nn.norm_apply(cfg.norm, p["norm2"], x)
    return x + mlp_apply(p["mlp"], y, activation=cfg.mlp_activation,
                         compute_dtype=cfg.cdtype)


def forward(params, cfg, frames: Array, tokens: Array) -> Array:
    """Teacher-forced decode.  Returns logits (B, S, V)."""
    enc = encode(params, cfg, frames)
    x = params["embed"]["table"].astype(cfg.cdtype)[tokens]
    x = x + params["dec_pos"]["table"][None, :x.shape[1]].astype(x.dtype)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, p_l):
        kv = attn.gqa_project_kv(p_l["cross_attn"], cfg, enc)
        return _dec_block_apply(p_l, cfg, carry, kv, positions), None

    x, _ = _iterate(cfg, _remat(cfg, body), x, params["decoder"])
    x = nn.norm_apply(cfg.norm, params["final_norm"], x)
    table = params["embed"]["table"].astype(cfg.cdtype)
    logits = x @ table.T        # whisper ties the output projection
    return _mask_pad_vocab(cfg, logits)


def loss_fn(params, cfg, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
    logits = constrain(
        forward(params, cfg, batch["frames"], batch["tokens"]
                ).astype(jnp.float32), "dp", None, "tp")
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    col = jnp.arange(logits.shape[-1])
    gold = jnp.sum(jnp.where(col == safe[..., None], logits, 0.0), axis=-1)
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "nll": loss, "ntokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# decode (cache self-attn kv; cross kv precomputed at prefill)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> Dict[str, Any]:
    dt = cfg.cdtype
    L = cfg.n_layers
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    t_enc = cfg.n_frontend_tokens
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, kvh, hd), dt),
        "v": jnp.zeros((L, batch, max_len, kvh, hd), dt),
        "cross_k": jnp.zeros((L, batch, t_enc, kvh, hd), dt),
        "cross_v": jnp.zeros((L, batch, t_enc, kvh, hd), dt),
    }


def prefill(params, cfg, frames: Array, cache: Dict[str, Any]
            ) -> Dict[str, Any]:
    """Encode audio and precompute the cross-attention kv."""
    enc = encode(params, cfg, frames)

    def body(_, p_l):
        k, v = attn.gqa_project_kv(p_l["cross_attn"], cfg, enc)
        return None, (k, v)

    _, (ck, cv) = _iterate(cfg, body, None, params["decoder"])
    cache = dict(cache)
    cache["cross_k"], cache["cross_v"] = ck, cv
    return cache


def decode_step(params, cfg, token: Array, cache: Dict[str, Any]
                ) -> Tuple[Array, Dict[str, Any]]:
    pos = cache["pos"]
    x = params["embed"]["table"].astype(cfg.cdtype)[token]
    x = x + params["dec_pos"]["table"].astype(cfg.cdtype)[pos]

    def body(carry, scanned):
        p_l, k_l, v_l, ck_l, cv_l = scanned
        y = nn.norm_apply(cfg.norm, p_l["norm1"], carry)
        out, k_l, v_l = attn.gqa_decode_step(p_l["self_attn"], cfg, y,
                                             k_l, v_l, pos)
        carry = carry + out
        y = nn.norm_apply(cfg.norm, p_l["norm_x"], carry)
        q = nn.dense_apply(p_l["cross_attn"]["wq"], y, cfg.cdtype)
        bsz = q.shape[0]
        q = q.reshape(bsz, cfg.n_heads, cfg.head_dim_)
        o = attn.decode_attention(
            q, ck_l, cv_l,
            jnp.full((bsz,), ck_l.shape[1], jnp.int32))
        carry = carry + nn.dense_apply(
            p_l["cross_attn"]["wo"],
            o.reshape(bsz, cfg.n_heads * cfg.head_dim_), cfg.cdtype)
        y = nn.norm_apply(cfg.norm, p_l["norm2"], carry)
        carry = carry + mlp_apply(p_l["mlp"], y,
                                  activation=cfg.mlp_activation,
                                  compute_dtype=cfg.cdtype)
        return carry, (k_l, v_l)

    x, (k_new, v_new) = _iterate(
        cfg, body, x, (params["decoder"], cache["k"], cache["v"],
                       cache["cross_k"], cache["cross_v"]))
    x = nn.norm_apply(cfg.norm, params["final_norm"], x)
    logits = _mask_pad_vocab(
        cfg, x @ params["embed"]["table"].astype(cfg.cdtype).T)
    new_cache = dict(cache)
    new_cache.update({"k": k_new, "v": v_new, "pos": pos + 1})
    return logits, new_cache
