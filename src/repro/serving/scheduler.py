"""Admission scheduling + engine statistics for the serving engine.

The scheduler is deliberately simple (strict FIFO staging into slot
staging buffers); its value is that the policy and the accounting live
*outside* the engine's jax plumbing, so policy experiments (priority
queues, length-aware packing) don't touch device code.

With the superstep engine the scheduler's contract is small but load-
bearing: ``take`` must pop requests in exact submission order (FIFO
fairness -- a request is never overtaken while queued) and must
eventually pop every request as staging capacity frees up (no
starvation).  ``tests/test_scheduler.py`` property-tests both against
random arrival traces.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8


class FifoScheduler:
    """FIFO admission: pop requests in submission order as slots free up."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: List = []           # Request objects (engine-owned)

    def submit(self, req) -> None:
        self.waiting.append(req)

    def __len__(self) -> int:
        return len(self.waiting)

    def take(self, n: int) -> List:
        """Pop the next admission group: the first ``n`` waiting requests,
        in exact submission order."""
        n = max(0, min(n, len(self.waiting)))
        group, self.waiting = self.waiting[:n], self.waiting[n:]
        return group


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, int(q * (len(ys) - 1) + 0.5))
    return float(ys[i])


@dataclasses.dataclass
class EngineStats:
    """Counters + wall-clock for the serving superstep loop.

    ``decode_steps`` counts *device* rounds (K per superstep) while
    ``decode_calls`` counts host round-trips (one ``lm.superstep``
    dispatch each); ``slot_steps`` is rounds x batch -- every row is
    stepped every round to keep shapes static, and ``wasted_slot_steps``
    counts the rows that were stepped while dead with nothing staged
    (the idle waste in-loop re-admission exists to eliminate;
    ``snapshot()['wasted_slot_fraction']`` is the trajectory metric).
    ``prefill_tokens`` counts prompt tokens consumed on device (up to
    ``prompt_chunk`` per prefilling row-round under packed prefill) and
    ``prefill_rounds`` the slot-rounds spent prefilling (== tokens at
    C=1); the exact slot-step identity under any C is ``slot_steps ==
    prefill_rounds + decode_tokens - first_token_overlaps +
    wasted_slot_steps`` (a request's first token rides its final prefill
    round).  Timers wrap the device calls including host sync, so
    tokens-per-second is an end-to-end number.

    Per-request latency: ``ttft_s`` / ``ttft_rounds`` measure submit ->
    first token (wall clock at host drain granularity, and exact device
    rounds); ``itl_s`` is the per-request mean inter-token gap in wall
    seconds (host drain granularity -- the load signal), while
    ``itl_rounds`` is the same gap in device rounds.  The superstep
    never stalls an emitting row, so without speculation ``itl_rounds``
    is 1.0 by construction; it is kept as a regression canary -- any
    deviation above 1.0 means a scheduler/preemption change started
    inserting idle rounds into running streams, while values below 1.0
    are exactly the speculative multi-emit win.

    Speculative decoding: ``draft_proposed`` / ``draft_accepted`` count
    draft tokens offered to / accepted by the verifier, and
    ``non_spec_tokens`` counts the tokens the non-speculative path
    contributes (one per emitting slot-round -- the verify round's own
    token).  The exact identities: ``decode_tokens == draft_accepted +
    non_spec_tokens``, and the slot-step identity above holds with
    ``decode_tokens`` replaced by ``non_spec_tokens`` (a spec round is
    still ONE slot-step however many tokens it emits).
    ``snapshot()['accept_rate']`` is the trajectory metric.
    """
    prompt_chunk: int = 1
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    prefill_tokens: int = 0
    prefill_rounds: int = 0
    decode_tokens: int = 0
    decode_steps: int = 0
    decode_calls: int = 0
    slot_steps: int = 0
    wasted_slot_steps: int = 0
    draft_proposed: int = 0
    draft_accepted: int = 0
    non_spec_tokens: int = 0
    queue_peak: int = 0
    decode_time_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    ttft_rounds: List[int] = dataclasses.field(default_factory=list)
    itl_s: List[float] = dataclasses.field(default_factory=list)
    itl_rounds: List[float] = dataclasses.field(default_factory=list)

    def observe_queue(self, depth: int) -> None:
        self.queue_peak = max(self.queue_peak, depth)

    def record_first_token(self, wall_s: float, rounds: int) -> None:
        self.ttft_s.append(wall_s)
        self.ttft_rounds.append(rounds)

    def record_completion(self, n_tokens: int, first_round: int,
                          last_round: int, first_s: float = 0.0,
                          last_s: float = 0.0) -> None:
        if n_tokens > 1:
            self.itl_rounds.append(
                (last_round - first_round) / (n_tokens - 1))
            self.itl_s.append((last_s - first_s) / (n_tokens - 1))

    def timed(self, kind: str):
        """Context manager: adds elapsed wall time to ``<kind>_time_s``."""
        stats = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()

            def __exit__(self, *exc):
                dt = time.perf_counter() - self.t0
                setattr(stats, f"{kind}_time_s",
                        getattr(stats, f"{kind}_time_s") + dt)
                return False

        return _Timer()

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    def tokens_per_second(self) -> float:
        return self.total_tokens / max(self.decode_time_s, 1e-9)

    def decode_tokens_per_second(self) -> float:
        return self.decode_tokens / max(self.decode_time_s, 1e-9)

    def snapshot(self) -> Dict[str, float]:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)
             if not isinstance(getattr(self, f.name), list)}
        d["tokens_per_second"] = self.tokens_per_second()
        d["decode_tokens_per_second"] = self.decode_tokens_per_second()
        d["host_roundtrips_per_decode_token"] = (
            self.decode_calls / max(self.decode_tokens, 1))
        d["wasted_slot_fraction"] = (
            self.wasted_slot_steps / max(self.slot_steps, 1))
        d["accept_rate"] = (
            self.draft_accepted / max(self.draft_proposed, 1))
        d["ttft_s_mean"] = (sum(self.ttft_s) / len(self.ttft_s)
                            if self.ttft_s else 0.0)
        d["ttft_s_p95"] = _percentile(self.ttft_s, 0.95)
        d["ttft_rounds_mean"] = (
            sum(self.ttft_rounds) / len(self.ttft_rounds)
            if self.ttft_rounds else 0.0)
        d["itl_s_mean"] = (sum(self.itl_s) / len(self.itl_s)
                           if self.itl_s else 0.0)
        d["itl_rounds_mean"] = (sum(self.itl_rounds) / len(self.itl_rounds)
                                if self.itl_rounds else 0.0)
        return d
