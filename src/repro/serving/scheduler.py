"""Admission scheduling + engine statistics for the serving engine.

The scheduler is deliberately simple (FIFO admission into free slots with a
per-round prefill token budget); its value is that the policy and the
accounting live *outside* the engine's jax plumbing, so policy experiments
(priority queues, length-aware packing) don't touch device code.

Shape bucketing: jitted prefill recompiles per (rows, T_pad) shape, so
``bucket_length`` rounds the padded prompt length up to a power of two
(min 8) -- the number of distinct compiled prefill programs is then
O(log max_len) rather than O(#distinct prompt lengths).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


def bucket_length(t: int, minimum: int = 8) -> int:
    """Round t up to a power of two (>= minimum) to bound recompiles."""
    b = minimum
    while b < t:
        b *= 2
    return b


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8
    # prompts longer than this prefill in fixed-size chunks interleaved
    # with decode rounds -- one chunk per engine step(), i.e. per decode
    # block of K tokens (None/0 = whole-prompt prefill).  Only effective
    # for archs whose cache supports resume (lm.supports_chunked_prefill).
    prefill_chunk: Optional[int] = None
    # cap on summed prompt tokens admitted per round (None = no cap);
    # bounds the size of one batched prefill call under bursty arrivals
    max_prefill_tokens: Optional[int] = None


class FifoScheduler:
    """FIFO admission: fill free slots, respecting the prefill token budget."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: List = []           # Request objects (engine-owned)

    def submit(self, req) -> None:
        self.waiting.append(req)

    def __len__(self) -> int:
        return len(self.waiting)

    def take(self, free_slots: int,
             max_prompt_len: Optional[int] = None) -> List:
        """Pop the next admission group: at most ``free_slots`` requests,
        at most ``max_prefill_tokens`` summed prompt tokens (always at
        least one request, so oversized prompts cannot starve).

        ``max_prompt_len`` stops at the first queue head longer than the
        limit (FIFO order preserved) -- used to admit short prompts into
        idle slots while a chunked-prefill cohort is in flight.
        """
        budget = self.cfg.max_prefill_tokens
        group: List = []
        used = 0
        while self.waiting and len(group) < free_slots:
            nxt = len(self.waiting[0].prompt)
            if max_prompt_len is not None and nxt > max_prompt_len:
                break
            if group and budget is not None and used + nxt > budget:
                break
            group.append(self.waiting.pop(0))
            used += nxt
        return group


@dataclasses.dataclass
class EngineStats:
    """Counters + wall-clock for the serving hot paths.

    ``prefill_tokens`` counts true prompt tokens (padding excluded);
    ``decode_tokens`` counts generated tokens.  ``decode_steps`` counts
    *device* decode iterations while ``decode_calls`` counts host
    round-trips (one ``lm.decode_many`` dispatch each); with decode
    block K they differ by ~Kx, and the snapshot's
    ``host_roundtrips_per_decode_token`` is the serving-efficiency
    number the multi-token decode loop exists to shrink.  Timers wrap
    the device calls including host sync, so tokens-per-second is an
    end-to-end number.
    """
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    prefill_tokens: int = 0
    padded_prefill_tokens: int = 0
    prefill_calls: int = 0
    decode_tokens: int = 0
    decode_steps: int = 0
    decode_calls: int = 0
    queue_peak: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0

    def observe_queue(self, depth: int) -> None:
        self.queue_peak = max(self.queue_peak, depth)

    def timed(self, kind: str):
        """Context manager: adds elapsed wall time to ``<kind>_time_s``."""
        stats = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()

            def __exit__(self, *exc):
                dt = time.perf_counter() - self.t0
                setattr(stats, f"{kind}_time_s",
                        getattr(stats, f"{kind}_time_s") + dt)
                return False

        return _Timer()

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def total_time_s(self) -> float:
        return self.prefill_time_s + self.decode_time_s

    def tokens_per_second(self) -> float:
        return self.total_tokens / max(self.total_time_s, 1e-9)

    def decode_tokens_per_second(self) -> float:
        return self.decode_tokens / max(self.decode_time_s, 1e-9)

    def snapshot(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["tokens_per_second"] = self.tokens_per_second()
        d["decode_tokens_per_second"] = self.decode_tokens_per_second()
        d["padding_overhead"] = (
            self.padded_prefill_tokens / max(self.prefill_tokens, 1))
        d["host_roundtrips_per_decode_token"] = (
            self.decode_calls / max(self.decode_tokens, 1))
        return d
