"""Admission scheduling + engine statistics for the serving engine.

The policy and the accounting live *outside* the engine's jax plumbing,
so policy experiments (priority queues, deadline shaping, length-aware
packing) don't touch device code.

``AdmissionScheduler`` owns three serving-robustness policies:

  * **admission verdicts** -- ``submit()`` returns :data:`ADMITTED`,
    :data:`REJECTED_QUEUE_FULL` (bounded queue, high/low watermark
    hysteresis) or :data:`SHED_UNMEETABLE_DEADLINE` (the caller passes a
    capacity estimate -- the engine builds it from its ``_row_eta``
    rounds-to-free machinery -- and a request whose deadline cannot be
    met even by the estimate is shed at the door instead of wasting a
    slot);
  * **priority classes + EDF ordering with aging** -- ``take()`` pops by
    ``(effective priority, deadline, submission order)`` where a
    request's effective priority improves by one class for every
    ``aging_rounds`` device rounds it has waited, so low-priority work
    cannot starve behind a stream of high-priority arrivals;
  * **retry backoff** -- requests carry ``not_before`` (a device round);
    ``take`` skips them until the round clock catches up, which is how
    the engine's NaN-quarantine retry backoff is enforced.  When the
    engine is otherwise idle it takes with ``ignore_backoff=True`` --
    backoff exists to let a transient fault clear while other work runs,
    not to stall an empty machine.

With the default config (unbounded queue, one priority class, no
deadlines) the behaviour is exactly the original strict FIFO: ``take``
pops in submission order and every request is eventually popped
(``tests/test_scheduler.py`` property-tests both against random arrival
traces).  ``FifoScheduler`` remains as an alias for that degenerate
configuration.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional

# ---------------------------------------------------------------------------
# Admission verdicts (returned by AdmissionScheduler.submit)
# ---------------------------------------------------------------------------
ADMITTED = "ADMITTED"
REJECTED_QUEUE_FULL = "REJECTED_QUEUE_FULL"
SHED_UNMEETABLE_DEADLINE = "SHED_UNMEETABLE_DEADLINE"


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8
    # bounded queue: 0 = unbounded (legacy behaviour).  Admission closes
    # when the queue reaches ceil(high_watermark * max_queue) and stays
    # closed (hysteresis) until it drains below low_watermark * max_queue,
    # so a saturated engine sheds bursts instead of oscillating.
    max_queue: int = 0
    high_watermark: float = 1.0
    low_watermark: float = 0.5
    # EDF aging: waiting this many device rounds improves a request's
    # effective priority by one class (0 disables aging).
    aging_rounds: int = 64


class AdmissionScheduler:
    """Priority + deadline (EDF with aging) admission with a bounded queue.

    Requests are engine-owned objects; the scheduler reads (with safe
    defaults, so plain tagged objects work in tests) ``priority`` (lower
    is more urgent), ``deadline`` (absolute device round or None),
    ``submit_round`` and ``not_before``.
    """

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: List = []           # Request objects (engine-owned)
        self._seq = 0
        self._order: Dict[int, int] = {}  # id(req) -> submission seq
        self._saturated = False

    # -- admission ----------------------------------------------------
    def submit(self, req, now_round: int = 0,
               est_finish: Optional[int] = None) -> str:
        """Admit ``req`` or return a rejection verdict.

        ``est_finish`` is the caller's capacity estimate (absolute device
        round by which the request could plausibly finish); when the
        request carries a deadline the estimate cannot meet, it is shed
        immediately rather than admitted to die in the queue.
        """
        if self.cfg.max_queue > 0:
            hi = math.ceil(self.cfg.high_watermark * self.cfg.max_queue)
            lo = self.cfg.low_watermark * self.cfg.max_queue
            if self._saturated and len(self.waiting) < lo:
                self._saturated = False
            if len(self.waiting) >= min(hi, self.cfg.max_queue):
                self._saturated = True
            if self._saturated:
                return REJECTED_QUEUE_FULL
        deadline = getattr(req, "deadline", None)
        if deadline is not None and est_finish is not None \
                and est_finish > deadline:
            return SHED_UNMEETABLE_DEADLINE
        self._order[id(req)] = self._seq
        self._seq += 1
        self.waiting.append(req)
        return ADMITTED

    def remove(self, req) -> bool:
        """Withdraw a queued request (cancellation / deadline sweep)."""
        try:
            self.waiting.remove(req)
        except ValueError:
            return False
        self._order.pop(id(req), None)
        return True

    def __len__(self) -> int:
        return len(self.waiting)

    # -- ordering -----------------------------------------------------
    def _key(self, req, now_round: int):
        pr = getattr(req, "priority", 1)
        if self.cfg.aging_rounds > 0:
            waited = max(0, now_round - getattr(req, "submit_round", 0))
            pr = pr - waited // self.cfg.aging_rounds
        deadline = getattr(req, "deadline", None)
        return (pr, math.inf if deadline is None else deadline,
                self._order[id(req)])

    def take(self, n: int, now_round: int = 0,
             ignore_backoff: bool = False) -> List:
        """Pop the next admission group of up to ``n`` requests by
        (aged priority, earliest deadline, submission order).  Within one
        priority class with no deadlines this is exact submission order:
        aging can only *improve* an earlier request's class relative to a
        later one, never degrade it, so default-config behaviour is
        strict FIFO.  Requests whose ``not_before`` round is still in the
        future are skipped unless ``ignore_backoff``.
        """
        n = max(0, n)
        pool = self.waiting if ignore_backoff else \
            [r for r in self.waiting
             if getattr(r, "not_before", 0) <= now_round]
        group = sorted(pool, key=lambda r: self._key(r, now_round))[:n]
        for req in group:
            self.waiting.remove(req)
            self._order.pop(id(req), None)
        return group

    # -- snapshot support (serving/recovery.py) -----------------------
    def state_dict(self) -> dict:
        """JSON-able queue state: waiting requests as ``[rid, seq]``
        pairs in queue order plus the submission-sequence counter and
        the saturation latch.  Requests themselves are engine-owned and
        serialized by the engine snapshot; this captures only what the
        scheduler adds on top (ordering + hysteresis)."""
        return {"waiting": [[r.rid, self._order[id(r)]]
                            for r in self.waiting],
                "seq": self._seq, "saturated": self._saturated}

    def load_state_dict(self, state: dict, requests) -> None:
        """Rebuild the queue from :meth:`state_dict` output;
        ``requests`` maps rid -> the restored Request object."""
        self.waiting = [requests[rid] for rid, _ in state["waiting"]]
        self._order = {id(requests[rid]): int(seq)
                       for rid, seq in state["waiting"]}
        self._seq = int(state["seq"])
        self._saturated = bool(state["saturated"])


# Degenerate configuration of AdmissionScheduler (unbounded queue, one
# priority class, no deadlines) == the original strict-FIFO scheduler.
FifoScheduler = AdmissionScheduler


@dataclasses.dataclass
class ShardStats:
    """One data shard's slice of the slot-step identity.

    Under a ``--mesh dxm`` serving mesh the slot pool splits into ``d``
    contiguous row groups (shard ``s`` owns rows ``[s*B/d, (s+1)*B/d)``)
    and the superstep emits its counters per shard, so the identity
    ``slot_steps == prefill_rounds + non_spec_tokens - first_tokens +
    wasted_slot_steps + nonfinite_decode_rounds`` must hold for every
    shard individually as well as summed (the single-device engine is
    the ``d=1`` special case with one shard).  ``non_spec_tokens`` equals
    ``decode_tokens`` without speculation; ``first_tokens`` counts
    requests whose first output token this shard emitted (each rides its
    final prefill round -- the overlap term)."""
    slot_steps: int = 0
    prefill_rounds: int = 0
    decode_tokens: int = 0
    first_tokens: int = 0
    wasted_slot_steps: int = 0
    nonfinite_decode_rounds: int = 0
    non_spec_tokens: int = 0

    def identity_ok(self) -> bool:
        return self.slot_steps == (
            self.prefill_rounds + self.non_spec_tokens - self.first_tokens
            + self.wasted_slot_steps + self.nonfinite_decode_rounds)


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, int(q * (len(ys) - 1) + 0.5))
    return float(ys[i])


@dataclasses.dataclass
class EngineStats:
    """Counters + wall-clock for the serving superstep loop.

    ``decode_steps`` counts *device* rounds (K per superstep) while
    ``decode_calls`` counts host round-trips (one ``lm.superstep``
    dispatch each); ``slot_steps`` is rounds x batch -- every row is
    stepped every round to keep shapes static, and ``wasted_slot_steps``
    counts the rows that were stepped while dead with nothing staged
    (the idle waste in-loop re-admission exists to eliminate;
    ``snapshot()['wasted_slot_fraction']`` is the trajectory metric).
    ``prefill_tokens`` counts prompt tokens consumed on device (up to
    ``prompt_chunk`` per prefilling row-round under packed prefill) and
    ``prefill_rounds`` the slot-rounds spent prefilling (== tokens at
    C=1); the exact slot-step identity under any C is ``slot_steps ==
    prefill_rounds + decode_tokens - first_token_overlaps +
    wasted_slot_steps + nonfinite_decode_rounds`` (a request's first
    token rides its final prefill round; a round whose emission the
    non-finite guard suppressed is counted by the last term -- see
    below).  Timers wrap the device calls including host sync, so
    tokens-per-second is an end-to-end number.

    Per-request latency: ``ttft_s`` / ``ttft_rounds`` measure submit ->
    first token (wall clock at host drain granularity, and exact device
    rounds); ``itl_s`` is the per-request mean inter-token gap in wall
    seconds (host drain granularity -- the load signal), while
    ``itl_rounds`` is the same gap in device rounds.  The superstep
    never stalls an emitting row, so without speculation ``itl_rounds``
    is 1.0 by construction; it is kept as a regression canary -- any
    deviation above 1.0 means a scheduler/preemption change started
    inserting idle rounds into running streams, while values below 1.0
    are exactly the speculative multi-emit win.

    Speculative decoding: ``draft_proposed`` / ``draft_accepted`` count
    draft tokens offered to / accepted by the verifier, and
    ``non_spec_tokens`` counts the tokens the non-speculative path
    contributes (one per emitting slot-round -- the verify round's own
    token).  The exact identities: ``decode_tokens == draft_accepted +
    non_spec_tokens``, and the slot-step identity above holds with
    ``decode_tokens`` replaced by ``non_spec_tokens`` (a spec round is
    still ONE slot-step however many tokens it emits).
    ``snapshot()['accept_rate']`` is the trajectory metric.
    ``spec_disabled`` counts the times the rolling accept-rate floor
    turned drafting off (graceful degradation under hostile inputs).

    Fault tolerance: ``cancelled`` / ``timed_out`` / ``failed`` /
    ``shed`` / ``rejected`` count terminal request outcomes other than
    completion (shed = unmeetable deadline at admission, rejected =
    bounded-queue backpressure); ``retried`` counts quarantine re-
    enqueues and ``quarantined`` counts slot kills by the non-finite
    guard.  ``nonfinite_decode_rounds`` is the guard's slot-step
    identity term: a round whose emission was suppressed on a decoding
    row appears in no other counter.  Terminal accounting: ``submitted
    == completed + cancelled + timed_out + failed + shed + rejected``
    once the engine drains (retries move a request back to the queue,
    they are not terminal).

    DP-shard failover: ``shard_crashes`` counts data shards the
    ``shard_crash`` chaos point killed and ``failover_requeued`` the
    staged/in-flight requests drained off dead shards back onto the
    survivors (a failover requeue restarts the stream like a quarantine
    retry but burns no retry budget -- the crash is not the request's
    fault).  A dead shard's rows keep stepping as ``wasted_slot_steps``
    on its own :class:`ShardStats`, so the per-shard identity holds
    through a crash.
    """
    prompt_chunk: int = 1
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    prefill_tokens: int = 0
    prefill_rounds: int = 0
    decode_tokens: int = 0
    decode_steps: int = 0
    decode_calls: int = 0
    slot_steps: int = 0
    wasted_slot_steps: int = 0
    draft_proposed: int = 0
    draft_accepted: int = 0
    non_spec_tokens: int = 0
    queue_peak: int = 0
    # fault-tolerance counters
    cancelled: int = 0
    timed_out: int = 0
    failed: int = 0
    retried: int = 0
    shed: int = 0
    rejected: int = 0
    quarantined: int = 0
    nonfinite_decode_rounds: int = 0
    spec_disabled: int = 0
    # DP-shard failover (serving/recovery.py + faults.shard_crash)
    shard_crashes: int = 0
    failover_requeued: int = 0
    decode_time_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    ttft_rounds: List[int] = dataclasses.field(default_factory=list)
    itl_s: List[float] = dataclasses.field(default_factory=list)
    itl_rounds: List[float] = dataclasses.field(default_factory=list)
    # per-data-shard identity slices (one entry on a single-device mesh);
    # the engine initialises this to its mesh's data-axis size
    shards: List[ShardStats] = dataclasses.field(default_factory=list)

    def shard_identities_ok(self) -> bool:
        """Slot-step identity per shard AND for the cross-shard sums."""
        if not all(s.identity_ok() for s in self.shards):
            return False
        tot = ShardStats()
        for s in self.shards:
            for f in dataclasses.fields(ShardStats):
                setattr(tot, f.name,
                        getattr(tot, f.name) + getattr(s, f.name))
        return tot.identity_ok()

    def observe_queue(self, depth: int) -> None:
        self.queue_peak = max(self.queue_peak, depth)

    def record_first_token(self, wall_s: float, rounds: int) -> None:
        self.ttft_s.append(wall_s)
        self.ttft_rounds.append(rounds)

    def record_completion(self, n_tokens: int, first_round: int,
                          last_round: int, first_s: float = 0.0,
                          last_s: float = 0.0) -> None:
        if n_tokens > 1:
            self.itl_rounds.append(
                (last_round - first_round) / (n_tokens - 1))
            self.itl_s.append((last_s - first_s) / (n_tokens - 1))

    def timed(self, kind: str):
        """Context manager: adds elapsed wall time to ``<kind>_time_s``."""
        stats = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()

            def __exit__(self, *exc):
                dt = time.perf_counter() - self.t0
                setattr(stats, f"{kind}_time_s",
                        getattr(stats, f"{kind}_time_s") + dt)
                return False

        return _Timer()

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    def tokens_per_second(self) -> float:
        return self.total_tokens / max(self.decode_time_s, 1e-9)

    def decode_tokens_per_second(self) -> float:
        return self.decode_tokens / max(self.decode_time_s, 1e-9)

    def snapshot(self) -> Dict[str, float]:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)
             if not isinstance(getattr(self, f.name), list)}
        d["tokens_per_second"] = self.tokens_per_second()
        d["decode_tokens_per_second"] = self.decode_tokens_per_second()
        d["host_roundtrips_per_decode_token"] = (
            self.decode_calls / max(self.decode_tokens, 1))
        d["wasted_slot_fraction"] = (
            self.wasted_slot_steps / max(self.slot_steps, 1))
        d["accept_rate"] = (
            self.draft_accepted / max(self.draft_proposed, 1))
        d["completion_rate"] = self.completed / max(self.submitted, 1)
        d["ttft_s_mean"] = (sum(self.ttft_s) / len(self.ttft_s)
                            if self.ttft_s else 0.0)
        d["ttft_s_p95"] = _percentile(self.ttft_s, 0.95)
        d["ttft_rounds_mean"] = (
            sum(self.ttft_rounds) / len(self.ttft_rounds)
            if self.ttft_rounds else 0.0)
        d["itl_s_mean"] = (sum(self.itl_s) / len(self.itl_s)
                           if self.itl_s else 0.0)
        d["itl_rounds_mean"] = (sum(self.itl_rounds) / len(self.itl_rounds)
                                if self.itl_rounds else 0.0)
        if self.shards:
            d["n_shards"] = len(self.shards)
            d["shards"] = [dataclasses.asdict(s) for s in self.shards]
            d["shard_identities_ok"] = self.shard_identities_ok()
        return d
