"""Autotuned tile-plan discovery for the serving engine.

``benchmarks/autotune.py`` sweeps the decode-path knobs per model config
-- the block kernel's ``block_dh`` feature tile, the packed-prefill
chunk C and the superstep decode block K -- and persists the winner as
a ``TUNE_<config>.json`` plan.  This module is the consumer side: the
engine resolves a plan at startup and folds it into its config
(``block_dh``) and scheduling knobs (``prompt_chunk`` / ``decode_block``
defaults; explicit constructor arguments always win).

Discovery order for ``resolve_plan(cfg, "auto")``:

  1. ``$REPRO_TUNE_DIR/TUNE_<fingerprint>.json``
  2. ``./TUNE_<fingerprint>.json`` (current working directory)
  3. ``<repo root>/TUNE_<fingerprint>.json`` (the checked-in plans)

where the fingerprint is ``<cfg.name>_L<n_layers>_d<d_model>`` -- plans
are shape-specific, and a discovered plan whose recorded config does not
match the engine's is ignored (an explicitly given path raises instead:
silently serving with a foreign tile plan is the harder bug to find).
Regenerate with ``make bench-autotune`` (see README "Autotuning").
"""

from __future__ import annotations

import json
import logging
import os
from pathlib import Path
from typing import Optional, Union

log = logging.getLogger("repro.tuning")

# src/repro/serving/tuning.py -> repo root
_REPO_ROOT = Path(__file__).resolve().parents[3]

_MATCH_KEYS = ("name", "n_layers", "d_model", "d_ff")


def fingerprint(cfg) -> str:
    return f"{cfg.name}_L{cfg.n_layers}_d{cfg.d_model}"


def tune_filename(cfg) -> str:
    return f"TUNE_{fingerprint(cfg)}.json"


def config_stamp(cfg) -> dict:
    """The shape fields a plan is valid for."""
    stamp = {k: getattr(cfg, k) for k in _MATCH_KEYS}
    stamp["compute_dtype"] = cfg.compute_dtype
    return stamp


def plan_matches(plan: dict, cfg) -> bool:
    rec = plan.get("config", {})
    return all(rec.get(k) == getattr(cfg, k) for k in _MATCH_KEYS)


def load_plan(path: Union[str, Path]) -> dict:
    with open(path) as f:
        return json.load(f)


def save_plan(path: Union[str, Path], plan: dict) -> None:
    with open(path, "w") as f:
        json.dump(plan, f, indent=2, sort_keys=True)
        f.write("\n")


def search_paths(cfg):
    name = tune_filename(cfg)
    tune_dir = os.environ.get("REPRO_TUNE_DIR")
    if tune_dir:
        yield Path(tune_dir) / name
    yield Path.cwd() / name
    yield _REPO_ROOT / name


def resolve_plan(cfg, tune) -> Optional[dict]:
    """``tune``: None -> no plan; "auto" -> discovery order above; a
    path -> that file (raising on shape mismatch); a dict -> as-is."""
    if tune is None:
        return None
    if isinstance(tune, dict):
        return tune
    if tune == "auto":
        for p in search_paths(cfg):
            if p.is_file():
                plan = load_plan(p)
                if plan_matches(plan, cfg):
                    plan.setdefault("source", str(p))
                    return plan
                # a stale/foreign plan on the discovery path is easy to
                # serve past silently -- name the file and the mismatch
                log.warning(
                    "tune plan %s skipped: recorded config %s does not "
                    "match engine config %s", p, plan.get("config"),
                    config_stamp(cfg))
        return None
    plan = load_plan(tune)
    if not plan_matches(plan, cfg):
        raise ValueError(
            f"tune plan {tune} was generated for "
            f"{plan.get('config')}, not for {config_stamp(cfg)}")
    plan.setdefault("source", str(tune))
    return plan


def apply_plan(cfg, plan: dict):
    """Fold the plan's kernel-level knobs into the model config."""
    kw = {}
    if plan.get("block_dh"):
        kw["block_dh"] = int(plan["block_dh"])
    if plan.get("fuse_block"):
        kw["fuse_block"] = plan["fuse_block"]
    return cfg.replace(**kw) if kw else cfg
