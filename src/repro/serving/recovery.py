"""Crash-tolerant serving: engine snapshots + a write-ahead request
journal with deterministic replay recovery.

The paper's O(1) recurrent state (Were RNNs All We Needed?, section
4.1) is what makes *full engine* checkpointing cheap enough to do
between supersteps: a serving snapshot is the O(B x d_hidden) slot pool
-- h/conv/ssm rows, positions, sampling key chains -- plus host-side
request bookkeeping, not a paged KV tree whose size grows with every
token in flight.  Snapshotting a Transformer serving engine at the same
cadence would serialize the whole KV working set per generation; here
the npz is a few dense rows per slot regardless of how long the streams
have run (the recurrent-resurgence deployment argument, see PAPERS.md).

Two cooperating pieces, layered on the engine's determinism contract
(greedy/seeded streams are a pure function of the submit/cancel/step
sequence -- wall clock feeds stats only, never control flow):

  * **Snapshots** -- a versioned, config-stamped codec
    (:func:`snapshot_engine` / :func:`apply_snapshot`) serializing the
    full serving state: every device slot-state leaf (flattened with
    ``training/checkpoint.py``'s path-key scheme), the numpy staging
    mirrors, scheduler queue order + backoff/deadline fields, request
    lifecycle + partial outputs, ``EngineStats`` including per-shard
    ledgers, speculative-degradation state and the chaos injector's RNG
    states.  Written atomically to ``<dir>/snap_<round>/arrays.npz +
    manifest.json`` with a sha256 content checksum; restore walks
    generations newest-first and falls back past corrupt ones.
  * **Write-ahead journal** -- an append-only JSONL
    (:class:`Journal`) of every engine mutation: ``submit`` records are
    fsync'd *before* the engine mutates (the rid is deterministic, so
    the record can promise it), ``cancel`` likewise, and each ``step``
    appends its emissions + a stats digest after the superstep drains,
    fsync'd once per host round-trip.  Each record carries a seq number
    and CRC; a torn tail line is dropped (and truncated before new
    appends), a mid-file corruption stops replay at the last good
    record.

``restore_engine`` (surfaced as ``ServingEngine.restore``) rebuilds the
engine from the journal header's constructor knobs, loads the newest
good snapshot, then *re-executes* the journal tail through the real
``submit``/``cancel``/``step`` code paths.  During replay the journal
verifies each re-executed operation against its record -- emissions and
digests must match bit for bit -- and flips to append mode when the
tail is exhausted, so a restored engine continues journaling seamlessly
and its greedy streams are bit-identical to an uninterrupted run
(tests/test_recovery.py; the ``--crash`` bench lane measures recovery
time and replayed rounds).  Only round-clock metrics survive a restore
exactly; wall-clock latency stats span two processes and are not
comparable across the boundary.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import serve_mesh
from repro.serving import tuning
from repro.serving.faults import FaultConfig, FaultInjector
from repro.serving.scheduler import EngineStats, ShardStats
from repro.training import checkpoint as ckpt

JOURNAL_NAME = "journal.jsonl"
JOURNAL_FORMAT = "serving-journal"
JOURNAL_VERSION = 1
SNAPSHOT_FORMAT = "serving-snapshot"
SNAPSHOT_VERSION = 1
_SNAP_PREFIX = "snap_"
_SMIRROR = "smirror"


class RecoveryError(RuntimeError):
    """Recovery cannot proceed (config mismatch, missing/corrupt
    journal, or a replayed operation diverging from its record)."""


class SnapshotCorruptError(RecoveryError):
    """A snapshot generation failed its sha256 / manifest check."""


def _np_item(obj):
    """json.dumps default hook: numpy scalars -> python scalars (prompt
    tokens often arrive as np.int64 from benchmark traces)."""
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {obj!r}")


def _jnorm(obj):
    """JSON round-trip normalization: tuples -> lists, numpy scalars ->
    python scalars, so recorded and live values compare equal."""
    return json.loads(json.dumps(obj, default=_np_item))


def config_stamp(cfg) -> dict:
    """The config fields a snapshot/journal is valid for: the tuning
    stamp (name/layers/widths/dtype) plus the fields that change the
    slot-state layout or the emitted streams."""
    stamp = tuning.config_stamp(cfg)
    stamp["vocab_size"] = cfg.vocab_size
    stamp["block_kind"] = cfg.block_kind
    return stamp


def engine_knobs(engine) -> dict:
    """Constructor knobs needed to rebuild ``engine`` equivalently --
    everything that shapes device state, placement or replay control
    flow.  All values JSON-able; recorded in the journal header and
    every snapshot manifest (they must agree at restore)."""
    from repro.serving import draft as draft_lib
    draft = engine.draft
    spec_name = ngram = None
    if draft is not None:
        if isinstance(draft, draft_lib.NGramDraft):
            spec_name, ngram = "ngram", draft.ngram
        else:
            spec_name = type(draft).__name__
    sc = engine.scheduler.cfg
    return {
        "max_batch": engine.max_batch, "max_len": engine.max_len,
        "seed": engine.seed,
        "decode_block": engine.decode_block,
        "prompt_chunk": engine.prompt_chunk,
        "speculative": spec_name,
        "draft_len": None if draft is None else draft.draft_len,
        "draft_ngram": ngram,
        "max_queue": sc.max_queue,
        "high_watermark": sc.high_watermark,
        "low_watermark": sc.low_watermark,
        "aging_rounds": sc.aging_rounds,
        "max_retries": engine.max_retries,
        "retry_backoff": engine.retry_backoff,
        "spec_accept_floor": engine.spec_accept_floor,
        "spec_window": engine.spec_window,
        "spec_cooldown": engine.spec_cooldown,
        "mesh": None if engine.mesh_plan is None else str(engine.mesh_plan),
        "fuse_block": engine.cfg.fuse_block,
        "block_dh": engine.cfg.block_dh,
        "faults": None if engine.faults is None
        else dataclasses.asdict(engine.faults.cfg),
    }


def engine_header(engine) -> dict:
    return {"format": JOURNAL_FORMAT, "version": JOURNAL_VERSION,
            "config": config_stamp(engine.cfg),
            "engine": engine_knobs(engine),
            "snapshot": {"every": engine.snapshot_every,
                         "keep": engine.snapshot_keep}}


# ---------------------------------------------------------------------------
# Write-ahead journal
# ---------------------------------------------------------------------------

def _crc(rec: dict) -> int:
    body = {k: v for k, v in rec.items() if k != "crc"}
    return zlib.crc32(
        json.dumps(body, sort_keys=True, separators=(",", ":"),
                   default=_np_item).encode())


def read_journal(path: str):
    """Parse a journal file tolerantly.  Returns ``(header, records,
    dropped, good_bytes)``: the header record (or None), the good data
    records in seq order, how many trailing lines were dropped (torn
    tail or corruption -- reading stops at the first bad line; records
    after a corrupt one cannot be trusted to be gap-free), and the byte
    offset of the end of the last good record (append resumes there)."""
    with open(path, "rb") as f:
        data = f.read()
    header, records = None, []
    good, pos, dropped = 0, 0, 0
    while pos < len(data):
        nl = data.find(b"\n", pos)
        if nl < 0:        # torn final line (no newline): drop it
            dropped += 1
            break
        line = data[pos:nl]
        try:
            rec = json.loads(line)
            if not isinstance(rec, dict) or rec.get("crc") != _crc(rec):
                raise ValueError("crc mismatch")
        except (ValueError, TypeError):
            dropped += sum(1 for ln in data[pos:].split(b"\n") if ln)
            break
        if rec.get("kind") == "header":
            header = rec
        else:
            records.append(rec)
        good = nl + 1
        pos = nl + 1
    return header, records, dropped, good


class Journal:
    """Append-only, CRC'd, seq-numbered record log of engine mutations.

    Two modes.  **append** (normal serving): ``record_*`` serializes the
    payload, fsyncs, done.  **replay** (inside ``restore_engine``): the
    engine re-executes the recorded operations, and each ``record_*``
    call *verifies* the re-executed payload against the next pending
    record instead of writing -- any mismatch means the replay diverged
    from the original run and raises :class:`RecoveryError`.  When the
    pending tail is exhausted the journal truncates any torn bytes and
    flips to append mode, so the restored engine journals seamlessly.
    """

    def __init__(self, path: str, fh, mode: str, next_seq: int,
                 pending: Optional[List[dict]] = None, good_bytes: int = 0):
        self.path = path
        self._fh = fh
        self.mode = mode
        self._next_seq = next_seq
        self._pending = list(pending or [])
        self._good_bytes = good_bytes
        self.replayed = 0
        self.replayed_rounds = 0
        if self.mode == "replay" and not self._pending:
            self._switch_to_append()

    # -- constructors --------------------------------------------------
    @classmethod
    def create(cls, path: str, header: dict) -> "Journal":
        """Start a NEW journal epoch (truncating any previous file --
        resuming an old epoch goes through ``restore_engine``, never
        through a fresh engine construction)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        fh = open(path, "wb")
        j = cls(path, fh, "append", next_seq=0)
        j._append("header", header)
        return j

    @classmethod
    def for_replay(cls, path: str, pending: List[dict],
                   next_seq: int, good_bytes: int) -> "Journal":
        return cls(path, None, "replay", next_seq, pending, good_bytes)

    # -- engine-facing hooks -------------------------------------------
    @property
    def replaying(self) -> bool:
        return self.mode == "replay"

    @property
    def last_seq(self) -> int:
        return self._next_seq - 1

    def record_submit(self, payload: dict) -> None:
        self._record("submit", payload)

    def record_cancel(self, payload: dict) -> None:
        self._record("cancel", payload)

    def record_step(self, payload: dict) -> None:
        self._record("step", payload)

    def sync(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- internals -----------------------------------------------------
    def _append(self, kind: str, payload: dict) -> None:
        rec = {"seq": self._next_seq, "kind": kind}
        rec.update(payload)
        rec = _jnorm(rec)
        rec["crc"] = _crc(rec)
        self._fh.write((json.dumps(rec, sort_keys=True,
                                   separators=(",", ":")) + "\n").encode())
        self._next_seq += 1
        self.sync()

    def _record(self, kind: str, payload: dict) -> None:
        if self.mode != "replay":
            self._append(kind, payload)
            return
        if not self._pending:
            raise RecoveryError(
                f"replay produced an extra {kind!r} record with no "
                f"journal record left to match it")
        exp = self._pending.pop(0)
        want = _jnorm(payload)
        if exp.get("kind") != kind:
            raise RecoveryError(
                f"replay divergence at seq {exp.get('seq')}: journal "
                f"has {exp.get('kind')!r}, replay produced {kind!r}")
        for key, val in want.items():
            if exp.get(key) != val:
                raise RecoveryError(
                    f"replay divergence at seq {exp.get('seq')} "
                    f"({kind}): field {key!r} recorded "
                    f"{exp.get(key)!r} but replay produced {val!r}")
        self.replayed += 1
        if kind == "step" and not exp.get("noop"):
            self.replayed_rounds += int(exp["k"])
        if not self._pending:
            self._switch_to_append()

    def _switch_to_append(self) -> None:
        fh = open(self.path, "r+b")
        fh.seek(self._good_bytes or 0, os.SEEK_SET)
        if self._good_bytes:
            fh.truncate()           # drop any torn tail before appending
        else:
            fh.seek(0, os.SEEK_END)
        self._fh = fh
        self.mode = "append"


# ---------------------------------------------------------------------------
# Snapshot codec
# ---------------------------------------------------------------------------

def _stats_to_dict(stats: EngineStats) -> dict:
    return dataclasses.asdict(stats)


def _stats_from_dict(d: dict) -> EngineStats:
    d = dict(d)
    d["shards"] = [ShardStats(**s) for s in d.get("shards", [])]
    return EngineStats(**d)


def snapshot_engine(engine) -> Tuple[Dict[str, np.ndarray], dict]:
    """Serialize the engine's complete serving state to ``(arrays,
    manifest)``: every device slot-state leaf + the host staging/
    progress mirrors as numpy, and all host bookkeeping (requests with
    partial outputs, scheduler order, stats, spec/fault state) as a
    JSON-able manifest."""
    arrays = ckpt.flatten_tree(engine.state, "state")
    for k, v in engine._smirror.items():
        arrays[_SMIRROR + ckpt.SEP + k] = np.asarray(v)
    arrays["prompt_pos"] = engine._prompt_pos.copy()
    arrays["rid_dev"] = engine._rid_dev.copy()
    manifest = {
        "format": SNAPSHOT_FORMAT, "version": SNAPSHOT_VERSION,
        "time": time.time(),
        "round": engine.stats.decode_steps,
        "journal_seq": -1 if engine.journal is None
        else engine.journal.last_seq,
        "config": config_stamp(engine.cfg),
        "engine": engine_knobs(engine),
        "next_rid": engine._next_rid,
        "requests": [dataclasses.asdict(engine.requests[rid])
                     for rid in sorted(engine.requests)],
        "scheduler": engine.scheduler.state_dict(),
        "staged": [None if r is None else r.rid for r in engine.staged],
        "current": [None if r is None else r.rid for r in engine.current],
        "finished": sorted(engine.finished),
        "dirty_slots": sorted(set(engine._dirty_slots)),
        "dead_shards": sorted(engine.dead_shards),
        "spec": {"active": engine._spec_active,
                 "hist": [list(t) for t in engine._spec_hist],
                 "off_calls": engine._spec_off_calls},
        "stats": _stats_to_dict(engine.stats),
        "faults": None if engine.faults is None
        else engine.faults.state_dict(),
    }
    return arrays, manifest


def snapshot_path(directory: str, round_: int) -> str:
    return os.path.join(directory, f"{_SNAP_PREFIX}{round_:08d}")


def save_snapshot(engine, directory: str, keep: int = 3) -> str:
    """Atomic snapshot write (tmp dir + rename, sha256 checksum in the
    manifest) with keep-N GC of older generations."""
    arrays, manifest = snapshot_engine(engine)
    packed, dtypes = ckpt.pack_arrays(arrays)
    manifest["dtypes"] = dtypes
    os.makedirs(directory, exist_ok=True)
    final = snapshot_path(directory, manifest["round"])
    with ckpt.atomic_dir(final) as tmp:
        np.savez(os.path.join(tmp, "arrays.npz"), **packed)
        manifest["checksum"] = "sha256:" + ckpt.sha256_file(
            os.path.join(tmp, "arrays.npz"))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, default=_np_item)
    for r in list_snapshots(directory)[:-max(1, keep)]:
        shutil.rmtree(snapshot_path(directory, r), ignore_errors=True)
    return final


def list_snapshots(directory: str) -> List[int]:
    """Completed snapshot rounds in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(d[len(_SNAP_PREFIX):]) for d in os.listdir(directory)
        if d.startswith(_SNAP_PREFIX) and not d.endswith(".tmp"))


def load_snapshot(path: str) -> Tuple[Dict[str, np.ndarray], dict]:
    """Load + integrity-check one snapshot generation."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise SnapshotCorruptError(f"{path}: unreadable manifest ({e})")
    recorded = manifest.get("checksum")
    npz = os.path.join(path, "arrays.npz")
    try:
        actual = "sha256:" + ckpt.sha256_file(npz)
    except OSError as e:
        raise SnapshotCorruptError(f"{path}: unreadable arrays.npz ({e})")
    if recorded != actual:
        raise SnapshotCorruptError(
            f"{path}: arrays.npz hash {actual} != manifest {recorded}")
    raw = np.load(npz)
    return ckpt.unpack_arrays(raw, manifest.get("dtypes", {})), manifest


def latest_snapshot(directory: str):
    """Newest restorable snapshot, falling back past corrupt
    generations.  Returns ``(arrays, manifest, path, skipped_rounds)``
    with Nones when no generation is restorable."""
    skipped: List[int] = []
    for r in reversed(list_snapshots(directory)):
        path = snapshot_path(directory, r)
        try:
            arrays, manifest = load_snapshot(path)
            return arrays, manifest, path, skipped
        except SnapshotCorruptError:
            skipped.append(r)
    return None, None, None, skipped


# knob keys that must agree between the recorded engine and the rebuilt
# one -- everything in engine_knobs() shapes state, placement or replay
_KNOB_ALLOW_DIFF = ()


def apply_snapshot(engine, arrays: Dict[str, np.ndarray],
                   manifest: dict) -> None:
    """Load a decoded snapshot into a freshly constructed engine: device
    state (re-sharded onto the engine's mesh when present), staging /
    progress mirrors, requests, scheduler queue, stats and fault state.
    The engine must have been built with the snapshot's recorded knobs
    (``restore_engine`` guarantees this; a hand-built engine is checked
    and rejected on mismatch)."""
    stamp = _jnorm(config_stamp(engine.cfg))
    if manifest["config"] != stamp:
        raise RecoveryError(
            f"snapshot was written for config {manifest['config']}, "
            f"engine config is {stamp}")
    knobs = _jnorm(engine_knobs(engine))
    rec_knobs = manifest["engine"]
    diff = [k for k in set(knobs) | set(rec_knobs)
            if k not in _KNOB_ALLOW_DIFF
            and knobs.get(k) != rec_knobs.get(k)]
    if diff:
        raise RecoveryError(
            "engine knobs do not match the snapshot: " + ", ".join(
                f"{k}={knobs.get(k)!r} (snapshot {rec_knobs.get(k)!r})"
                for k in sorted(diff)))

    fresh_keys = set(ckpt.flatten_tree(engine.state, "state"))
    snap_keys = {k for k in arrays if k.startswith("state" + ckpt.SEP)}
    if fresh_keys != snap_keys:
        raise RecoveryError(
            f"snapshot state tree does not match the engine's: missing "
            f"{sorted(fresh_keys - snap_keys)}, unexpected "
            f"{sorted(snap_keys - fresh_keys)}")
    state = jax.tree.map(jnp.asarray,
                         ckpt.unflatten_tree(arrays, "state"))
    if engine.mesh is not None:
        state = jax.device_put(state, serve_mesh.slot_state_shardings(
            engine.cfg, state, engine.mesh_plan, engine.mesh))
    engine.state = state
    engine._smirror = {
        k[len(_SMIRROR) + len(ckpt.SEP):]: np.array(v)
        for k, v in arrays.items()
        if k.startswith(_SMIRROR + ckpt.SEP)}
    engine._prompt_pos = np.array(arrays["prompt_pos"])
    engine._rid_dev = np.array(arrays["rid_dev"])

    from repro.serving.engine import Request
    requests = {}
    for d in manifest["requests"]:
        req = Request(**d)
        requests[req.rid] = req
    engine.requests = requests
    engine.finished = {rid: requests[rid] for rid in manifest["finished"]}
    engine.current = [None if rid is None else requests[rid]
                      for rid in manifest["current"]]
    engine.staged = [None if rid is None else requests[rid]
                     for rid in manifest["staged"]]
    engine.scheduler.load_state_dict(manifest["scheduler"], requests)
    engine._next_rid = int(manifest["next_rid"])
    engine._dirty_slots = list(manifest["dirty_slots"])
    engine.dead_shards = set(manifest["dead_shards"])
    spec = manifest["spec"]
    engine._spec_active = bool(spec["active"])
    engine._spec_hist = [tuple(t) for t in spec["hist"]]
    engine._spec_off_calls = int(spec["off_calls"])
    engine.stats = _stats_from_dict(manifest["stats"])
    if engine.faults is not None and manifest.get("faults"):
        engine.faults.load_state_dict(manifest["faults"])
    engine._last_snapshot_round = int(manifest["round"])


# ---------------------------------------------------------------------------
# Restore: snapshot + journal-tail replay
# ---------------------------------------------------------------------------

def _ctor_kwargs(knobs: dict, cfg, *, speculative=None, draft_params=None):
    """Recorded knobs -> ServingEngine constructor kwargs (+ the config,
    with the recorded kernel tile folded back in)."""
    from repro.serving import draft as draft_lib
    if knobs.get("block_dh") and cfg.block_dh != knobs["block_dh"]:
        cfg = cfg.replace(block_dh=int(knobs["block_dh"]))
    spec = speculative
    if spec is None and knobs.get("speculative"):
        name = knobs["speculative"]
        if name != "ngram":
            raise RecoveryError(
                f"the journal records a {name!r} draft source, which "
                f"cannot be rebuilt from its name -- pass "
                f"speculative=<instance> (and draft_params) to restore")
        spec = draft_lib.NGramDraft(int(knobs["draft_len"]),
                                    int(knobs.get("draft_ngram") or 2))
    faults = None
    if knobs.get("faults"):
        fkw = dict(knobs["faults"])
        for key in ("nan_at", "shard_crash_at"):
            fkw[key] = tuple(tuple(t) for t in fkw.get(key) or ())
        faults = FaultInjector(FaultConfig(**fkw))
    kw = dict(
        max_batch=knobs["max_batch"], max_len=knobs["max_len"],
        seed=knobs["seed"], decode_block=knobs["decode_block"],
        prompt_chunk=knobs["prompt_chunk"], speculative=spec,
        draft_params=draft_params,
        max_queue=knobs["max_queue"],
        high_watermark=knobs["high_watermark"],
        low_watermark=knobs["low_watermark"],
        aging_rounds=knobs["aging_rounds"],
        max_retries=knobs["max_retries"],
        retry_backoff=knobs["retry_backoff"],
        spec_accept_floor=knobs["spec_accept_floor"],
        spec_window=knobs["spec_window"],
        spec_cooldown=knobs["spec_cooldown"],
        faults=faults, mesh=knobs["mesh"],
        fuse_block=knobs["fuse_block"], tune=None)
    return kw, cfg


def restore_engine(recover_dir: str, cfg, params, *, speculative=None,
                   draft_params=None):
    """Rebuild a :class:`~repro.serving.engine.ServingEngine` from a
    recovery directory on a fresh process: load the newest good
    snapshot (falling back past corrupt generations), then re-execute
    the journal tail -- every submit/cancel/step after the snapshot's
    ``journal_seq`` -- through the real engine code paths, verifying
    each replayed operation against its record.  The returned engine
    carries a ``recovery_report`` dict (snapshot used, corrupt
    generations skipped, records/rounds replayed, recovery wall time)
    and continues journaling + snapshotting where the dead process
    stopped; its streams are bit-identical to an uninterrupted run.

    ``cfg`` and ``params`` are caller-owned (model weights are a
    *training* checkpoint's job and are deliberately not in the serving
    snapshot); ``cfg`` must carry the same stamp the journal recorded.
    """
    from repro.serving import engine as engine_mod
    t0 = time.perf_counter()
    jpath = os.path.join(recover_dir, JOURNAL_NAME)
    if not os.path.exists(jpath):
        raise RecoveryError(
            f"no journal at {jpath}: the directory was never armed for "
            f"recovery (construct the engine with recover_dir=...)")
    header, records, dropped, good_bytes = read_journal(jpath)
    if header is None:
        raise RecoveryError(f"{jpath}: no readable header record")
    stamp = _jnorm(config_stamp(cfg))
    rec_stamp = dict(header["config"])
    rec_stamp.pop("block_dh", None)
    if rec_stamp != stamp:
        raise RecoveryError(
            f"journal was written for config {header['config']}, "
            f"engine config is {stamp}")
    kw, cfg = _ctor_kwargs(dict(header["engine"]), cfg,
                           speculative=speculative,
                           draft_params=draft_params)
    eng = engine_mod.ServingEngine(cfg, params, **kw)
    eng.recover_dir = recover_dir
    snapcfg = header.get("snapshot") or {}
    eng.snapshot_every = int(snapcfg.get("every", eng.snapshot_every))
    eng.snapshot_keep = int(snapcfg.get("keep", eng.snapshot_keep))

    arrays, manifest, spath, skipped = latest_snapshot(recover_dir)
    snap_seq = -1
    if manifest is not None:
        apply_snapshot(eng, arrays, manifest)
        snap_seq = int(manifest["journal_seq"])
    tail = [r for r in records if r["seq"] > snap_seq]
    next_seq = (records[-1]["seq"] if records else header["seq"]) + 1
    eng.journal = Journal.for_replay(jpath, tail, next_seq, good_bytes)

    for rec in tail:
        kind = rec["kind"]
        if kind == "submit":
            rid = eng.submit(list(rec["prompt"]), max_new=rec["max_new"],
                             temperature=rec["temperature"],
                             top_k=rec["top_k"], top_p=rec["top_p"],
                             eos=rec["eos"], priority=rec["priority"],
                             deadline=rec["deadline"])
            if rid != rec["rid"]:
                raise RecoveryError(
                    f"replayed submit produced rid {rid}, journal seq "
                    f"{rec['seq']} recorded rid {rec['rid']}")
        elif kind == "cancel":
            eng.cancel(rec["rid"])
        elif kind == "step":
            eng.step(rec["k"])
        else:
            raise RecoveryError(
                f"unknown journal record kind {kind!r} at seq "
                f"{rec['seq']}")
    if eng.journal.replaying:
        raise RecoveryError(
            "journal tail not fully consumed after replay -- the replay "
            "executed fewer operations than were recorded")
    eng.recovery_report = {
        "snapshot": spath,
        "snapshot_round": None if manifest is None else manifest["round"],
        "corrupt_snapshots_skipped": skipped,
        "journal_records": len(records),
        "replayed_records": len(tail),
        "replayed_rounds": eng.journal.replayed_rounds,
        "dropped_tail_records": dropped,
        "recovery_s": time.perf_counter() - t0,
    }
    return eng
