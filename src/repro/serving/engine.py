"""Serving engine v3: batched prefill + multi-token on-device decode.

The paper's serving story (§4.1, App. D.2): prefill processes the whole
prompt with the parallel scan (one forward), then decode rolls the O(1)
sequential cell.  The engine keeps a fixed-capacity batch of slots
(continuous batching, vLLM-style but with RNN/SSM states as first-class
cache kinds).  Hot paths:

  * **Batched prefill** -- each admission round gathers every queued
    request that fits a free slot, right-pads the prompts into ONE
    ``(k, T_pad)`` ``lm.prefill`` call with per-row length masking
    (``lengths=``), and splices all k terminal states into their slots in
    one jitted tree scatter.  Padded lengths are bucketed to powers of
    two so the number of compiled prefill programs stays O(log max_len).

  * **Multi-token on-device decode** -- ``step(n_tokens=K)`` runs
    ``lm.decode_many``: ONE jitted ``lax.scan`` over K iterations of
    step -> sample -> EOS/length-mask, with sampling controls, stop
    tokens, liveness and length caps all living in device-side control
    state.  The host sees a single ``(B, K)`` token buffer per call
    (one round-trip per K tokens instead of per token) and only splices
    finished slots / drains output buffers between calls.  The minRNN
    cell step itself runs in the fused Pallas decode kernel
    (``kernels/decode_step``) under the default ``scan_strategy="auto"``.

  * **Chunked prefill** -- prompts longer than ``prefill_chunk`` are
    prefilled in fixed-size chunks interleaved with decode (one chunk
    per ``step()``, i.e. per K decoded tokens), bounding how long
    running requests stall behind a long prompt.  Supported for
    recurrent-cache archs (``lm.supports_chunked_prefill``); KV-cache
    archs prefill whole-prompt.

Scheduling and accounting (queue policy, token counters, tokens/s, host
round-trips per decoded token) live in ``serving.scheduler``;
``engine.stats.snapshot()`` is the monitoring surface.  Greedy engine
output is argmax-identical to the single-request ``generate_one``
reference for every cache kind and any decode block size, under any
admission order and slot reuse -- the parity tests in
tests/test_serving.py and tests/test_decode.py drive this.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import sampling
from repro.serving.scheduler import (EngineStats, FifoScheduler,
                                     SchedulerConfig, bucket_length)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    prefilled: int = 0            # prompt tokens already consumed
    done: bool = False


def _splice_rows(cache_batch, cache_rows, slots):
    """Write k prefilled rows into slots ``slots`` of the engine cache.

    Every cache leaf is (L, B, ...) with batch on axis 1, except the shared
    position counter ``pos`` which is (B,).  One jitted tree-map scatter
    replaces v1's per-request splice loop.
    """
    def upd(big, small):
        if big.ndim == 1:                       # pos: (B,) <- (k,)
            return big.at[slots].set(small)
        return big.at[:, slots].set(small)      # (L, B, ...) <- (L, k, ...)

    return jax.tree.map(upd, cache_batch, cache_rows)


def _take_rows(cache_rows, keep):
    """Row-subset of a batched cache pytree (same layout as above)."""
    def sel(leaf):
        if leaf.ndim == 1:
            return leaf[keep]
        return leaf[:, keep]

    return jax.tree.map(sel, cache_rows)


class ServingEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 2048, seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 max_prefill_tokens: Optional[int] = None,
                 decode_block: int = 1):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # K = decoded tokens per host round-trip (lm.decode_many scan
        # length); admission / chunked prefill interleave at this grain
        self.decode_block = max(1, int(decode_block))
        self.cache = lm.init_cache(cfg, max_batch, max_len)
        self.free = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self._last_token = np.zeros((max_batch,), np.int32)

        self.scheduler = FifoScheduler(SchedulerConfig(
            max_batch=max_batch, prefill_chunk=prefill_chunk,
            max_prefill_tokens=max_prefill_tokens))
        self.stats = EngineStats()
        self._chunking = bool(prefill_chunk) and lm.supports_chunked_prefill(cfg)
        # in-flight chunked-prefill cohort: requests that prefill together,
        # one chunk per step, until each hands its slot to decode
        self._cohort: List[Request] = []
        self._cohort_cache: Optional[Dict[str, Any]] = None

        # per-slot sampling controls: host mirrors + cached device copies
        # (controls change only at admission; don't re-upload per step)
        self._temp = np.zeros((max_batch,), np.float32)
        self._topk = np.zeros((max_batch,), np.int32)
        self._topp = np.ones((max_batch,), np.float32)
        self._controls_dev = None
        self._keys = sampling.make_keys(seed, max_batch)

        # one compiled lm.decode_many program per distinct block size
        self._decode_fns: Dict[int, Any] = {}
        self._prefill = jax.jit(
            lambda p, toks, lengths: lm.prefill(p, cfg, toks, max_len,
                                                lengths=lengths))
        self._prefill_resume = jax.jit(
            lambda p, toks, lengths, cache: lm.prefill(
                p, cfg, toks, max_len, lengths=lengths, cache=cache))
        self._splice = jax.jit(_splice_rows)

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
               eos: Optional[int] = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"engine max_len ({self.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        self.scheduler.submit(Request(rid, list(prompt), max_new,
                                      temperature, top_k, top_p, eos))
        self.stats.submitted += 1
        self.stats.observe_queue(len(self.scheduler))
        return rid

    # ------------------------------------------------------------------
    # Prefill path
    # ------------------------------------------------------------------
    def _pad_batch(self, reqs: List[Request], chunk: Optional[int]):
        """Right-pad the next (chunk of the) prompt of each request into a
        (k, T_pad) token matrix + true lengths."""
        pieces = []
        for r in reqs:
            rest = r.prompt[r.prefilled:]
            pieces.append(rest[:chunk] if chunk else rest)
        # clamp the pow2 bucket to max_len: KV caches are sized (max_len,)
        # and _seed_kv cannot pad a prompt matrix wider than that
        t_pad = min(bucket_length(max(len(p) for p in pieces)),
                    self.max_len)
        toks = np.zeros((len(reqs), t_pad), np.int32)
        lengths = np.zeros((len(reqs),), np.int32)
        for i, p in enumerate(pieces):
            toks[i, :len(p)] = p
            lengths[i] = len(p)
        self.stats.prefill_tokens += int(lengths.sum())
        self.stats.padded_prefill_tokens += len(reqs) * t_pad
        return jnp.asarray(toks), jnp.asarray(lengths)

    def _set_slot_controls(self, reqs: List[Request]):
        for r in reqs:
            self._temp[r.slot] = r.temperature
            self._topk[r.slot] = r.top_k
            self._topp[r.slot] = r.top_p
        self._controls_dev = None               # invalidate device copies

    def _controls(self):
        if self._controls_dev is None:
            self._controls_dev = (jnp.asarray(self._temp),
                                  jnp.asarray(self._topk),
                                  jnp.asarray(self._topp))
        return self._controls_dev

    def _first_tokens(self, reqs: List[Request], logits_rows):
        """Sample each new request's first token from its last-prompt-position
        logits (one vectorized call, per-slot keys)."""
        slots = np.asarray([r.slot for r in reqs])
        keys = self._keys[jnp.asarray(slots)]
        toks, new_keys = sampling.sample_tokens(
            logits_rows, keys,
            jnp.asarray(self._temp[slots]), jnp.asarray(self._topk[slots]),
            jnp.asarray(self._topp[slots]))
        self._keys = self._keys.at[jnp.asarray(slots)].set(new_keys)
        toks = np.asarray(toks)
        for i, r in enumerate(reqs):
            t = int(toks[i])
            r.out.append(t)
            self._last_token[r.slot] = t
            self.active[r.slot] = r
            if (r.eos is not None and t == r.eos) or len(r.out) >= r.max_new:
                self._retire(r.slot)

    def _admit(self):
        """Move queued requests into slots.  Whole-prompt mode prefills the
        admission group in one batched call; chunked mode enqueues the group
        as the prefill cohort processed by ``_prefill_step``.

        While a cohort is in flight (at most one at a time), requests at
        the queue head whose whole prompt fits in one chunk are still
        admitted into idle slots via the whole-prompt path -- a long
        prompt must not head-of-line-block short ones."""
        if self._cohort:
            group = self.scheduler.take(
                len(self.free), self.scheduler.cfg.prefill_chunk)
        else:
            group = self.scheduler.take(len(self.free))
        if not group:
            return
        for r in group:
            r.slot = self.free.pop(0)
        self._set_slot_controls(group)
        self.stats.admitted += len(group)

        if self._chunking and not self._cohort:
            self._cohort = group
            self._cohort_cache = None
            return

        toks, lengths = self._pad_batch(group, None)
        with self.stats.timed("prefill"):
            logits, rows = self._prefill(self.params, toks, lengths)
            jax.block_until_ready(logits)
        self.stats.prefill_calls += 1
        slots = jnp.asarray([r.slot for r in group])
        self.cache = self._splice(self.cache, rows, slots)
        for r in group:
            r.prefilled = len(r.prompt)
        self._first_tokens(group, logits)

    def _prefill_step(self):
        """Advance the chunked-prefill cohort by one fixed-size chunk."""
        if not self._cohort:
            return
        chunk = self.scheduler.cfg.prefill_chunk
        toks, lengths = self._pad_batch(self._cohort, chunk)
        with self.stats.timed("prefill"):
            if self._cohort_cache is None:
                logits, rows = self._prefill(self.params, toks, lengths)
            else:
                logits, rows = self._prefill_resume(
                    self.params, toks, lengths, self._cohort_cache)
            jax.block_until_ready(logits)
        self.stats.prefill_calls += 1

        lengths = np.asarray(lengths)
        finished, keep = [], []
        for i, r in enumerate(self._cohort):
            r.prefilled += int(lengths[i])
            (finished if r.prefilled >= len(r.prompt) else keep).append(i)
        if finished:
            done_reqs = [self._cohort[i] for i in finished]
            idx = jnp.asarray(finished)
            slots = jnp.asarray([r.slot for r in done_reqs])
            self.cache = self._splice(self.cache, _take_rows(rows, idx),
                                      slots)
            self._first_tokens(done_reqs, logits[idx])
        self._cohort = [self._cohort[i] for i in keep]
        self._cohort_cache = _take_rows(rows, jnp.asarray(keep)) \
            if keep else None

    # ------------------------------------------------------------------
    # Decode path
    # ------------------------------------------------------------------
    def _retire(self, slot: int):
        req = self.active.pop(slot)
        req.done = True
        self.finished[req.rid] = req
        self.free.append(slot)
        self.stats.completed += 1

    def _decode_fn(self, n: int):
        fn = self._decode_fns.get(n)
        if fn is None:
            cfg = self.cfg
            fn = jax.jit(lambda p, tok, cache, controls: lm.decode_many(
                p, cfg, tok, cache, n, controls))
            self._decode_fns[n] = fn
        return fn

    def _decode_controls(self):
        """Assemble the device-side control state for one decode_many call.

        Sampling controls are the cached device copies (invalidated only
        at admission); liveness / stop / length-cap vectors are rebuilt
        from the active table -- (B,)-sized uploads, negligible next to
        the K decode steps they steer.
        """
        alive = np.zeros((self.max_batch,), bool)
        remaining = np.zeros((self.max_batch,), np.int32)
        eos = np.full((self.max_batch,), -1, np.int32)
        for slot, req in self.active.items():
            alive[slot] = True
            remaining[slot] = req.max_new - len(req.out)
            if req.eos is not None:
                eos[slot] = req.eos
        temp, topk, topp = self._controls()
        return {"temperature": temp, "top_k": topk, "top_p": topp,
                "keys": self._keys, "eos": jnp.asarray(eos),
                "alive": jnp.asarray(alive),
                "remaining": jnp.asarray(remaining)}

    def step(self, n_tokens: Optional[int] = None) -> int:
        """Admit pending requests, advance chunked prefill by one chunk,
        decode up to ``n_tokens`` (default ``self.decode_block``) tokens
        for every active slot in ONE on-device loop.  Returns the number
        of requests still in flight (active + prefilling + queued).

        Slots that hit EOS or their length cap mid-buffer stop emitting
        on device (their tail positions read -1) and are retired -- and
        their slots refilled -- only when the call returns, so one host
        round-trip covers ``n_tokens`` decode steps.
        """
        k = max(1, int(n_tokens)) if n_tokens is not None \
            else self.decode_block
        self._admit()
        self._prefill_step()
        if self.active:
            tok = jnp.asarray(self._last_token)
            controls = self._decode_controls()
            with self.stats.timed("decode"):
                buf, self.cache, dstate = self._decode_fn(k)(
                    self.params, tok, self.cache, controls)
                self._keys = dstate["keys"]
                buf_np = np.asarray(buf)            # (B, k), -1 padded
            self.stats.decode_calls += 1
            self.stats.decode_steps += k
            for slot, req in list(self.active.items()):
                for t in buf_np[slot]:
                    t = int(t)
                    if t < 0:
                        break
                    req.out.append(t)
                    self._last_token[slot] = t
                    self.stats.decode_tokens += 1
                if (req.eos is not None and req.out
                        and req.out[-1] == req.eos) or \
                        len(req.out) >= req.max_new:
                    self._retire(slot)
        return len(self.active) + len(self._cohort) + len(self.scheduler)

    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 10_000
                          ) -> Dict[int, List[int]]:
        steps = 0
        while (len(self.scheduler) or self._cohort or self.active) \
                and steps < max_steps:
            self.step()
            steps += 1
        return {rid: r.out for rid, r in self.finished.items()}


def generate_one(cfg, params, prompt: List[int], max_new: int = 32,
                 max_len: int = 2048) -> List[int]:
    """Single-request greedy reference path (the engine parity oracle)."""
    logits, cache = lm.prefill(params, cfg, jnp.asarray([prompt], jnp.int32),
                               max_len)
    out = [int(np.asarray(logits)[0, :cfg.vocab_size].argmax())]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(params, cfg,
                                       jnp.asarray([out[-1]], jnp.int32),
                                       cache)
        out.append(int(np.asarray(logits)[0, :cfg.vocab_size].argmax()))
    return out
