"""Batched serving engine: parallel-scan prefill + slot-based continuous
batching decode.

The paper's serving story (§4.1, App. D.2): prefill processes the whole
prompt with the parallel scan (one forward), then decode rolls the O(1)
sequential cell.  The engine keeps a fixed-capacity batch of slots; new
requests prefill individually and their terminal state is spliced into
their slot, so decode always runs one fused step for every active request
(continuous batching, vLLM-style but with RNN/SSM states as first-class
cache kinds).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False


def _splice(cache_batch, cache_one, slot: int):
    """Write a prefilled (batch-1) cache into slot `slot`."""
    def upd(big, small):
        if big.ndim == 1:                       # pos: (B,)
            return big.at[slot].set(small[0])
        # (L, B, ...) or (B, ...)?  all our caches are (L, B, ...) except pos
        return big.at[:, slot].set(small[:, 0])

    return jax.tree.map(upd, cache_batch, cache_one)


class ServingEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 2048, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.cache = lm.init_cache(cfg, max_batch, max_len)
        self.free = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self._rng = np.random.default_rng(seed)
        self._last_token = np.zeros((max_batch,), np.int32)

        self._decode = jax.jit(
            lambda p, tok, cache: lm.decode_step(p, cfg, tok, cache))
        self._splice = jax.jit(_splice, static_argnums=(2,))

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32,
               temperature: float = 0.0, eos: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_new,
                                  temperature, eos))
        return rid

    # ------------------------------------------------------------------
    def _admit(self):
        while self.queue and self.free:
            req = self.queue.pop(0)
            slot = self.free.pop(0)
            req.slot = slot
            logits, cache_one = lm.prefill(
                self.params, self.cfg, jnp.asarray([req.prompt], jnp.int32),
                self.max_len)
            self.cache = self._splice(self.cache, cache_one, slot)
            tok = self._sample(np.asarray(logits)[0], req)
            req.out.append(int(tok))
            self._last_token[slot] = tok
            self.active[slot] = req

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        logits = logits[:self.cfg.vocab_size]
        if req.temperature <= 0:
            return int(logits.argmax())
        p = np.exp((logits - logits.max()) / req.temperature)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit pending requests, decode one token for every active slot.
        Returns the number of active requests after the step."""
        self._admit()
        if not self.active:
            return 0
        tok = jnp.asarray(self._last_token)
        logits, self.cache = self._decode(self.params, tok, self.cache)
        logits = np.asarray(logits)
        for slot, req in list(self.active.items()):
            t = self._sample(logits[slot], req)
            req.out.append(t)
            self._last_token[slot] = t
            if (req.eos is not None and t == req.eos) or \
                    len(req.out) >= req.max_new:
                req.done = True
                self.finished[req.rid] = req
                del self.active[slot]
                self.free.append(slot)
        return len(self.active)

    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return {rid: r.out for rid, r in self.finished.items()}


def generate_one(cfg, params, prompt: List[int], max_new: int = 32,
                 max_len: int = 2048) -> List[int]:
    """Single-request reference path (tests compare the engine to this)."""
    logits, cache = lm.prefill(params, cfg, jnp.asarray([prompt], jnp.int32),
                               max_len)
    out = [int(np.asarray(logits)[0, :cfg.vocab_size].argmax())]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(params, cfg,
                                       jnp.asarray([out[-1]], jnp.int32),
                                       cache)
        out.append(int(np.asarray(logits)[0, :cfg.vocab_size].argmax()))
    return out
