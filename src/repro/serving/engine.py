"""Serving engine v4: continuous batching as ONE on-device superstep.

The paper's serving advantage over Transformers is the O(1) recurrent
state (Were RNNs All We Needed?, section 4.1): a minGRU/minLSTM slot is a
fixed-size hidden vector, so swapping a finished request for a queued one
is a row write, not a KV-cache reshuffle.  This engine exploits that all
the way down: admission, prefill, decode, sampling and retirement ALL
happen inside one jitted device loop (``lm.superstep``), and the host's
only jobs are queueing, staging and draining.

Per engine ``step()``:

  * the host stages queued requests into per-slot **staging buffers**
    (device-resident ``s_*`` arrays in the slot state -- prompt tokens,
    length cap, stop token, sampling controls, request id);
  * ONE ``lm.superstep(params, cfg, state, K)`` call lax.scans K rounds
    of *token select -> fused block step -> sample-or-teacher-force ->
    EOS/retire -> re-admission from staging*.  Prefilling rows consume
    their next prompt token (teacher forcing) and decoding rows feed
    back their last sample, through the SAME ``lm.decode_step`` -- and
    therefore the same fused Pallas cell kernel (``kernels/decode_step``
    under the default ``scan_strategy="auto"``) -- in the same round.
    With ``prompt_chunk=C > 1`` (recurrent-state archs only) a
    prefilling row instead consumes up to C prompt tokens per round via
    the masked varlen chunk kernels (``lm.decode_chunk``): one weight
    stream per round amortises over C prompt tokens, winning back the
    weight-bound regime where one-token-per-round sequential prefill
    loses to the old parallel-prefill engine.  A row that hits EOS or
    its length cap is re-armed from its staging buffer on the *next
    device round*, with zero idle rounds and no host involvement;
  * the host drains the returned ``(B, K)`` token + request-id buffers
    (the rid plane demuxes rows that served two requests in one call),
    retires finished requests, and restocks staging.

With ``speculative`` set (a ``serving.draft`` source -- ``"ngram"``
self-drafting or a tiny draft model), decoding rows propose up to
``draft_len`` tokens per device round and the superstep verifies them in
ONE pass through the same varlen chunk kernels, rolling the O(1)
recurrent state back to the last accepted position with a single gather
(no recompute, no paged-KV surgery -- the paper's constant-size state
makes rollback O(d_hidden) per slot).  The drain buffers grow a plane
(``(B, K, draft_len + 1)``), a row can emit several tokens per round
(inter-token latency drops below one round), and streams stay
bit-identical to the non-speculative engine -- drafts only change
latency, never content.

There is no separate prefill phase, no chunked-prefill interleave and no
phase barrier: a long prompt occupies one row while every other row keeps
decoding.  Dead rows with nothing staged still step (the batch stays
dense, shapes stay static); ``stats.wasted_slot_steps`` counts exactly
those rows, and ``stats`` also tracks per-request time-to-first-token and
inter-token latency.  Greedy engine output is bit-identical to the
single-request ``generate_one`` reference -- which drives the prompt
through the same ``decode_step`` path -- for every cache kind and block
size, under any admission order, mid-superstep arrival and slot reuse
(tests/test_serving.py, tests/test_decode.py).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import draft as draft_lib
from repro.serving.scheduler import (EngineStats, FifoScheduler,
                                     SchedulerConfig)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    # latency bookkeeping (wall clock + device-round clock)
    submitted_s: float = 0.0
    submit_round: int = 0
    first_token_s: float = 0.0
    first_round: int = 0
    admit_seq: int = -1           # staging order (FIFO fairness witness)


# staged request fields mirrored host-side as numpy (uploaded on change;
# the device only *reads* them at arm time and only flips s_valid)
_STAGE_FIELDS = ("s_valid", "s_prompt", "s_prompt_len", "s_rid",
                 "s_remaining", "s_eos", "s_temperature", "s_top_k",
                 "s_top_p")


class ServingEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 2048, seed: int = 0,
                 decode_block: int = 1, prompt_chunk: int = 1,
                 speculative=None, draft_len: int = 4,
                 draft_params=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # K = device rounds per host round-trip (lm.superstep scan length)
        self.decode_block = max(1, int(decode_block))
        # C = prompt tokens consumed per round by a prefilling row: the
        # superstep's packed-prefill branch (weight-bound regime win --
        # one weight stream amortises over C prompt tokens).  Without
        # speculation emission stays <= 1 token per slot-round, so the
        # (B, K) drain buffers and greedy streams are identical across C.
        self.prompt_chunk = max(1, int(prompt_chunk))
        if self.prompt_chunk > 1 and not lm.supports_prompt_packing(cfg):
            raise ValueError(
                f"prompt_chunk={self.prompt_chunk} requires a recurrent-"
                f"state arch (block_kind='minrnn'); "
                f"{cfg.name} has block_kind={cfg.block_kind!r}")
        # speculative decoding: a draft source name ("ngram") or instance
        # (serving.draft).  Decoding rows then emit up to draft_len + 1
        # tokens per device round -- the drain buffers grow a plane --
        # with streams still bit-identical to the non-speculative engine.
        if isinstance(speculative, str):
            speculative = draft_lib.make(speculative, draft_len)
        self.draft = speculative
        self.draft_params = draft_params if draft_params is not None \
            else getattr(speculative, "params", None)
        if self.draft is not None and not lm.supports_prompt_packing(cfg):
            raise ValueError(
                f"speculative decoding requires a recurrent-state arch "
                f"(block_kind='minrnn'); "
                f"{cfg.name} has block_kind={cfg.block_kind!r}")
        self.state = lm.init_slot_state(cfg, max_batch, max_len, seed=seed,
                                        draft=self.draft)

        self.scheduler = FifoScheduler(SchedulerConfig(max_batch=max_batch))
        self.stats = EngineStats(prompt_chunk=self.prompt_chunk)
        self._next_rid = 0
        # host mirrors of slot occupancy: the request currently armed in
        # each row, and the request parked in each row's staging buffer
        self.current: List[Optional[Request]] = [None] * max_batch
        self.staged: List[Optional[Request]] = [None] * max_batch
        self.finished: Dict[int, Request] = {}

        # numpy mirrors of the device staging arrays (authoritative on
        # the host side: the device only consumes them, flipping s_valid;
        # the mirror is re-synced from the device after every superstep)
        self._smirror = {k: np.asarray(self.state[k]) for k in _STAGE_FIELDS}
        self._smirror = {k: v.copy() for k, v in self._smirror.items()}
        self._dirty_slots: List[int] = []
        # device-progress mirrors (synced after every superstep): how far
        # each row's prompt has actually been consumed, and which request
        # the device thinks the row is running -- the staging ETA reads
        # these instead of assuming the whole prompt is still pending
        self._prompt_pos = np.zeros((max_batch,), np.int32)
        self._rid_dev = np.full((max_batch,), -1, np.int32)

        # one compiled superstep program per distinct block size
        self._superstep_fns: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new: int = 32,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
               eos: Optional[int] = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        # a request consumes len(prompt) + max_new - 1 cache positions:
        # the first output token is sampled at the last prompt position,
        # and the final output token is emitted without being fed back
        if len(prompt) + max_new - 1 > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) needs "
                f"{len(prompt) + max_new - 1} cache positions, exceeding "
                f"engine max_len ({self.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new, temperature, top_k,
                      top_p, eos)
        req.submitted_s = time.perf_counter()
        req.submit_round = self.stats.decode_steps
        self.scheduler.submit(req)
        self.stats.submitted += 1
        self.stats.observe_queue(len(self.scheduler))
        return rid

    # ------------------------------------------------------------------
    # Staging (host side of admission; the device does the arming)
    # ------------------------------------------------------------------
    def _row_eta(self, slot: int) -> int:
        """Upper bound on device rounds until this row frees up (0 for an
        idle row).  Drives staging placement: within one staging round,
        earlier-submitted requests park behind sooner-to-free rows.
        Prompt consumption is packed ``prompt_chunk`` tokens per round,
        so the prefill term is ``ceil(prompt_left / C)`` rounds over the
        prompt tokens the device has NOT yet consumed -- the synced
        ``prompt_pos`` mirror, not the full prompt length, which would
        overestimate a mid-prefill row by up to its whole prompt.  Under
        speculative decoding the decode term stays an upper bound (every
        round commits at least one token).  This is greedy per call, not
        a global ordering guarantee -- arrivals in a *later* round can
        still land on a row that frees up before an earlier request's
        row does; strict FIFO holds for staging order (``admit_seq``),
        not start order."""
        req = self.current[slot]
        if req is None:
            return 0
        if req.out:
            prompt_left = 0
        else:
            # trust the device mirror only when the row is actually
            # running THIS request (it may still be parked in staging)
            consumed = int(self._prompt_pos[slot]) \
                if int(self._rid_dev[slot]) == req.rid else 0
            prompt_left = max(0, len(req.prompt) - consumed)
        prompt_rounds = -(-prompt_left // self.prompt_chunk)
        return prompt_rounds + req.max_new - len(req.out)

    def _stage(self):
        """Park queued requests into empty staging buffers, strict FIFO.

        Rows whose current request is finished (or that never held one)
        are preferred so the device arms the request on the very next
        round; the remaining buffers are lookahead -- the request arms
        the moment its row dies, mid-superstep, with zero idle rounds.
        Busy rows are filled in order of estimated rounds-to-free
        (``_row_eta``), keeping staging placement aligned with
        submission order.
        """
        empty = [i for i in range(self.max_batch) if self.staged[i] is None]
        empty.sort(key=lambda i: (self._row_eta(i), i))
        group = self.scheduler.take(len(empty))
        if not group:
            return
        m = self._smirror
        for req, slot in zip(group, empty):
            req.slot = slot
            req.admit_seq = self.stats.admitted
            self.staged[slot] = req
            m["s_prompt"][slot, :] = 0
            m["s_prompt"][slot, :len(req.prompt)] = req.prompt
            m["s_prompt_len"][slot] = len(req.prompt)
            m["s_rid"][slot] = req.rid
            m["s_remaining"][slot] = req.max_new
            m["s_eos"][slot] = -1 if req.eos is None else req.eos
            m["s_temperature"][slot] = req.temperature
            m["s_top_k"][slot] = req.top_k
            m["s_top_p"][slot] = req.top_p
            m["s_valid"][slot] = True
            self.stats.admitted += 1
            self._dirty_slots.append(slot)

    def _upload_staging(self):
        """Push newly staged rows to the device.  The (B,) control
        vectors are re-uploaded whole (a few words); the (B, max_len)
        prompt matrix -- the only leaf whose full upload would scale
        with max_len -- is scattered row-wise for just the dirty slots.
        """
        if not self._dirty_slots:
            return
        rows = jnp.asarray(sorted(set(self._dirty_slots)))
        self.state["s_prompt"] = self.state["s_prompt"].at[rows].set(
            jnp.asarray(self._smirror["s_prompt"][np.asarray(rows)]))
        for k in _STAGE_FIELDS:
            if k != "s_prompt":
                self.state[k] = jnp.asarray(self._smirror[k])
        self._dirty_slots = []

    # ------------------------------------------------------------------
    # The superstep
    # ------------------------------------------------------------------
    def _superstep_fn(self, n: int):
        fn = self._superstep_fns.get(n)
        if fn is None:
            cfg, chunk, draft = self.cfg, self.prompt_chunk, self.draft
            fn = jax.jit(lambda p, dp, s: lm.superstep(
                p, cfg, s, n, prompt_chunk=chunk, draft=draft,
                draft_params=dp))
            self._superstep_fns[n] = fn
        return fn

    def _promote(self, slot: int) -> Request:
        """The device armed this row's staged request: update mirrors."""
        prev = self.current[slot]
        assert prev is None or prev.done, \
            "device armed a row whose request the host still thinks is live"
        req = self.staged[slot]
        assert req is not None
        self.current[slot] = req
        self.staged[slot] = None
        return req

    def _finish(self, req: Request, now: float, last_round: int):
        req.done = True
        self.finished[req.rid] = req
        self.current[req.slot] = None
        self.stats.completed += 1
        self.stats.record_completion(len(req.out), req.first_round,
                                     last_round, req.first_token_s, now)

    def step(self, n_tokens: Optional[int] = None) -> int:
        """Stage pending requests, then run ONE on-device superstep of
        ``n_tokens`` (default ``self.decode_block``) rounds: every slot
        advances one token per round -- its next prompt token while
        prefilling, a sampled token while decoding -- and slots that
        retire mid-call are re-armed from staging in-loop.  Returns the
        number of requests still in flight (armed + staged + queued).
        """
        k = max(1, int(n_tokens)) if n_tokens is not None \
            else self.decode_block
        self._stage()
        if not any(self.current) and not any(self.staged):
            return len(self.scheduler)
        self._upload_staging()

        with self.stats.timed("decode"):
            toks, rids, self.state, counters = self._superstep_fn(k)(
                self.params, self.draft_params, self.state)
            toks_np = np.asarray(toks)
            rids_np = np.asarray(rids)
            s_valid_np = np.asarray(self.state["s_valid"])
            self._prompt_pos[:] = np.asarray(self.state["prompt_pos"])
            self._rid_dev[:] = np.asarray(self.state["rid"])
        if toks_np.ndim == 2:       # non-speculative: one plane per round
            toks_np = toks_np[:, :, None]
            rids_np = rids_np[:, :, None]
        base_round = self.stats.decode_steps
        self.stats.decode_calls += 1
        self.stats.decode_steps += k
        self.stats.slot_steps += k * self.max_batch
        self.stats.prefill_tokens += int(counters["prefill_steps"])
        self.stats.prefill_rounds += int(counters["prefill_rounds"])
        self.stats.wasted_slot_steps += int(counters["wasted_slot_steps"])
        self.stats.draft_proposed += int(counters.get("draft_proposed", 0))
        self.stats.draft_accepted += int(counters.get("draft_accepted", 0))

        now = time.perf_counter()
        drained = 0
        for slot in range(self.max_batch):
            for j in range(k):
                for c in range(toks_np.shape[2]):
                    rid = int(rids_np[slot, j, c])
                    if rid < 0:
                        continue
                    req = self.current[slot]
                    if req is None or req.rid != rid:
                        req = self._promote(slot)   # armed mid-superstep
                        assert req.rid == rid, (req.rid, rid)
                    t = int(toks_np[slot, j, c])
                    if not req.out:
                        req.first_token_s = now
                        req.first_round = base_round + j
                        self.stats.record_first_token(
                            now - req.submitted_s,
                            base_round + j + 1 - req.submit_round)
                    req.out.append(t)
                    drained += 1
                    if (req.eos is not None and t == req.eos) or \
                            len(req.out) >= req.max_new:
                        self._finish(req, now, base_round + j)
            # armed without emitting yet (still prefilling at call end)
            if self.staged[slot] is not None and not s_valid_np[slot]:
                self._promote(slot)
        self.stats.decode_tokens += drained
        # non_spec_tokens: tokens the non-speculative path contributes --
        # one per emitting slot-round.  The device counts those rounds
        # under speculation; without it every drained token is one.
        self.stats.non_spec_tokens += int(
            counters["emit_rounds"]) if "emit_rounds" in counters \
            else drained
        # re-sync the staging mirror with what the device consumed
        self._smirror["s_valid"][:] = s_valid_np
        return (sum(r is not None for r in self.current)
                + sum(r is not None for r in self.staged)
                + len(self.scheduler))

    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 100_000
                          ) -> Dict[int, List[int]]:
        steps = 0
        while (len(self.scheduler) or any(self.current)
               or any(self.staged)) and steps < max_steps:
            self.step()
            steps += 1
        return {rid: r.out for rid, r in self.finished.items()}


def replay_trace(engine: ServingEngine, trace: List[Dict[str, Any]],
                 submit, max_steps: int = 100_000) -> None:
    """Drive ``engine`` over an arrival trace until every request
    completes.  The arrival clock is the engine's device-round counter:
    request ``i`` is submitted via ``submit(i, trace[i])`` once
    ``trace[i]["arrival"] <= stats.decode_steps`` -- or immediately when
    the engine is idle, so a gap in arrivals cannot stall the round
    clock.  Shared by the arrival-trace bench, the serving example and
    the scheduler property tests so the replay semantics live in one
    place."""
    i, steps = 0, 0
    while i < len(trace) or engine.stats.completed < i:
        due = i < len(trace) and \
            trace[i]["arrival"] <= engine.stats.decode_steps
        idle = engine.stats.completed == i
        while i < len(trace) and (due or idle):
            submit(i, trace[i])
            i += 1
            due = i < len(trace) and \
                trace[i]["arrival"] <= engine.stats.decode_steps
            idle = False
        engine.step()
        steps += 1
        if steps >= max_steps:
            raise RuntimeError(
                f"arrival trace did not drain within {max_steps} steps "
                f"({engine.stats.completed}/{i} submitted requests done)")


@functools.lru_cache(maxsize=32)
def _decode_step_fn(cfg):
    """One compiled decode step per config (configs are frozen/hashable);
    repeated generate_one calls share it instead of re-tracing."""
    return jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))


def generate_one(cfg, params, prompt: List[int], max_new: int = 32,
                 max_len: int = 2048) -> List[int]:
    """Single-request greedy reference path (the engine parity oracle).

    Drives the prompt token-by-token through ``lm.decode_step`` -- the
    same unified code path the engine superstep uses for prefill and
    decode -- so engine streams are bit-comparable for every cache kind.
    (The parallel ``lm.prefill`` scan matches this path to fp32 rounding;
    the padding-invariance tests in tests/test_serving.py pin that
    equivalence on the parallel side, and
    test_generate_one_matches_parallel_prefill pins it here.)
    """
    # same cache-position budget as ServingEngine.submit: the request
    # consumes len(prompt) + max_new - 1 positions.  KV-cache archs would
    # otherwise scatter past max_len (silently dropped under jit -- wrong
    # attention), recurrent archs would just mis-count; both are bugs.
    if len(prompt) + max_new - 1 > max_len:
        raise ValueError(
            f"prompt ({len(prompt)}) + max_new ({max_new}) needs "
            f"{len(prompt) + max_new - 1} cache positions, exceeding "
            f"max_len ({max_len})")
    cache = lm.init_cache(cfg, 1, max_len)
    step = _decode_step_fn(cfg)
    logits = None
    for t in prompt:
        logits, cache = step(params, jnp.asarray([t], jnp.int32), cache)
    out = [int(np.asarray(logits)[0, :cfg.vocab_size].argmax())]
    for _ in range(max_new - 1):
        logits, cache = step(params, jnp.asarray([out[-1]], jnp.int32),
                             cache)
        out.append(int(np.asarray(logits)[0, :cfg.vocab_size].argmax()))
    return out
