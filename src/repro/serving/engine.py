"""Serving engine v5: continuous batching as ONE on-device superstep,
with a fault-tolerance layer (admission control, deadlines, cancellation,
NaN-quarantine, deterministic chaos injection).

The paper's serving advantage over Transformers is the O(1) recurrent
state (Were RNNs All We Needed?, section 4.1): a minGRU/minLSTM slot is a
fixed-size hidden vector, so swapping a finished request for a queued one
is a row write, not a KV-cache reshuffle.  This engine exploits that all
the way down: admission, prefill, decode, sampling and retirement ALL
happen inside one jitted device loop (``lm.superstep``), and the host's
only jobs are queueing, staging and draining.

Per engine ``step()``:

  * the host sweeps deadlines (queued, staged and in-flight requests can
    all time out; in-flight kills retire the slot between supersteps and
    preserve partial output), then stages queued requests into per-slot
    **staging buffers** (device-resident ``s_*`` arrays in the slot
    state -- prompt tokens, length cap, stop token, sampling controls,
    request id);
  * ONE ``lm.superstep(params, cfg, state, K)`` call lax.scans K rounds
    of *token select -> fused block step -> sample-or-teacher-force ->
    EOS/retire -> re-admission from staging*.  Prefilling rows consume
    their next prompt token (teacher forcing) and decoding rows feed
    back their last sample, through the SAME ``lm.decode_step`` -- and
    therefore the same fused Pallas cell kernel (``kernels/decode_step``
    under the default ``scan_strategy="auto"``) -- in the same round.
    With ``prompt_chunk=C > 1`` (recurrent-state archs only) a
    prefilling row instead consumes up to C prompt tokens per round via
    the masked varlen chunk kernels (``lm.decode_chunk``);
  * the host drains the returned ``(B, K)`` token + request-id buffers
    (the rid plane demuxes rows that served two requests in one call),
    retires finished requests, quarantines rows the in-loop numerical
    health guard killed (re-enqueueing their request under a bounded
    retry budget with backoff), and restocks staging.

**Failure model** (see README "Failure model" for the full diagram):

  * ``submit`` returns a request id unconditionally; the *admission
    verdict* (``scheduler.ADMITTED`` / ``REJECTED_QUEUE_FULL`` /
    ``SHED_UNMEETABLE_DEADLINE``) lands on ``request.verdict``.  A
    rejected or shed request is terminal immediately (status SHED) --
    under a bounded queue the engine sheds load instead of growing
    without bound.
  * Every request ends in exactly one terminal status: COMPLETED,
    CANCELLED (``engine.cancel(rid)``), TIMED_OUT (per-request round
    deadline), FAILED (non-finite state, retry budget exhausted) or
    SHED.  ``stats`` counts each.
  * A row whose activations go non-finite is killed *in-loop* by the
    superstep's health guard (its emission is suppressed, so garbage
    never reaches a stream) and re-armed through the same state-zeroing
    path normal re-admission uses; the host re-enqueues the poisoned
    request with exponential round backoff until ``max_retries``.
  * ``faults`` (a ``serving.faults.FaultInjector``) injects NaN state
    corruption, dropped staging uploads and straggler stalls at named
    points in ``step`` -- deterministic, seeded, fully inert when None.
  * Speculative decoding degrades gracefully: a rolling accept-rate
    floor (``spec_accept_floor``) disables drafting when a hostile
    input stream makes verify rounds pure overhead.
  * With ``recover_dir`` set the engine is crash tolerant: every
    submit/cancel/step goes to a write-ahead journal and the full
    serving state snapshots every ``snapshot_every`` rounds, so
    ``ServingEngine.restore`` on a fresh process resumes with streams
    bit-identical to an uninterrupted run (serving/recovery.py).
  * A scheduled ``shard_crash`` fault kills a whole data shard of the
    slot pool: the engine marks its rows dead, drains the shard's
    staged + in-flight requests onto the survivors through the requeue
    path (no retry budget burned) and serves degraded --
    ``stats.shard_crashes`` / ``stats.failover_requeued`` count it.

With ``speculative`` set (a ``serving.draft`` source -- ``"ngram"``
self-drafting or a tiny draft model), decoding rows propose up to
``draft_len`` tokens per device round and the superstep verifies them in
ONE pass through the same varlen chunk kernels, rolling the O(1)
recurrent state back to the last accepted position with a single gather.
Streams stay bit-identical to the non-speculative engine -- drafts only
change latency, never content.

There is no separate prefill phase, no chunked-prefill interleave and no
phase barrier: a long prompt occupies one row while every other row keeps
decoding.  Dead rows with nothing staged still step (the batch stays
dense, shapes stay static); ``stats.wasted_slot_steps`` counts exactly
those rows, and ``stats`` also tracks per-request time-to-first-token and
inter-token latency.  Greedy engine output is bit-identical to the
single-request ``generate_one`` reference -- which drives the prompt
through the same ``decode_step`` path -- for every cache kind and block
size, under any admission order, mid-superstep arrival and slot reuse
(tests/test_serving.py, tests/test_decode.py, tests/test_faults.py).
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as minrnn_blocks
from repro.distributed import serve_mesh
from repro.models import lm
from repro.serving import draft as draft_lib
from repro.serving import sampling
from repro.serving import tuning
from repro.serving.scheduler import (ADMITTED, REJECTED_QUEUE_FULL,
                                     AdmissionScheduler, EngineStats,
                                     SchedulerConfig, ShardStats)

# ---------------------------------------------------------------------------
# Request lifecycle: QUEUED -> STAGED -> RUNNING -> one terminal status
# (a quarantine retry moves FAILED-candidate requests back to QUEUED).
# ---------------------------------------------------------------------------
QUEUED = "QUEUED"
STAGED = "STAGED"
RUNNING = "RUNNING"
COMPLETED = "COMPLETED"
CANCELLED = "CANCELLED"
TIMED_OUT = "TIMED_OUT"
FAILED = "FAILED"
SHED = "SHED"
TERMINAL_STATUSES = frozenset(
    {COMPLETED, CANCELLED, TIMED_OUT, FAILED, SHED})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    # robustness: scheduling class, lifecycle and retry bookkeeping
    priority: int = 1             # lower = more urgent (EDF tie-break)
    deadline: Optional[int] = None  # absolute device round, or None
    status: str = QUEUED
    verdict: Optional[str] = None   # admission verdict (scheduler.*)
    retries: int = 0
    not_before: int = 0           # retry-backoff gate (device round)
    # latency bookkeeping (wall clock + device-round clock)
    submitted_s: float = 0.0
    submit_round: int = 0
    first_token_s: float = 0.0
    first_round: int = 0
    admit_seq: int = -1           # staging order (FIFO fairness witness)


class EngineStallError(RuntimeError):
    """``run_to_completion`` exceeded ``max_steps`` with work still
    pending.  ``.report`` carries the queue + per-slot occupancy
    snapshot (``ServingEngine.occupancy_report``) so hangs are
    diagnosable instead of silent."""

    def __init__(self, message: str, report: Dict[str, Any]):
        super().__init__(message)
        self.report = report


# staged request fields mirrored host-side as numpy (uploaded on change;
# the device only *reads* them at arm time and only flips s_valid)
_STAGE_FIELDS = ("s_valid", "s_prompt", "s_prompt_len", "s_rid",
                 "s_remaining", "s_eos", "s_temperature", "s_top_k",
                 "s_top_p")


class ServingEngine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 2048, seed: int = 0,
                 decode_block: Optional[int] = None,
                 prompt_chunk: Optional[int] = None,
                 speculative=None, draft_len: int = 4,
                 draft_params=None,
                 max_queue: int = 0, high_watermark: float = 1.0,
                 low_watermark: float = 0.5, aging_rounds: int = 64,
                 max_retries: int = 1, retry_backoff: int = 8,
                 spec_accept_floor: Optional[float] = None,
                 spec_window: int = 8, spec_cooldown: int = 0,
                 faults=None, mesh=None,
                 fuse_block: Optional[str] = None, tune=None,
                 recover_dir: Optional[str] = None,
                 snapshot_every: int = 8, snapshot_keep: int = 3):
        # autotuned tile plan (serving/tuning.py): ``tune`` is None (no
        # plan -- historical behavior byte for byte), "auto" (TUNE_*.json
        # discovery order), a path, or a plan dict.  The plan supplies
        # kernel tiling (block_dh) and scheduling defaults (decode_block
        # / prompt_chunk) -- explicit constructor arguments always win.
        # ``fuse_block`` ("auto"|"on"|"off") overrides the config knob.
        self.tune_plan = tuning.resolve_plan(cfg, tune)
        if self.tune_plan is not None:
            cfg = tuning.apply_plan(cfg, self.tune_plan)
            if decode_block is None:
                decode_block = self.tune_plan.get("decode_block")
            if prompt_chunk is None:
                prompt_chunk = self.tune_plan.get("prompt_chunk")
        if fuse_block is not None and fuse_block != cfg.fuse_block:
            cfg = cfg.replace(fuse_block=fuse_block)
        decode_block = 1 if decode_block is None else decode_block
        prompt_chunk = 1 if prompt_chunk is None else prompt_chunk
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.seed = int(seed)
        # K = device rounds per host round-trip (lm.superstep scan length)
        self.decode_block = max(1, int(decode_block))
        # C = prompt tokens consumed per round by a prefilling row: the
        # superstep's packed-prefill branch (weight-bound regime win --
        # one weight stream amortises over C prompt tokens).  Without
        # speculation emission stays <= 1 token per slot-round, so the
        # (B, K) drain buffers and greedy streams are identical across C.
        self.prompt_chunk = max(1, int(prompt_chunk))
        if self.prompt_chunk > 1 and not lm.supports_prompt_packing(cfg):
            raise ValueError(
                f"prompt_chunk={self.prompt_chunk} requires a recurrent-"
                f"state arch (block_kind='minrnn'); "
                f"{cfg.name} has block_kind={cfg.block_kind!r}")
        # speculative decoding: a draft source name ("ngram") or instance
        # (serving.draft).  Decoding rows then emit up to draft_len + 1
        # tokens per device round -- the drain buffers grow a plane --
        # with streams still bit-identical to the non-speculative engine.
        if isinstance(speculative, str):
            speculative = draft_lib.make(speculative, draft_len)
        self.draft = speculative
        self.draft_params = draft_params if draft_params is not None \
            else getattr(speculative, "params", None)
        if self.draft is not None and not lm.supports_prompt_packing(cfg):
            raise ValueError(
                f"speculative decoding requires a recurrent-state arch "
                f"(block_kind='minrnn'); "
                f"{cfg.name} has block_kind={cfg.block_kind!r}")
        # mesh-sharded serving (``--mesh dxm``): the slot pool splits
        # into ``data`` contiguous row groups (shard s owns rows
        # [s*B/d, (s+1)*B/d)) and ``model`` shards d_hidden for the gate
        # projections (see distributed/serve_mesh.py).  None keeps the
        # original single-device path byte for byte.
        self.mesh_plan = serve_mesh.MeshPlan.parse(mesh)
        self.mesh = None
        if self.mesh_plan is not None:
            plan = self.mesh_plan
            if max_batch % plan.data != 0:
                raise ValueError(
                    f"max_batch ({max_batch}) must divide over the data "
                    f"axis ({plan.data}): each shard owns "
                    f"max_batch/data contiguous slot rows")
            if plan.model > 1:
                if cfg.block_kind != "minrnn":
                    raise ValueError(
                        f"tensor-parallel serving (model axis "
                        f"{plan.model} > 1) shards d_hidden and requires "
                        f"block_kind='minrnn'; {cfg.name} has "
                        f"block_kind={cfg.block_kind!r}")
                if not serve_mesh._tp_shards_hidden(cfg, plan):
                    raise ValueError(
                        f"d_hidden of {cfg.name} does not divide over "
                        f"the model axis ({plan.model}); pick a model "
                        f"size that divides d_hidden")
            self.mesh = plan.build()
        self.dp = self.mesh_plan.data if self.mesh_plan is not None else 1
        self._rows_per_shard = max_batch // self.dp
        self.state = lm.init_slot_state(cfg, max_batch, max_len, seed=seed,
                                        draft=self.draft)
        if self.mesh is not None:
            # pin the NamedShardings up front so the superstep's shard_map
            # consumes in-place instead of resharding every call
            self.state = jax.device_put(
                self.state, serve_mesh.slot_state_shardings(
                    cfg, self.state, self.mesh_plan, self.mesh))
            self.params = jax.device_put(
                params, serve_mesh.serve_params_shardings(
                    params, cfg, self.mesh_plan, self.mesh))
            if self.draft_params is not None:
                from jax.sharding import NamedSharding, PartitionSpec
                self.draft_params = jax.device_put(
                    self.draft_params,
                    NamedSharding(self.mesh, PartitionSpec()))

        self.scheduler = AdmissionScheduler(SchedulerConfig(
            max_batch=max_batch, max_queue=max_queue,
            high_watermark=high_watermark, low_watermark=low_watermark,
            aging_rounds=aging_rounds))
        self.stats = EngineStats(
            prompt_chunk=self.prompt_chunk,
            shards=[ShardStats() for _ in range(self.dp)])
        # fault tolerance: quarantine retry budget + backoff (rounds),
        # chaos injector (None = fully inert), speculative degradation
        self.max_retries = max(0, int(max_retries))
        self.retry_backoff = max(0, int(retry_backoff))
        self.faults = faults
        self.spec_accept_floor = spec_accept_floor
        self.spec_window = max(1, int(spec_window))
        self.spec_cooldown = max(0, int(spec_cooldown))
        self._spec_active = True
        self._spec_hist: List = []      # (proposed, accepted) per call
        self._spec_off_calls = 0
        # DP-shard failover: data shards whose slot rows a scheduled
        # shard_crash killed.  Dead rows never stage again (they keep
        # stepping as wasted_slot_steps so the slot-step identity holds
        # per shard); their requests drain onto the survivors.
        self.dead_shards: set = set()
        self._next_rid = 0
        # host mirrors of slot occupancy: the request currently armed in
        # each row, and the request parked in each row's staging buffer
        self.current: List[Optional[Request]] = [None] * max_batch
        self.staged: List[Optional[Request]] = [None] * max_batch
        self.finished: Dict[int, Request] = {}
        self.requests: Dict[int, Request] = {}   # rid -> every request

        # numpy mirrors of the device staging arrays (authoritative on
        # the host side: the device only consumes them, flipping s_valid;
        # the mirror is re-synced from the device after every superstep)
        self._smirror = {k: np.asarray(self.state[k]) for k in _STAGE_FIELDS}
        self._smirror = {k: v.copy() for k, v in self._smirror.items()}
        self._dirty_slots: List[int] = []
        # device-progress mirrors (synced after every superstep): how far
        # each row's prompt has actually been consumed, and which request
        # the device thinks the row is running -- the staging ETA reads
        # these instead of assuming the whole prompt is still pending
        self._prompt_pos = np.zeros((max_batch,), np.int32)
        self._rid_dev = np.full((max_batch,), -1, np.int32)

        # one compiled superstep program per (block size, drafting on)
        self._superstep_fns: Dict[Any, Any] = {}

        # crash recovery (serving/recovery.py): with ``recover_dir`` set
        # the engine journals every submit/cancel/step to a write-ahead
        # log and snapshots its full serving state every
        # ``snapshot_every`` device rounds, so ``ServingEngine.restore``
        # on a fresh process resumes bit-identically.  Constructing with
        # recover_dir starts a NEW journal epoch (truncating any prior
        # one) -- resuming goes through ``restore``, never through a
        # fresh construction.  None keeps the engine journal-free.
        self.snapshot_every = max(1, int(snapshot_every))
        self.snapshot_keep = max(1, int(snapshot_keep))
        self.recover_dir = recover_dir
        self._last_snapshot_round = 0
        self.journal = None
        self.recovery_report: Optional[Dict[str, Any]] = None
        if recover_dir is not None:
            from repro.serving import recovery
            os.makedirs(recover_dir, exist_ok=True)
            self.journal = recovery.Journal.create(
                os.path.join(recover_dir, recovery.JOURNAL_NAME),
                recovery.engine_header(self))

    # ------------------------------------------------------------------
    # Submission + admission control
    # ------------------------------------------------------------------
    @property
    def kernel_tier(self) -> str:
        """Which decode kernel tier serves this engine: "block-fused"
        (whole block per pallas_call, kernels/block_step), "cell-fused"
        (cell-only kernel) or "unfused".  Tensor-parallel serving shards
        the row-parallel projections, whose psum must stay outside the
        kernel, so TP meshes report the cell tier.  Surfaced on the
        launch/example stats lines."""
        if self.cfg.block_kind != "minrnn":
            return "unfused"
        tier = minrnn_blocks.fuse_block_tier(lm._minrnn_block_cfg(self.cfg))
        if tier == "block-fused" and self.mesh_plan is not None \
                and self.mesh_plan.model > 1:
            return "cell-fused"
        return tier

    def _service_rounds(self, req: Request) -> int:
        """Rounds a request occupies a row end to end: packed prefill
        plus decode, minus the first-token/last-prefill overlap."""
        return -(-len(req.prompt) // self.prompt_chunk) + req.max_new - 1

    def _est_finish_round(self, req: Request) -> int:
        """Capacity estimate: the absolute device round by which ``req``
        could plausibly finish, built from the ``_row_eta`` rounds-to-
        free machinery.  Queued + staged work ahead of it is placed
        greedily on the earliest-freeing rows; this is an estimate (EDF
        reordering and speculative multi-emit shift it), used only to
        shed requests whose deadline even the estimate cannot meet.
        Rows on a crashed data shard never free up and are excluded --
        a dead row's eta of 0 would otherwise absorb the whole queue and
        the shedder would admit work the survivors cannot serve."""
        live = [s for s in range(self.max_batch)
                if s // self._rows_per_shard not in self.dead_shards]
        if not live:
            return 1 << 62      # total outage: nothing can ever finish
        etas = [self._row_eta(s) for s in live]
        for i, slot in enumerate(live):
            parked = self.staged[slot]
            if parked is not None:
                etas[i] += self._service_rounds(parked)
        heapq.heapify(etas)
        for ahead in self.scheduler.waiting:
            heapq.heappush(etas,
                           heapq.heappop(etas) + self._service_rounds(ahead))
        return (self.stats.decode_steps + min(etas)
                + self._service_rounds(req))

    def submit(self, prompt: List[int], max_new: int = 32,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
               eos: Optional[int] = None, priority: int = 1,
               deadline: Optional[int] = None) -> int:
        """Submit a request; always returns its rid.  The admission
        verdict lands on ``engine.requests[rid].verdict``: a request the
        bounded queue rejects or the deadline shedder refuses is
        terminal immediately with status SHED (empty output).

        ``priority`` is the scheduling class (lower = more urgent);
        ``deadline`` is a device-round budget relative to submission --
        the request is TIMED_OUT (partial output preserved) once the
        round clock passes ``submit_round + deadline``, whether it is
        queued, staged or in flight.  Deadline enforcement happens at
        host round-trip boundaries, so it quantises to ``decode_block``.
        """
        if not prompt:
            raise ValueError("empty prompt")
        # a request consumes len(prompt) + max_new - 1 cache positions:
        # the first output token is sampled at the last prompt position,
        # and the final output token is emitted without being fed back
        if len(prompt) + max_new - 1 > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) needs "
                f"{len(prompt) + max_new - 1} cache positions, exceeding "
                f"engine max_len ({self.max_len})")
        sampling.validate_controls(temperature, top_k, top_p)
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be a positive device-round "
                             f"budget, got {deadline!r}")
        rid = self._next_rid
        if self.journal is not None:
            # write-ahead: the record is durable BEFORE the engine
            # mutates, and can promise the rid because rid assignment is
            # deterministic
            self.journal.record_submit({
                "rid": rid, "round": self.stats.decode_steps,
                "prompt": [int(t) for t in prompt],
                "max_new": int(max_new),
                "temperature": float(temperature), "top_k": int(top_k),
                "top_p": float(top_p),
                "eos": None if eos is None else int(eos),
                "priority": int(priority),
                "deadline": None if deadline is None else int(deadline)})
        self._next_rid += 1
        req = Request(rid, [int(t) for t in prompt], max_new, temperature,
                      top_k, top_p, eos, priority=priority)
        req.submitted_s = time.perf_counter()
        req.submit_round = self.stats.decode_steps
        if deadline is not None:
            req.deadline = req.submit_round + int(deadline)
        self.requests[rid] = req
        self.stats.submitted += 1
        est = self._est_finish_round(req) if req.deadline is not None \
            else None
        req.verdict = self.scheduler.submit(
            req, now_round=req.submit_round, est_finish=est)
        if req.verdict == ADMITTED:
            req.status = QUEUED
            self.stats.observe_queue(len(self.scheduler))
        else:
            self._retire(req, SHED)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is in the lifecycle.  Queued and
        staged requests retire with empty output; an in-flight request
        has its slot killed between supersteps and keeps the tokens
        already drained (partial output).  Returns True if the request
        transitioned to CANCELLED, False if it is unknown or already
        terminal."""
        if self.journal is not None:
            # journaled even when a no-op: replay re-executes the same
            # call and reaches the same verdict deterministically
            self.journal.record_cancel({"rid": int(rid),
                                        "round": self.stats.decode_steps})
        req = self.requests.get(rid)
        if req is None or req.done:
            return False
        if self.scheduler.remove(req):
            self._retire(req, CANCELLED)
            return True
        if req.slot is not None and self.staged[req.slot] is req:
            self._unstage(req.slot)
            self._retire(req, CANCELLED)
            return True
        if req.slot is not None and self.current[req.slot] is req:
            self._kill_inflight(req, CANCELLED)
            return True
        return False

    # ------------------------------------------------------------------
    # Staging (host side of admission; the device does the arming)
    # ------------------------------------------------------------------
    def _row_eta(self, slot: int) -> int:
        """Upper bound on device rounds until this row frees up (0 for an
        idle row).  Drives staging placement: within one staging round,
        earlier-submitted requests park behind sooner-to-free rows.
        Prompt consumption is packed ``prompt_chunk`` tokens per round,
        so the prefill term is ``ceil(prompt_left / C)`` rounds over the
        prompt tokens the device has NOT yet consumed -- the synced
        ``prompt_pos`` mirror, not the full prompt length, which would
        overestimate a mid-prefill row by up to its whole prompt.  Under
        speculative decoding the decode term stays an upper bound (every
        round commits at least one token).  This is greedy per call, not
        a global ordering guarantee -- arrivals in a *later* round can
        still land on a row that frees up before an earlier request's
        row does; strict FIFO holds for staging order (``admit_seq``),
        not start order."""
        req = self.current[slot]
        if req is None:
            return 0
        if req.out:
            prompt_left = 0
        else:
            # trust the device mirror only when the row is actually
            # running THIS request (it may still be parked in staging)
            consumed = int(self._prompt_pos[slot]) \
                if int(self._rid_dev[slot]) == req.rid else 0
            prompt_left = max(0, len(req.prompt) - consumed)
        prompt_rounds = -(-prompt_left // self.prompt_chunk)
        return prompt_rounds + req.max_new - len(req.out)

    def _stage(self):
        """Park queued requests into empty staging buffers in scheduler
        order (aged priority, then earliest deadline, then submission --
        strict FIFO in the default single-class/no-deadline config).

        Rows whose current request is finished (or that never held one)
        are preferred so the device arms the request on the very next
        round; the remaining buffers are lookahead -- the request arms
        the moment its row dies, mid-superstep, with zero idle rounds.
        Busy rows are filled in order of estimated rounds-to-free
        (``_row_eta``), keeping staging placement aligned with
        scheduler order.

        Under a data-parallel mesh every slot row belongs to exactly one
        shard, so admission is also a *placement* decision: requests go
        to the least-loaded shard first (load = summed ``_row_eta`` over
        the shard's rows plus the service rounds of its parked staging),
        with rounds-to-free then row index breaking ties.  A shard whose
        rows all run long prompts stops attracting new work until the
        others catch up.  At ``data=1`` the shard load is one constant
        and this reduces exactly to the pre-mesh ``(eta, row)`` order.
        """
        empty = [i for i in range(self.max_batch)
                 if self.staged[i] is None
                 and i // self._rows_per_shard not in self.dead_shards]
        now = self.stats.decode_steps
        group = self.scheduler.take(len(empty), now_round=now)
        if not group and self.scheduler.waiting \
                and not any(self.current) and not any(self.staged):
            # every queued request sits in retry backoff but the machine
            # is idle: the round clock only advances while work runs, so
            # honouring the backoff would deadlock.  Backoff exists to
            # let a transient fault clear while OTHER work runs.
            group = self.scheduler.take(len(empty), now_round=now,
                                        ignore_backoff=True)
        if not group:
            return
        load = [0] * self.dp
        for i in range(self.max_batch):
            load[i // self._rows_per_shard] += self._row_eta(i)
        for i, parked in enumerate(self.staged):
            if parked is not None:
                load[i // self._rows_per_shard] += \
                    self._service_rounds(parked)
        m = self._smirror
        for req in group:
            empty.sort(key=lambda i: (load[i // self._rows_per_shard],
                                      self._row_eta(i), i))
            slot = empty.pop(0)
            load[slot // self._rows_per_shard] += self._service_rounds(req)
            req.slot = slot
            req.status = STAGED
            req.admit_seq = self.stats.admitted
            self.staged[slot] = req
            m["s_prompt"][slot, :] = 0
            m["s_prompt"][slot, :len(req.prompt)] = req.prompt
            m["s_prompt_len"][slot] = len(req.prompt)
            m["s_rid"][slot] = req.rid
            m["s_remaining"][slot] = req.max_new
            m["s_eos"][slot] = -1 if req.eos is None else req.eos
            m["s_temperature"][slot] = req.temperature
            m["s_top_k"][slot] = req.top_k
            m["s_top_p"][slot] = req.top_p
            m["s_valid"][slot] = True
            self.stats.admitted += 1
            self._dirty_slots.append(slot)

    def _unstage(self, slot: int):
        """Withdraw a parked request from its staging buffer (cancel /
        deadline sweep) before the device can arm it."""
        req = self.staged[slot]
        self.staged[slot] = None
        req.slot = None
        self._smirror["s_valid"][slot] = False
        self._dirty_slots.append(slot)

    def _upload_staging(self):
        """Push newly staged rows to the device.  The (B,) control
        vectors are re-uploaded whole (a few words); the (B, max_len)
        prompt matrix -- the only leaf whose full upload would scale
        with max_len -- is scattered row-wise for just the dirty slots.

        The ``drop_upload`` chaos injection point intercepts here: a
        dropped slot's prompt row is NOT uploaded and its ``s_valid`` is
        masked False for this call (the device must never arm a row
        whose prompt row it does not have), and the slot stays dirty so
        the next call retries -- the request arms one superstep late.
        """
        if not self._dirty_slots:
            return
        rows = sorted(set(self._dirty_slots))
        dropped: List[int] = []
        if self.faults is not None:
            rows, dropped = self.faults.drop_upload(
                self.stats.decode_calls, rows)
        if rows:
            r = jnp.asarray(rows)
            self.state["s_prompt"] = self.state["s_prompt"].at[r].set(
                jnp.asarray(self._smirror["s_prompt"][np.asarray(r)]))
        s_valid = self._smirror["s_valid"]
        if dropped:
            s_valid = s_valid.copy()
            s_valid[dropped] = False
        for k in _STAGE_FIELDS:
            if k == "s_prompt":
                continue
            src = s_valid if k == "s_valid" else self._smirror[k]
            self.state[k] = jnp.asarray(src)
        self._dirty_slots = list(dropped)

    # ------------------------------------------------------------------
    # The superstep
    # ------------------------------------------------------------------
    def _superstep_fn(self, n: int):
        key = (n, self._spec_active and self.draft is not None)
        fn = self._superstep_fns.get(key)
        if fn is None:
            cfg, chunk = self.cfg, self.prompt_chunk
            draft = self.draft if key[1] else None
            if self.mesh is not None:
                fn = serve_mesh.make_superstep(
                    cfg, self.mesh_plan, self.mesh, self.state,
                    self.params, n, prompt_chunk=chunk, draft=draft)
            else:
                fn = jax.jit(lambda p, dp, s: lm.superstep(
                    p, cfg, s, n, prompt_chunk=chunk, draft=draft,
                    draft_params=dp))
            self._superstep_fns[key] = fn
        return fn

    def _promote(self, slot: int) -> Request:
        """The device armed this row's staged request: update mirrors."""
        prev = self.current[slot]
        assert prev is None or prev.done, \
            "device armed a row whose request the host still thinks is live"
        req = self.staged[slot]
        assert req is not None
        self.current[slot] = req
        self.staged[slot] = None
        req.status = RUNNING
        return req

    def _retire(self, req: Request, status: str):
        """Move a request to a terminal status and count it."""
        req.done = True
        req.status = status
        if req.slot is not None:
            if self.current[req.slot] is req:
                self.current[req.slot] = None
            req.slot = None
        self.finished[req.rid] = req
        if status == COMPLETED:
            self.stats.completed += 1
        elif status == CANCELLED:
            self.stats.cancelled += 1
        elif status == TIMED_OUT:
            self.stats.timed_out += 1
        elif status == FAILED:
            self.stats.failed += 1
        elif status == SHED:
            if req.verdict == REJECTED_QUEUE_FULL:
                self.stats.rejected += 1
            else:
                self.stats.shed += 1

    def _finish(self, req: Request, now: float, last_round: int):
        self._retire(req, COMPLETED)
        self.stats.record_completion(len(req.out), req.first_round,
                                     last_round, req.first_token_s, now)

    def _kill_inflight(self, req: Request, status: str):
        """Retire an in-flight request between supersteps: its device row
        goes dead (re-armed from staging on the next round like any
        retirement) and the tokens drained so far are preserved."""
        slot = req.slot
        self.state = dict(self.state)
        self.state["alive"] = self.state["alive"].at[slot].set(False)
        self._retire(req, status)

    def _sweep_deadlines(self):
        """Retire every request whose deadline round has passed --
        queued, staged or in flight (the latter keeping partial
        output).  Runs at host round-trip boundaries."""
        now = self.stats.decode_steps
        overdue = [r for r in self.scheduler.waiting
                   if r.deadline is not None and now >= r.deadline]
        for req in overdue:
            self.scheduler.remove(req)
            self._retire(req, TIMED_OUT)
        for slot in range(self.max_batch):
            req = self.staged[slot]
            if req is not None and req.deadline is not None \
                    and now >= req.deadline:
                self._unstage(slot)
                self._retire(req, TIMED_OUT)
            req = self.current[slot]
            if req is not None and req.deadline is not None \
                    and now >= req.deadline:
                self._kill_inflight(req, TIMED_OUT)

    def _corrupt_slots(self, slots: List[int]):
        """Chaos injection: overwrite the recurrent state rows of
        ``slots`` with NaN (the ``corrupt_state`` point).  The in-loop
        health guard detects the poisoned rows on their next round."""
        cache = dict(self.state["cache"])
        rows = jnp.asarray(slots, jnp.int32)
        touched = False
        for name in lm._RECURRENT_CACHE_KEYS:
            leaf = cache.get(name)
            if leaf is not None and jnp.issubdtype(leaf.dtype,
                                                   jnp.floating):
                cache[name] = leaf.at[:, rows].set(jnp.nan)
                touched = True
        if touched:
            self.state = dict(self.state)
            self.state["cache"] = cache

    def _requeue(self, req: Request, round_: int, *, count_retry: bool,
                 backoff: bool) -> bool:
        """Re-enqueue a request whose slot died under it, or retire it
        if it cannot be retried.  The shared tail of quarantine (health-
        guard kill: ``count_retry=True`` -- the row poisoning might be
        the request's input, so it burns retry budget and backs off
        exponentially) and DP-shard failover (``count_retry=False`` --
        an infrastructure death is never the request's fault: no budget
        burned, re-eligible immediately).  Returns True if the request
        went back to QUEUED, False if it retired terminally."""
        if req.deadline is not None and round_ >= req.deadline:
            self._retire(req, TIMED_OUT)
            return False
        if count_retry and req.retries >= self.max_retries:
            self._retire(req, FAILED)
            return False
        verdict = self.scheduler.submit(req, now_round=round_)
        req.verdict = verdict
        if verdict != ADMITTED:
            self._retire(req, FAILED)   # no queue room for the retry
            return False
        if count_retry:
            req.retries += 1
            self.stats.retried += 1
        req.out = []        # the retry restarts the stream from scratch
        req.status = QUEUED
        req.not_before = round_ + (
            self.retry_backoff * (2 ** (req.retries - 1))
            if backoff else 0)
        self.stats.observe_queue(len(self.scheduler))
        return True

    def _crash_shard(self, shard: int, round_: int):
        """DP-shard failover (the ``shard_crash`` injection point fired):
        mark ``shard``'s slot rows permanently dead and drain its parked
        + in-flight requests back through the requeue path onto the
        surviving shards.  The dead rows stay in the dense batch --
        stepping as ``wasted_slot_steps``, so the per-shard slot-step
        identity keeps holding -- but never stage again.  A drained
        request restarts its stream from the prompt on a survivor
        (greedy output is placement-independent, so the re-served stream
        is identical to its no-crash stream); failover does not burn the
        request's retry budget."""
        self.dead_shards.add(shard)
        self.stats.shard_crashes += 1
        rows = serve_mesh.shard_rows(shard, self._rows_per_shard)
        self.state = dict(self.state)
        self.state["alive"] = self.state["alive"].at[
            jnp.asarray(list(rows))].set(False)
        for slot in rows:
            parked = self.staged[slot]
            if parked is not None:
                self._unstage(slot)
                if self._requeue(parked, round_, count_retry=False,
                                 backoff=False):
                    self.stats.failover_requeued += 1
            req = self.current[slot]
            if req is not None and not req.done:
                self.current[slot] = None
                req.slot = None
                if self._requeue(req, round_, count_retry=False,
                                 backoff=False):
                    self.stats.failover_requeued += 1

    def _quarantine(self, slot: int, round_: int, s_valid_np, dirty):
        """The superstep's health guard killed this row at ``round_``:
        attribute the kill to the occupying request and re-enqueue it
        under the bounded retry budget (exponential round backoff), or
        retire it FAILED once the budget is spent.  The slot itself
        needs no host repair -- the device already marked it dead and
        the next arm re-zeroes its state through the normal re-admission
        path."""
        self.stats.quarantined += 1
        req = self.current[slot]
        if req is None or req.done:
            # the victim armed mid-superstep from staging (it emitted
            # nothing before the kill, so the drain never promoted it)
            if self.staged[slot] is not None and not s_valid_np[slot] \
                    and slot not in dirty:
                req = self._promote(slot)
            else:
                return
        self.current[slot] = None
        req.slot = None
        self._requeue(req, round_, count_retry=True, backoff=True)

    def _adapt_speculation(self, counters):
        """Rolling accept-rate floor: when a window of verify rounds
        accepts below ``spec_accept_floor``, drafting is disabled (the
        engine swaps to the plain superstep program) instead of paying a
        draft_len-wide verify pass for ~1 token per round.  With
        ``spec_cooldown > 0`` drafting re-probes after that many calls;
        streams are bit-identical either way -- only latency changes."""
        if self.draft is None or self.spec_accept_floor is None:
            return
        if not self._spec_active:
            self._spec_off_calls += 1
            if self.spec_cooldown and \
                    self._spec_off_calls >= self.spec_cooldown:
                self._spec_active = True
                self._spec_off_calls = 0
                self._spec_hist = []
            return
        proposed = int(counters.get("draft_proposed", 0))
        if proposed <= 0:
            return
        self._spec_hist.append(
            (proposed, int(counters.get("draft_accepted", 0))))
        if len(self._spec_hist) > self.spec_window:
            self._spec_hist.pop(0)
        if len(self._spec_hist) == self.spec_window:
            tp = sum(p for p, _ in self._spec_hist)
            ta = sum(a for _, a in self._spec_hist)
            if ta < self.spec_accept_floor * tp:
                self._spec_active = False
                self._spec_hist = []
                self.stats.spec_disabled += 1

    def step(self, n_tokens: Optional[int] = None) -> int:
        """Sweep deadlines, stage pending requests, then run ONE
        on-device superstep of ``n_tokens`` (default
        ``self.decode_block``) rounds: every slot advances one token per
        round -- its next prompt token while prefilling, a sampled token
        while decoding -- and slots that retire mid-call are re-armed
        from staging in-loop.  Drains emissions, quarantines rows the
        numerical health guard killed, and restocks staging.  Returns
        the number of requests still in flight (armed + staged +
        queued)."""
        k = max(1, int(n_tokens)) if n_tokens is not None \
            else self.decode_block
        self._sweep_deadlines()
        if self.faults is not None:
            for s in self.faults.shard_crash(self.stats.decode_steps, k,
                                             self.dp):
                if s not in self.dead_shards:
                    self._crash_shard(s, self.stats.decode_steps)
        self._stage()
        if not any(self.current) and not any(self.staged):
            if self.journal is not None:
                # every step() call is journaled, no-ops included: the
                # replay must re-execute the exact call sequence
                self.journal.record_step({
                    "round": self.stats.decode_steps, "k": k,
                    "noop": True})
                self._maybe_snapshot()
            return len(self.scheduler)
        self._upload_staging()
        if self.faults is not None:
            slots = self.faults.corrupt_state(
                self.stats.decode_steps, k, self.max_batch)
            if slots:
                self._corrupt_slots(slots)

        with self.stats.timed("decode"):
            toks, rids, self.state, counters = self._superstep_fn(k)(
                self.params, self.draft_params, self.state)
            toks_np = np.asarray(toks)
            rids_np = np.asarray(rids)
            s_valid_np = np.asarray(self.state["s_valid"])
            nf_np = np.asarray(counters["nonfinite"])
            self._prompt_pos[:] = np.asarray(self.state["prompt_pos"])
            self._rid_dev[:] = np.asarray(self.state["rid"])
            if self.faults is not None:
                stall = self.faults.straggler(self.stats.decode_calls)
                if stall > 0:
                    time.sleep(stall)
        if toks_np.ndim == 2:       # non-speculative: one plane per round
            toks_np = toks_np[:, :, None]
            rids_np = rids_np[:, :, None]
        base_round = self.stats.decode_steps
        self.stats.decode_calls += 1
        self.stats.decode_steps += k
        self.stats.slot_steps += k * self.max_batch
        # under a mesh the counters come back as (data,) per-shard
        # vectors (single device: scalars -- atleast_1d unifies both);
        # the global stats take the cross-shard sum, the per-shard
        # ShardStats take their own component
        percall = {kk: np.atleast_1d(np.asarray(v))
                   for kk, v in counters.items() if kk != "nonfinite"}
        agg = {kk: int(v.sum()) for kk, v in percall.items()}
        self.stats.prefill_tokens += agg["prefill_steps"]
        self.stats.prefill_rounds += agg["prefill_rounds"]
        self.stats.wasted_slot_steps += agg["wasted_slot_steps"]
        self.stats.nonfinite_decode_rounds += agg["nonfinite_decode_rounds"]
        self.stats.draft_proposed += agg.get("draft_proposed", 0)
        self.stats.draft_accepted += agg.get("draft_accepted", 0)
        for s, sh in enumerate(self.stats.shards):
            sh.slot_steps += k * self._rows_per_shard
            sh.prefill_rounds += int(percall["prefill_rounds"][s])
            sh.wasted_slot_steps += int(percall["wasted_slot_steps"][s])
            sh.nonfinite_decode_rounds += int(
                percall["nonfinite_decode_rounds"][s])
        self._adapt_speculation(agg)

        now = time.perf_counter()
        dirty = set(self._dirty_slots)
        drained = 0
        drained_shard = [0] * self.dp
        emits: List[List[int]] = []     # (rid, token) in drain order
        for slot in range(self.max_batch):
            shard = slot // self._rows_per_shard
            for j in range(k):
                if nf_np[slot, j]:
                    self._quarantine(slot, base_round + j, s_valid_np,
                                     dirty)
                for c in range(toks_np.shape[2]):
                    rid = int(rids_np[slot, j, c])
                    if rid < 0:
                        continue
                    req = self.current[slot]
                    if req is None or req.rid != rid:
                        req = self._promote(slot)   # armed mid-superstep
                        assert req.rid == rid, (req.rid, rid)
                    t = int(toks_np[slot, j, c])
                    if not req.out:
                        req.first_token_s = now
                        req.first_round = base_round + j
                        self.stats.record_first_token(
                            now - req.submitted_s,
                            base_round + j + 1 - req.submit_round)
                        self.stats.shards[shard].first_tokens += 1
                    req.out.append(t)
                    emits.append([rid, t])
                    drained += 1
                    drained_shard[shard] += 1
                    if (req.eos is not None and t == req.eos) or \
                            len(req.out) >= req.max_new:
                        self._finish(req, now, base_round + j)
            # armed without emitting yet (still prefilling at call end);
            # a slot whose upload was dropped is still parked, not armed
            if self.staged[slot] is not None and not s_valid_np[slot] \
                    and slot not in dirty:
                self._promote(slot)
        self.stats.decode_tokens += drained
        # non_spec_tokens: tokens the non-speculative path contributes --
        # one per emitting slot-round.  The device counts those rounds
        # under speculation; without it every drained token is one.
        spec = "emit_rounds" in percall
        self.stats.non_spec_tokens += agg["emit_rounds"] if spec \
            else drained
        for s, sh in enumerate(self.stats.shards):
            sh.decode_tokens += drained_shard[s]
            sh.non_spec_tokens += int(percall["emit_rounds"][s]) if spec \
                else drained_shard[s]
        # re-sync the staging mirror with what the device consumed --
        # except dirty slots (dropped uploads), whose parked requests
        # the device never saw: their mirror rows stay authoritative
        self._smirror["s_valid"][:] = s_valid_np
        for slot in dirty:
            if self.staged[slot] is not None:
                self._smirror["s_valid"][slot] = True
        if self.journal is not None:
            # the step record lands AFTER the superstep drains: crashing
            # mid-step replays the whole step (the journal never saw it)
            self.journal.record_step({"round": base_round, "k": k,
                                      "emits": emits,
                                      "digest": self._journal_digest()})
            self._maybe_snapshot()
        return (sum(r is not None for r in self.current)
                + sum(r is not None for r in self.staged)
                + len(self.scheduler))

    def _journal_digest(self) -> Dict[str, int]:
        """Round-clock stats fingerprint written with every step record;
        a replayed step must reproduce it exactly (wall-clock latency
        fields are deliberately absent -- they span processes)."""
        st = self.stats
        return {"round": st.decode_steps, "completed": st.completed,
                "cancelled": st.cancelled, "timed_out": st.timed_out,
                "failed": st.failed, "quarantined": st.quarantined,
                "decode_tokens": st.decode_tokens,
                "shard_crashes": st.shard_crashes}

    def _maybe_snapshot(self):
        """Snapshot the full serving state every ``snapshot_every``
        device rounds (suppressed while replaying a journal tail --
        replay re-executes past work, it does not re-persist it)."""
        if self.recover_dir is None or self.journal.replaying:
            return
        if self.stats.decode_steps - self._last_snapshot_round \
                < self.snapshot_every:
            return
        from repro.serving import recovery
        recovery.save_snapshot(self, self.recover_dir,
                               keep=self.snapshot_keep)
        self._last_snapshot_round = self.stats.decode_steps

    @classmethod
    def restore(cls, recover_dir: str, cfg, params, *, speculative=None,
                draft_params=None) -> "ServingEngine":
        """Rebuild an engine from a crash-recovery directory on a fresh
        process: newest good snapshot + journal-tail replay (see
        ``serving.recovery.restore_engine``).  The returned engine's
        streams are bit-identical to an uninterrupted run and it keeps
        journaling + snapshotting where the dead process stopped;
        ``engine.recovery_report`` says what recovery did."""
        from repro.serving import recovery
        return recovery.restore_engine(recover_dir, cfg, params,
                                       speculative=speculative,
                                       draft_params=draft_params)

    # ------------------------------------------------------------------
    def occupancy_report(self) -> Dict[str, Any]:
        """Queue + per-slot occupancy snapshot (stall diagnosis)."""
        slots = []
        for i in range(self.max_batch):
            cur, parked = self.current[i], self.staged[i]
            slots.append({
                "slot": i,
                "current": None if cur is None else {
                    "rid": cur.rid, "status": cur.status,
                    "prompt_len": len(cur.prompt),
                    "prompt_pos": int(self._prompt_pos[i]),
                    "out_tokens": len(cur.out),
                    "deadline": cur.deadline, "retries": cur.retries},
                "staged": None if parked is None else {
                    "rid": parked.rid, "status": parked.status,
                    "not_before": parked.not_before},
            })
        return {
            "decode_steps": self.stats.decode_steps,
            "queue_depth": len(self.scheduler),
            "queued": [r.rid for r in self.scheduler.waiting],
            "in_flight": sum(r is not None for r in self.current),
            "staged": sum(r is not None for r in self.staged),
            "dead_shards": sorted(self.dead_shards),
            "slots": slots,
        }

    def run_to_completion(self, max_steps: int = 100_000
                          ) -> Dict[int, List[int]]:
        """Step until every request reaches a terminal status.  Raises
        :class:`EngineStallError` (occupancy report attached) instead of
        returning silently if ``max_steps`` is exhausted with work still
        pending.  Returns ``{rid: output tokens}`` for every terminal
        request (non-completed requests contribute their partial -- or
        empty -- output; check ``engine.finished[rid].status``)."""
        steps = 0
        while (len(self.scheduler) or any(self.current)
               or any(self.staged)):
            if steps >= max_steps:
                report = self.occupancy_report()
                raise EngineStallError(
                    f"engine did not drain within {max_steps} steps: "
                    f"{report['queue_depth']} queued, "
                    f"{report['in_flight']} in flight, "
                    f"{report['staged']} staged at round "
                    f"{report['decode_steps']} (see .report)", report)
            self.step()
            steps += 1
        return {rid: r.out for rid, r in self.finished.items()}


def replay_trace(engine: ServingEngine, trace: List[Dict[str, Any]],
                 submit, max_steps: int = 100_000, start: int = 0,
                 stop=None) -> int:
    """Drive ``engine`` over an arrival trace until every request
    reaches a terminal status.  The arrival clock is the engine's
    device-round counter: request ``i`` is submitted via
    ``submit(i, trace[i])`` once ``trace[i]["arrival"] <=
    stats.decode_steps`` -- or immediately when the engine is idle, so a
    gap in arrivals cannot stall the round clock.  Drain is judged on
    *terminal* requests (``engine.finished``), not completions, so
    shed / failed / timed-out requests under fault injection or
    overload cannot hang the replay.  Shared by the arrival-trace
    bench, the serving example and the scheduler property tests so the
    replay semantics live in one place.

    Crash-recovery hooks: ``start`` says how many leading trace entries
    were already submitted (continue a restored engine with
    ``start=len(engine.requests)`` -- the count includes shed requests,
    exactly the submit calls already journaled), and ``stop(engine)``
    is checked after every step -- returning True abandons the drive
    mid-trace (the ``--crash`` bench's kill switch).  Returns how many
    trace entries have been submitted.  Because submission is driven by
    the round clock and terminal counts only, a continued drive makes
    the same submit-round decisions an uninterrupted one would."""
    i, steps = start, 0
    while i < len(trace) or len(engine.finished) < i:
        due = i < len(trace) and \
            trace[i]["arrival"] <= engine.stats.decode_steps
        idle = len(engine.finished) == i
        while i < len(trace) and (due or idle):
            submit(i, trace[i])
            i += 1
            due = i < len(trace) and \
                trace[i]["arrival"] <= engine.stats.decode_steps
            idle = False
        engine.step()
        steps += 1
        if stop is not None and stop(engine):
            return i
        if steps >= max_steps:
            raise RuntimeError(
                f"arrival trace did not drain within {max_steps} steps "
                f"({len(engine.finished)}/{i} submitted requests "
                f"terminal)")
    return i


@functools.lru_cache(maxsize=32)
def _decode_step_fn(cfg):
    """One compiled decode step per config (configs are frozen/hashable);
    repeated generate_one calls share it instead of re-tracing."""
    return jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))


def generate_one(cfg, params, prompt: List[int], max_new: int = 32,
                 max_len: int = 2048) -> List[int]:
    """Single-request greedy reference path (the engine parity oracle).

    Drives the prompt token-by-token through ``lm.decode_step`` -- the
    same unified code path the engine superstep uses for prefill and
    decode -- so engine streams are bit-comparable for every cache kind.
    (The parallel ``lm.prefill`` scan matches this path to fp32 rounding;
    the padding-invariance tests in tests/test_serving.py pin that
    equivalence on the parallel side, and
    test_generate_one_matches_parallel_prefill pins it here.)
    """
    if not prompt:
        raise ValueError("empty prompt")
    # same cache-position budget as ServingEngine.submit: the request
    # consumes len(prompt) + max_new - 1 positions.  KV-cache archs would
    # otherwise scatter past max_len (silently dropped under jit -- wrong
    # attention), recurrent archs would just mis-count; both are bugs.
    if len(prompt) + max_new - 1 > max_len:
        raise ValueError(
            f"prompt ({len(prompt)}) + max_new ({max_new}) needs "
            f"{len(prompt) + max_new - 1} cache positions, exceeding "
            f"max_len ({max_len})")
    cache = lm.init_cache(cfg, 1, max_len)
    step = _decode_step_fn(cfg)
    logits = None
    for t in prompt:
        logits, cache = step(params, jnp.asarray([t], jnp.int32), cache)
    out = [int(np.asarray(logits)[0, :cfg.vocab_size].argmax())]
    for _ in range(max_new - 1):
        logits, cache = step(params, jnp.asarray([out[-1]], jnp.int32),
                             cache)
        out.append(int(np.asarray(logits)[0, :cfg.vocab_size].argmax()))
    return out
