"""Draft-token sources for speculative decoding in the serving superstep.

Speculative decoding exploits the paper's O(1) recurrent state (Were
RNNs All We Needed?, section 3): verifying C draft tokens is ONE pass
through the varlen chunk kernels (the same masked per-token replay
prompt packing uses, weights streamed from HBM once), and rolling back
to the first rejected position is an O(d_hidden) per-slot gather of the
chunk's per-position states -- no paged-KV surgery, no recompute, no
host round-trip.  The superstep stays exact: every emitted token is the
token the non-speculative engine would have produced (greedy argmax or
categorical under the same emission-aligned key chain), so drafts only
ever change *latency*, never content.

A draft source is a small strategy object the superstep calls inside
its jitted scan body, so every method must be pure jax on fixed shapes:

  * ``draft_len``                 -- static S, max draft tokens/round;
  * ``extra_state(batch, max_len)`` -- device state the source carries
    per slot (e.g. the draft model's own decode cache), merged into the
    slot state by ``lm.init_slot_state``;
  * ``propose(params, st)``       -- (drafts (B, S), n_draft (B,)):
    draft continuations of ``st["tok"]`` for every row (the superstep
    masks non-decoding rows itself);
  * ``commit(params, st, tok_blk, valid_eff)`` -- state updates after
    the round committed ``valid_eff[b]`` tokens of ``tok_blk[b]`` (the
    model source advances its draft cache here; stateless sources
    return ``{}``).

Sources:

  * :class:`NGramDraft` -- self-drafting from the request's own prompt
    + emitted output: match the last ``ngram`` tokens against history
    and propose the continuation of the most recent earlier occurrence.
    Free (no extra model), surprisingly strong on repetitive text.
  * :class:`ModelDraft` -- a tiny minGRU/minLSTM draft model sharing
    the tokenizer: S sequential greedy draft steps propose, one draft
    ``decode_chunk`` per round keeps its cache in lockstep with the
    committed stream.  With the *target* config + params it is an exact
    oracle (every draft accepted) -- the test fixture for full-
    acceptance rollback.
  * :class:`FixedDraft` -- test-only constant-token source exercising
    the first-token-rejection rollback path.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class NGramDraft:
    """Prompt/output n-gram self-drafting.

    History is the slot's prompt buffer, which the speculative superstep
    extends in place with every emitted token (``prompt_len + n_out``
    tokens total).  The proposal: find the most recent occurrence of the
    last ``ngram`` tokens strictly before the current position and
    propose the up-to-``draft_len`` tokens that followed it; no match
    (or too little history) proposes nothing.
    """

    params = None                 # stateless: no draft weights

    def __init__(self, draft_len: int = 4, ngram: int = 2):
        if draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {draft_len}")
        if ngram < 1:
            raise ValueError(f"ngram must be >= 1, got {ngram}")
        self.draft_len = int(draft_len)
        self.ngram = int(ngram)

    def extra_state(self, batch: int, max_len: int) -> Dict[str, Any]:
        return {}

    def propose(self, params, st) -> Tuple[Array, Array]:
        hist_buf = st["prompt"]                       # (B, P) history
        bsz, p_cap = hist_buf.shape
        g, s = self.ngram, self.draft_len
        hist = st["prompt_len"] + st["n_out"]         # tokens of history

        # suffix: the last g history tokens (the pattern to re-find)
        sfx_idx = jnp.clip(hist[:, None] - g + jnp.arange(g)[None],
                           0, p_cap - 1)
        suffix = jnp.take_along_axis(hist_buf, sfx_idx, axis=1)   # (B, g)

        # windows H[p : p+g] for every start p, via g static slices
        n_pos = p_cap - g + 1
        match = jnp.ones((bsz, n_pos), bool)
        for j in range(g):
            match = match & (hist_buf[:, j:j + n_pos] == suffix[:, j:j + 1])
        pos = jnp.arange(n_pos)[None]
        # p <= hist-g-1: the window ends strictly before the suffix's own
        # occurrence AND its continuation token H[p+g] is inside history
        ok = match & (pos <= (hist - g - 1)[:, None])
        p_star = jnp.max(jnp.where(ok, pos, -1), axis=1)   # most recent
        has = (p_star >= 0) & (hist >= g + 1)

        cont = p_star + g                      # continuation start index
        d_idx = jnp.clip(cont[:, None] + jnp.arange(s)[None], 0, p_cap - 1)
        drafts = jnp.take_along_axis(hist_buf, d_idx, axis=1)
        n_draft = jnp.where(has, jnp.minimum(s, hist - cont), 0)
        return drafts.astype(jnp.int32), n_draft.astype(jnp.int32)

    def commit(self, params, st, tok_blk, valid_eff) -> Dict[str, Any]:
        return {}


class ModelDraft:
    """Tiny draft model (same tokenizer) proposing greedy continuations.

    ``cfg``/``params`` are the draft model's own; its decode cache rides
    the slot state (``extra_state``) and is kept in lockstep with the
    target stream by ``commit`` -- one draft ``decode_chunk`` over the
    very tokens the target committed, so the draft cache is always
    conditioned on the accepted history (never on rejected drafts).
    ``propose`` looks ahead with S sequential greedy draft steps from a
    throwaway copy of that cache.
    """

    def __init__(self, cfg, params=None, draft_len: int = 4):
        if cfg.block_kind != "minrnn":
            raise ValueError(
                f"ModelDraft needs a recurrent-state draft model "
                f"(block_kind='minrnn'), got {cfg.block_kind!r}")
        if draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {draft_len}")
        self.cfg = cfg
        self.params = params
        self.draft_len = int(draft_len)

    def extra_state(self, batch: int, max_len: int) -> Dict[str, Any]:
        from repro.models import lm
        return {"draft_cache": lm.init_cache(self.cfg, batch, max_len)}

    def propose(self, params, st) -> Tuple[Array, Array]:
        from repro.models import lm
        cache = st["draft_cache"]           # throwaway lookahead copy
        tok = st["tok"]
        drafts = []
        for _ in range(self.draft_len):
            logits, cache = lm.decode_step(params, self.cfg, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            drafts.append(tok)
        drafts = jnp.stack(drafts, axis=1)              # (B, S)
        n_draft = jnp.full(tok.shape, self.draft_len, jnp.int32)
        return drafts, n_draft

    def commit(self, params, st, tok_blk, valid_eff) -> Dict[str, Any]:
        from repro.models import lm
        _, cache = lm.decode_chunk(params, self.cfg, tok_blk, valid_eff,
                                   st["draft_cache"])
        return {"draft_cache": cache}


class FixedDraft:
    """Test-only source proposing a constant token: with a token the
    target (almost) never emits, every draft is rejected at the first
    position -- the rollback-to-prefix path under maximal stress."""

    params = None

    def __init__(self, token: int, draft_len: int = 4):
        self.token = int(token)
        self.draft_len = int(draft_len)

    def extra_state(self, batch: int, max_len: int) -> Dict[str, Any]:
        return {}

    def propose(self, params, st) -> Tuple[Array, Array]:
        bsz = st["tok"].shape[0]
        drafts = jnp.full((bsz, self.draft_len), self.token, jnp.int32)
        return drafts, jnp.full((bsz,), self.draft_len, jnp.int32)

    def commit(self, params, st, tok_blk, valid_eff) -> Dict[str, Any]:
        return {}


def make(kind: str, draft_len: int = 4, **kw):
    """Convenience constructor: ``"ngram"`` -> :class:`NGramDraft`."""
    if kind == "ngram":
        return NGramDraft(draft_len=draft_len, **kw)
    raise ValueError(
        f"unknown draft source {kind!r}; pass 'ngram' or a draft-source "
        f"instance (NGramDraft / ModelDraft)")
