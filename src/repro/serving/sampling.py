"""On-device vectorized token sampling for the serving engine.

One jitted call samples the whole ``(batch, vocab)`` logits matrix at once
with *per-slot* controls -- temperature, top-k, top-p and an independent RNG
key per slot -- replacing the engine v1 per-request host-side numpy loop
(one device->host transfer + one python iteration per slot per step).

Semantics (matching the common serving stacks):

  * ``temperature <= 0``  -> greedy argmax (exact, not a small-T limit).
  * ``temperature > 0``   -> categorical over ``softmax(logits / T)`` after
    the support restrictions below.
  * ``top_k > 0``    keeps the k highest logits (ties at the k-th value are
    all kept); ``top_k <= 0`` disables the filter.
  * ``top_p < 1``    keeps the smallest set of tokens whose probability mass
    reaches ``top_p`` (nucleus sampling); ``top_p >= 1`` disables it.

All controls are traced arrays, so one compiled program serves any mix of
greedy / sampled slots.  ``sample_tokens`` returns advanced keys
(`jax.random.split` per slot), making runs reproducible under a fixed
engine seed.

``lm.superstep`` calls this every device round for every slot --
including teacher-forced (prefilling) rows, whose sample is masked out
rather than skipped, so the compiled round is branch-free.  The
superstep keeps the returned key only for rows that *emit* that round:
a request's k-th output token always uses the k-th key in its slot's
chain, however many teacher-forced (and, under packed prefill,
multi-token) rounds interleave -- which is what makes seeded streams
bit-exact across ``prompt_chunk`` values.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG = jnp.float32(-1e30)     # "removed from support" without -inf NaN risk


def validate_controls(temperature: float, top_k: int, top_p: float) -> None:
    """Reject malformed per-request sampling controls at submission time.

    The device kernels are branch-free and would silently mis-sample on
    out-of-domain controls (a negative temperature flips the softmax
    ordering, a non-positive top_p empties the nucleus), so the serving
    entry points validate here with a clear error instead.  Valid:
    ``temperature >= 0`` (0 = greedy), ``top_k >= 0`` (0 = off),
    ``0 < top_p <= 1`` (1 = off); all must be finite.
    """
    if not math.isfinite(temperature) or temperature < 0:
        raise ValueError(
            f"temperature must be finite and >= 0 (0 = greedy), "
            f"got {temperature!r}")
    if int(top_k) != top_k or top_k < 0:
        raise ValueError(
            f"top_k must be a non-negative integer (0 disables the "
            f"filter), got {top_k!r}")
    if not math.isfinite(top_p) or not 0.0 < top_p <= 1.0:
        raise ValueError(
            f"top_p must be in (0, 1] (1 disables nucleus sampling), "
            f"got {top_p!r}")


def make_keys(seed: int, batch: int) -> Array:
    """Independent per-slot PRNG keys, (batch, 2) uint32."""
    base = jax.random.PRNGKey(int(seed) % (2**31 - 1))
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(batch))


def _support_mask(logits: Array, top_k: Array, top_p: Array) -> Array:
    """Apply top-k then nucleus filtering with ONE descending sort.

    Both filters keep a *prefix* of the sorted row (top-k keeps everything
    >= the k-th value, ties included; the nucleus keeps the smallest prefix
    whose mass reaches top_p), so their intersection is a prefix too: find
    its last element and threshold the unsorted row against it.
    """
    v = logits.shape[-1]
    sorted_desc = -jnp.sort(-logits, axis=-1)

    k = jnp.clip(top_k, 1, v).astype(jnp.int32)
    kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    kth = jnp.where((top_k > 0)[:, None], kth, _NEG)
    keep_k = sorted_desc >= kth                       # prefix (ties kept)

    probs = jax.nn.softmax(jnp.where(keep_k, sorted_desc, _NEG), axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # token j (sorted) is in the nucleus iff the mass *before* it is
    # < top_p; top_p >= 1 disables explicitly (f32 cumsum saturates at 1.0,
    # which would otherwise drop tiny-probability tail tokens)
    keep_p = ((csum - probs) < top_p[:, None]) | (top_p >= 1.0)[:, None]

    count = jnp.maximum(jnp.sum(keep_k & keep_p, axis=-1), 1).astype(
        jnp.int32)
    cutoff = jnp.take_along_axis(sorted_desc, (count - 1)[:, None], axis=-1)
    return jnp.where(logits >= cutoff, logits, _NEG)


def _sample(logits: Array, keys: Array, temperature: Array,
            top_k: Array, top_p: Array):
    """One sampling round (the shared core of ``sample_tokens`` and
    ``sample_chain`` -- both MUST run the exact same ops so a chained
    position-0 sample is bit-identical to a standalone call)."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    split = jax.vmap(jax.random.split)(keys)        # (B, 2, 2)
    new_keys, use_keys = split[:, 0], split[:, 1]

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    scaled = _support_mask(scaled, top_k, top_p)
    sampled = jax.vmap(jax.random.categorical)(use_keys, scaled
                                               ).astype(jnp.int32)
    tokens = jnp.where(temperature > 0, sampled, greedy)
    return tokens, new_keys


@jax.jit
def sample_tokens(logits: Array, keys: Array, temperature: Array,
                  top_k: Array, top_p: Array):
    """logits: (B, V); keys: (B, 2) uint32; temperature/top_p: (B,) f32;
    top_k: (B,) int32.  Returns (tokens (B,) int32, advanced keys)."""
    return _sample(logits, keys, temperature, top_k, top_p)


@jax.jit
def sample_chain(logits: Array, keys: Array, temperature: Array,
                 top_k: Array, top_p: Array):
    """Chained per-position sampling for speculative verify.

    logits: (B, W, V) -- per-position verify logits from one chunk pass.
    Position ``i`` is sampled exactly as the ``i``-th of ``W`` sequential
    ``sample_tokens`` calls would be: the key chain advances one split
    per position, so a row that commits ``e`` positions this round lands
    on the same key state as ``e`` non-speculative rounds -- which is
    what keeps seeded speculative streams bit-identical to the
    non-speculative engine (emission-aligned keys, see the module
    docstring).

    Returns ``(tokens (B, W) int32, keys_after (B, W, 2) uint32)`` where
    ``keys_after[:, i]`` is the key state after ``i + 1`` samples (the
    caller gathers the slot's new key at its last committed position;
    ``keys_after[:, 0]`` equals ``sample_tokens``'s advanced keys).
    """
    def body(k, lg):
        toks, nk = _sample(lg, k, temperature, top_k, top_p)
        return nk, (toks, nk)

    _, (toks, nks) = jax.lax.scan(body, keys, jnp.moveaxis(logits, 1, 0))
    return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(nks, 0, 1)
