"""Deterministic fault injection for the serving engine (chaos harness).

Every fault path the engine's robustness layer handles -- non-finite
activations, lost staging uploads, straggling device rounds -- can be
*expressed* here and replayed exactly, so failure handling is tested the
same way correctness is: against a seeded, reproducible schedule rather
than by waiting for production to break.

``FaultInjector`` is consulted by ``ServingEngine.step`` at three named
injection points:

  * ``corrupt_state`` -- before the superstep dispatch covering device
    rounds ``[base_round, base_round + k)``, returns the slots whose
    recurrent state the engine overwrites with NaN.  The in-loop
    numerical health guard then detects the poisoned row on its next
    round, suppresses its emission and kills it (quarantine -> bounded
    retry).  Explicit ``nan_at=((round, slot), ...)`` schedules fire at
    the superstep boundary covering that round (host code cannot reach
    inside the jitted scan mid-flight -- with ``decode_block=1`` the
    boundary IS the round); ``nan_rate`` draws per slot-round.
  * ``drop_upload`` -- a staged-request upload "fails": the engine skips
    those slots' staging upload this host round-trip and retries on the
    next, so the request arms one superstep late.  Models a transient
    host->device transfer loss without losing the request.
  * ``straggler`` -- after the superstep returns, the engine stalls for
    ``straggler_s`` wall seconds, modelling a slow device round (shows
    up in wall-clock latency stats, never in round-clock counters).
  * ``shard_crash`` -- before staging, kills a whole data shard of the
    slot pool at a scheduled device round (``shard_crash_at``): the
    engine marks the shard's rows permanently dead, drains its staged +
    in-flight requests back through the requeue path onto the surviving
    shards and serves degraded on the smaller pool (DP-shard failover;
    see README "Failure model" / "Crash recovery").

Determinism: each injection point owns an independent
``numpy.random.Generator`` seeded from ``seed``, and every call draws a
fixed-shape sample, so a fixed engine configuration + request trace
replays the exact same fault schedule.  An engine constructed with
``faults=None`` never touches this module (the injector is fully inert
when disabled -- the fault-free path is bit-identical with or without
the harness importable), and an injector with all rates zero and no
explicit schedules injects nothing.

``injector.events`` logs every injected fault as ``(kind, when, slot)``
tuples for assertions and bench reporting.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

INJECTION_POINTS = ("corrupt_state", "drop_upload", "straggler",
                    "shard_crash")

_RATE_FIELDS = ("nan_rate", "drop_rate", "straggler_rate")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault schedule for :class:`FaultInjector`.

    Rates are per-opportunity probabilities: ``nan_rate`` per slot-round,
    ``drop_rate`` per dirty staging slot per upload, ``straggler_rate``
    per host round-trip.  ``nan_at`` adds explicit (device round, slot)
    corruptions on top of the random draws (the deterministic handle the
    unit tests use).  ``shard_crash_at`` is an explicit (device round,
    data shard) kill schedule: the engine drains the dead shard's
    requests onto the survivors and serves degraded (DP-shard failover
    -- a crash is a scheduled event, not a rate, so recovery replays are
    exact).  Rates outside [0, 1] are rejected at construction: a typo'd
    ``nan_rate=10`` would otherwise silently behave as rate 1.0.
    """
    seed: int = 0
    nan_rate: float = 0.0
    drop_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_s: float = 0.001
    nan_at: Tuple[Tuple[int, int], ...] = ()
    shard_crash_at: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self):
        for name in _RATE_FIELDS:
            v = getattr(self, name)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(
                    f"{name} is a probability and must be in [0, 1], "
                    f"got {v!r}")
        if self.straggler_s < 0.0:
            raise ValueError(
                f"straggler_s must be >= 0 seconds, got "
                f"{self.straggler_s!r}")


class FaultInjector:
    def __init__(self, cfg: FaultConfig = None, **kw):
        if cfg is None:
            cfg = FaultConfig(**kw)
        elif kw:
            raise ValueError("pass FaultConfig or kwargs, not both")
        self.cfg = cfg
        self._rng = {p: np.random.default_rng(cfg.seed * 7919 + i)
                     for i, p in enumerate(INJECTION_POINTS)}
        self.events: List[Tuple[str, int, int]] = []
        self._crashed_shards: set = set()

    # -- named injection points ---------------------------------------
    def corrupt_state(self, base_round: int, k: int,
                      batch: int) -> List[int]:
        """Slots to poison before the superstep over rounds
        ``[base_round, base_round + k)``."""
        hits = {slot for r, slot in self.cfg.nan_at
                if base_round <= r < base_round + k and 0 <= slot < batch}
        if self.cfg.nan_rate > 0.0:
            draws = self._rng["corrupt_state"].random((k, batch))
            hits |= set(np.nonzero((draws < self.cfg.nan_rate)
                                   .any(axis=0))[0].tolist())
        slots = sorted(hits)
        self.events.extend(("corrupt_state", base_round, s) for s in slots)
        return slots

    def drop_upload(self, call_idx: int,
                    slots: Sequence[int]) -> Tuple[List[int], List[int]]:
        """Split this upload's dirty slots into (kept, dropped)."""
        if self.cfg.drop_rate <= 0.0 or not slots:
            return list(slots), []
        draws = self._rng["drop_upload"].random(len(slots))
        kept = [s for s, d in zip(slots, draws)
                if d >= self.cfg.drop_rate]
        dropped = [s for s in slots if s not in kept]
        self.events.extend(("drop_upload", call_idx, s) for s in dropped)
        return kept, dropped

    def straggler(self, call_idx: int) -> float:
        """Seconds of injected stall after this host round-trip."""
        if self.cfg.straggler_rate <= 0.0:
            return 0.0
        if self._rng["straggler"].random() < self.cfg.straggler_rate:
            self.events.append(("straggler", call_idx, -1))
            return self.cfg.straggler_s
        return 0.0

    def shard_crash(self, base_round: int, k: int,
                    n_shards: int) -> List[int]:
        """Data shards scheduled to die during the superstep covering
        rounds ``[base_round, base_round + k)`` (each shard fires at
        most once per injector lifetime -- a dead shard stays dead).
        Schedule-only by design: a crash is the one fault whose recovery
        path must replay exactly, so it is pinned to a device round
        rather than drawn from a rate."""
        hits = {shard for r, shard in self.cfg.shard_crash_at
                if base_round <= r < base_round + k
                and 0 <= shard < n_shards} - self._crashed_shards
        shards = sorted(hits)
        self._crashed_shards |= hits
        self.events.extend(("shard_crash", base_round, s) for s in shards)
        return shards

    # -- reporting ----------------------------------------------------
    def counts(self) -> dict:
        """Injected-event count per injection point (every point keyed,
        including zero-count ones, so dashboards diff cleanly)."""
        out = {p: 0 for p in INJECTION_POINTS}
        for kind, _, _ in self.events:
            out[kind] += 1
        return out

    # -- snapshot support (serving/recovery.py) -----------------------
    def state_dict(self) -> dict:
        """JSON-able mid-trace state: per-point RNG generator states,
        the event log and the fired shard-crash set.  Restoring this
        into a fresh injector (same :class:`FaultConfig`) makes the
        remaining fault schedule identical to the uninterrupted run --
        the property journal-tail replay needs."""
        return {
            "rng": {p: g.bit_generator.state
                    for p, g in self._rng.items()},
            "events": [list(e) for e in self.events],
            "crashed_shards": sorted(self._crashed_shards),
        }

    def load_state_dict(self, state: dict) -> None:
        for p, s in state.get("rng", {}).items():
            if p in self._rng:
                self._rng[p].bit_generator.state = s
        self.events = [tuple(e) for e in state.get("events", [])]
        self._crashed_shards = set(state.get("crashed_shards", []))
