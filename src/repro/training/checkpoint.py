"""Fault-tolerant checkpointing.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, written to a tmp dir
and atomically renamed (a crash mid-save never corrupts the latest good
checkpoint).  Restore accepts target shardings, so a checkpoint written on
one mesh restores onto any other mesh (elastic scaling / node-count
changes): arrays are device_put with the *target* NamedShardings.

Trees are flattened to path-keyed entries ("params/layers/blocks/..."), so
restore does not need a pickled treedef -- robust across code versions.

Integrity: ``save`` records the SHA-256 of ``arrays.npz`` in the
manifest; ``restore`` re-hashes and raises :class:`CheckpointCorruptError`
on mismatch (bit rot, truncated copy, torn write on a non-atomic
filesystem).  ``CheckpointManager.restore_latest`` walks checkpoints
newest-first and falls back past corrupt ones, so one bad checkpoint
degrades recovery by ``save_interval`` steps instead of killing it.
Checkpoints written before this scheme (no ``checksum`` field) restore
unverified for compatibility.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import logging
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

log = logging.getLogger("repro.checkpoint")


class CheckpointCorruptError(RuntimeError):
    """arrays.npz does not match the manifest checksum (or is missing)."""


def sha256_file(path: str) -> str:
    """Streaming SHA-256 of a file's content (the integrity primitive
    shared with the serving snapshot codec, ``serving/recovery.py``)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


_sha256 = sha256_file

import jax
import jax.numpy as jnp
import numpy as np

SEP = "|"


def flatten_tree(tree, prefix: str) -> Dict[str, np.ndarray]:
    """Flatten a pytree to host-numpy entries keyed by ``SEP``-joined
    path strings under ``prefix`` -- restore needs no pickled treedef."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = prefix + SEP + SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def unflatten_tree(flat: Dict[str, np.ndarray], prefix: str):
    """Rebuild a nested dict tree from path keys."""
    root: Dict[str, Any] = {}
    pl = prefix + SEP
    for key, val in flat.items():
        if not key.startswith(pl):
            continue
        parts = key[len(pl):].split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


_flatten = flatten_tree
_unflatten = unflatten_tree


def pack_arrays(arrays: Dict[str, np.ndarray]):
    """npz-safe packing: bf16 leaves round-trip via a uint16 view.
    Returns ``(packed, dtypes)`` where ``dtypes`` goes in the manifest."""
    dtypes, packed = {}, {}
    for k, v in arrays.items():
        if v.dtype == jnp.bfloat16:
            packed[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            packed[k] = v
            dtypes[k] = str(v.dtype)
    return packed, dtypes


def unpack_arrays(raw, dtypes: Dict[str, str]) -> Dict[str, np.ndarray]:
    """Inverse of :func:`pack_arrays` over a loaded npz."""
    out = {}
    for k in raw.files:
        v = raw[k]
        if dtypes.get(k) == "bfloat16":
            v = v.view(jnp.bfloat16)
        out[k] = v
    return out


@contextlib.contextmanager
def atomic_dir(final: str):
    """Yield a tmp directory that atomically replaces ``final`` when the
    block completes -- a crash mid-write never corrupts the previous
    good generation (checkpoints and serving snapshots share this)."""
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    yield tmp
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def save(directory: str, step: int, params, opt_state=None,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic checkpoint write.  Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")

    arrays = flatten_tree(params, "params")
    manifest = {"step": step, "time": time.time(), "extra": extra or {}}
    if opt_state is not None:
        arrays.update(flatten_tree(opt_state.mu, "mu"))
        arrays.update(flatten_tree(opt_state.nu, "nu"))
        arrays["opt_step"] = np.asarray(jax.device_get(opt_state.step))
        manifest["has_opt"] = True
    # dtype map (npz keeps dtypes, but bf16 round-trips via view)
    packed, dtypes = pack_arrays(arrays)
    manifest["dtypes"] = dtypes
    with atomic_dir(final) as tmp:
        np.savez(os.path.join(tmp, "arrays.npz"), **packed)
        manifest["checksum"] = "sha256:" + sha256_file(
            os.path.join(tmp, "arrays.npz"))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    return final


def verify(path: str) -> bool:
    """True iff the checkpoint's content hash matches its manifest.
    Pre-checksum checkpoints (no ``checksum`` field) verify trivially."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        return False
    recorded = manifest.get("checksum")
    if recorded is None:
        return os.path.exists(os.path.join(path, "arrays.npz"))
    try:
        return recorded == "sha256:" + _sha256(
            os.path.join(path, "arrays.npz"))
    except OSError:
        return False


def _load_arrays(path: str) -> Dict[str, np.ndarray]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    recorded = manifest.get("checksum")
    if recorded is not None:
        actual = "sha256:" + sha256_file(os.path.join(path, "arrays.npz"))
        if actual != recorded:
            raise CheckpointCorruptError(
                f"{path}: arrays.npz hash {actual} != manifest "
                f"{recorded}")
    raw = np.load(os.path.join(path, "arrays.npz"))
    return unpack_arrays(raw, manifest["dtypes"]), manifest


def restore(path: str, *, shardings=None, opt_shardings=None):
    """Returns (step, params, opt_state_or_None).

    ``shardings``: optional pytree of NamedShardings matching params --
    arrays land directly on the (possibly different) target mesh.
    """
    flat, manifest = _load_arrays(path)
    params = _unflatten(flat, "params")
    params = _place(params, shardings)
    opt_state = None
    if manifest.get("has_opt"):
        from repro.training.optimizer import AdamWState
        mu = _place(_unflatten(flat, "mu"),
                    opt_shardings[1] if opt_shardings else shardings)
        nu = _place(_unflatten(flat, "nu"),
                    opt_shardings[2] if opt_shardings else shardings)
        opt_state = AdamWState(step=jnp.asarray(flat["opt_step"]),
                               mu=mu, nu=nu)
    return manifest["step"], params, opt_state


def _place(tree, shardings):
    if shardings is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.tree.map(lambda a, s: jax.device_put(jnp.asarray(a), s),
                        tree, shardings)


def all_steps(directory: str):
    """Completed checkpoint steps in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


class CheckpointManager:
    """keep-N GC + optional background-thread saves."""

    def __init__(self, directory: str, keep: int = 3,
                 save_interval: int = 100, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.corrupt_skipped: list = []   # steps restore_latest fell past

    def maybe_save(self, step: int, params, opt_state=None, force=False):
        if not force and (step == 0 or step % self.save_interval != 0):
            return False
        self.wait()
        if self.async_save:
            # snapshot to host before handing off to the thread
            host_p = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  params)
            host_o = opt_state if opt_state is None else jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), opt_state)
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_p, host_o))
            self._thread.start()
        else:
            self._save_and_gc(step, params, opt_state)
        return True

    def _save_and_gc(self, step, params, opt_state):
        save(self.directory, step, params, opt_state)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, **kw):
        """Restore the newest checkpoint that passes integrity
        verification, falling back past corrupt ones (recorded in
        ``corrupt_skipped``).  Returns None when no restorable
        checkpoint exists."""
        for step in reversed(all_steps(self.directory)):
            path = os.path.join(self.directory, f"step_{step:08d}")
            try:
                return restore(path, **kw)
            except (CheckpointCorruptError, OSError, ValueError,
                    KeyError) as e:
                self.corrupt_skipped.append(step)
                log.warning("checkpoint %s unrestorable (%s); "
                            "falling back", path, e)
        return None
