"""Train-step builders.

Two paths:

  * ``make_train_step``       -- pjit/auto-sharded step for any arch (the
    dry-run path): loss -> grad -> AdamW, optional microbatch gradient
    accumulation via lax.scan (each microbatch's reduce-scatter overlaps the
    next microbatch's compute under XLA's latency-hiding scheduler).
  * ``make_dp_compressed_step`` -- explicit shard_map data-parallel step
    with the gradient all-reduce performed in bf16 (2x cross-pod bytes;
    EXPERIMENTS.md §Perf quantifies).  Params replicated (paper-scale LMs).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import encdec, lm
from repro.training import optimizer as opt_lib


def model_for(cfg):
    return encdec if cfg.family == "encdec" else lm


def make_loss_fn(cfg):
    model = model_for(cfg)

    def loss(params, batch):
        return model.loss_fn(params, cfg, batch)

    return loss


def make_train_step(cfg, opt_cfg: opt_lib.AdamWConfig, *,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, state, mx)."""
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (l, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulated(params, batch):
        # batch leaves: (n_micro, mb, ...) -- scan keeps grads fp32
        def body(acc, micro):
            grads, metrics = single(params, micro)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = lax.scan(body, zeros, batch)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            batch = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            grads, metrics = accumulated(params, batch)
        else:
            grads, metrics = single(params, batch)
        params, opt_state, om = opt_lib.apply(opt_cfg, opt_state, params,
                                              grads)
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def make_dp_compressed_step(cfg, opt_cfg: opt_lib.AdamWConfig, mesh, *,
                            grad_dtype=jnp.bfloat16) -> Callable:
    """Explicit-DP step: per-device grads cast to ``grad_dtype`` before the
    cross-device psum (gradient compression), fp32 master accumulation in
    the optimizer.  Params replicated across the mesh."""
    from repro.distributed import context as mesh_ctx
    from repro.distributed.context import dp_axes

    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    dp = dp_axes(mesh)

    def local_step(params, opt_state, batch):
        (l, metrics), grads = grad_fn(params, batch)
        # compression boundary: the only cross-device traffic is this psum
        grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        grads = lax.pmean(grads, dp)
        metrics = lax.pmean(metrics, dp)
        params, opt_state, om = opt_lib.apply(opt_cfg, opt_state, params,
                                              grads)
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    batch_spec = P(dp)

    def wrapped(params, opt_state, batch):
        in_batch_specs = jax.tree.map(
            lambda x: P(dp, *([None] * (x.ndim - 1))), batch)
        return mesh_ctx.shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P(), in_batch_specs),
            out_specs=(P(), P(), P()),
            check_vma=False,
        )(params, opt_state, batch)

    return wrapped
