"""Optimizers in pure JAX (no optax): AdamW with dtype-configurable moments
(bf16 moments halve optimizer HBM at >100B scale), global-norm clipping,
and warmup+cosine schedules.  State is a pytree mirroring params, so it
inherits parameter sharding (ZeRO: moments are sharded exactly like their
parameters)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"        # "bfloat16" for >100B models
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"             # cosine | constant | linear


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


def _decay_mask(path_leaf):
    """No weight decay on norms / biases / 1-d params."""
    path, leaf = path_leaf
    names = [str(getattr(p, "key", p)) for p in path]
    if leaf.ndim <= 1:
        return 0.0
    if any(n in ("scale", "bias", "a_log", "dt_bias", "d_skip") for n in names):
        return 0.0
    return 1.0


def schedule_lr(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "linear":
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    else:                                 # cosine
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * 0.5 * (
            1.0 + jnp.cos(math.pi * frac))
    return cfg.lr * warm * decay


def init(cfg: AdamWConfig, params) -> AdamWState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, state: AdamWState, params, grads,
          ) -> tuple:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    masks = [_decay_mask(pl) for pl in flat]
    masks = jax.tree_util.tree_unflatten(treedef, masks)

    def upd(p, g, mu, nu, wd_mask):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32)
        nu32 = nu.astype(jnp.float32)
        mu32 = b1 * mu32 + (1 - b1) * g32
        nu32 = b2 * nu32 + (1 - b2) * g32 * g32
        mu_hat = mu32 / bc1
        nu_hat = nu32 / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * wd_mask * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), mu32.astype(mu.dtype),
                nu32.astype(nu.dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu, masks)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
