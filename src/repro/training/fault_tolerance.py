"""Fault-tolerant training supervision.

``TrainSupervisor`` wraps the step loop with:

  * crash recovery: any exception in a step triggers restore from the last
    checkpoint and a deterministic data fast-forward (the data pipeline is
    a pure function of step index -- repro.data: no iterator state to lose).
    Restore verifies the checkpoint's content checksum and falls back past
    corrupt ones (``SupervisorReport.ckpt_fallbacks`` counts them);
  * straggler watchdog: per-step wall time EMA; steps slower than
    ``straggler_factor`` x EMA are logged and counted (on a real cluster
    the hook re-dispatches the shard -- here it records the event);
  * bounded retries so a deterministically-failing step surfaces instead of
    looping forever.

At 1000+ nodes the same structure holds: each host runs this loop over its
own shard; checkpoint save/restore is collective-free (per-host arrays.npz
written independently when params are host-local shards).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.training.checkpoint import CheckpointManager

log = logging.getLogger("repro.fault_tolerance")


@dataclass
class SupervisorReport:
    steps_run: int = 0
    failures_recovered: int = 0
    straggler_events: int = 0
    ckpt_fallbacks: int = 0    # corrupt checkpoints skipped on restore
    restarts: List[int] = field(default_factory=list)
    final_metrics: Optional[Dict[str, Any]] = None


class TrainSupervisor:
    def __init__(self, step_fn: Callable, batch_fn: Callable[[int], Any],
                 ckpt: CheckpointManager, *, max_retries: int = 3,
                 straggler_factor: float = 3.0, ema_decay: float = 0.9):
        """step_fn(params, opt_state, batch) -> (params, opt_state, metrics);
        batch_fn(step) -> batch (deterministic in step)."""
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.ema_decay = ema_decay
        self.failure_hook: Optional[Callable[[int], None]] = None  # tests

    def run(self, params, opt_state, n_steps: int,
            start_step: int = 0) -> tuple:
        report = SupervisorReport()
        step = start_step
        retries = 0
        ema: Optional[float] = None
        while step < n_steps:
            t0 = time.monotonic()
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)      # may raise (fault injection)
                batch = self.batch_fn(step)
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                report.final_metrics = metrics
            except Exception as e:   # noqa: BLE001 -- any step fault
                retries += 1
                report.failures_recovered += 1
                report.restarts.append(step)
                log.warning("step %d failed (%s); restoring", step, e)
                if retries > self.max_retries:
                    raise RuntimeError(
                        f"step {step} failed {retries} times") from e
                skipped0 = len(getattr(self.ckpt, "corrupt_skipped", ()))
                restored = self.ckpt.restore_latest()
                report.ckpt_fallbacks += len(getattr(
                    self.ckpt, "corrupt_skipped", ())) - skipped0
                if restored is not None:
                    ckpt_step, params, opt_state = restored
                    step = ckpt_step
                # else: retry from current in-memory state
                continue
            retries = 0
            dt = time.monotonic() - t0
            if ema is not None and dt > self.straggler_factor * ema:
                report.straggler_events += 1
                log.warning("straggler: step %d took %.3fs (EMA %.3fs)",
                            step, dt, ema)
            ema = dt if ema is None else \
                self.ema_decay * ema + (1 - self.ema_decay) * dt
            step += 1
            report.steps_run += 1
            self.ckpt.maybe_save(step, params, opt_state)
        self.ckpt.wait()
        return params, opt_state, report
