"""minGRU (the paper's Section 3.1).

    z_t  = sigma(Linear_dh(x_t))
    h~_t = Linear_dh(x_t)            (vanilla)  |  g(Linear_dh(x_t)) (log mode)
    h_t  = (1 - z_t) * h_{t-1} + z_t * h~_t

Two numerical modes, both from the paper:
  * ``linear``  -- Appendix A: scan directly on (a, b) = (1-z, z*h~)
  * ``log``     -- Appendix B: Heinsen log-space scan; requires h~ > 0 via g()

Each mode has a parallel (training / prefill) and a sequential step
(decode) form; parallel == rolled-out sequential is tested exhaustively.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core import scan as scan_lib

Array = jax.Array


def init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32,
         use_bias: bool = True):
    kz, kh = jax.random.split(key)
    return {
        "wz": nn.dense_init(kz, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
        "wh": nn.dense_init(kh, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
    }


def n_params(d_in: int, d_hidden: int, use_bias: bool = False) -> int:
    return 2 * d_in * d_hidden + (2 * d_hidden if use_bias else 0)


# ---------------------------------------------------------------------------
# Parallel (training / prefill) modes
# ---------------------------------------------------------------------------

def parallel(params, x: Array, h0: Optional[Array] = None, *,
             mode: str = "log", scan_strategy: str = "associative",
             compute_dtype=None) -> Array:
    """x: (..., T, d_in) -> h: (..., T, d_hidden).

    ``scan_strategy`` selects the execution path (``core.scan.STRATEGIES``):
    ``"auto"``/``"fused"`` run the whole layer (projections + scan) in the
    Pallas fused kernel; ``"pallas"`` keeps XLA projections but scans in
    the Pallas kernel (log-space kernel for ``mode="log"``); the remaining
    strategies are pure-jnp.  In log mode only ``pallas`` changes the scan
    implementation -- ``sequential``/``chunked`` fall back to the
    associative Heinsen scan.
    """
    if mode not in ("log", "linear"):
        raise ValueError(f"unknown minGRU mode {mode!r}")
    strategy = scan_lib.resolve_strategy(scan_strategy)
    if strategy == "fused":
        return _fused_parallel(params, x, h0, mode=mode,
                               compute_dtype=compute_dtype)
    k = nn.dense_apply(params["wz"], x, compute_dtype)   # gate pre-activation
    v = nn.dense_apply(params["wh"], x, compute_dtype)   # candidate pre-act

    if mode == "log":
        # Appendix B Algorithm 6, scanned in fp32 for stability.
        log_z = nn.log_sigmoid(k.astype(jnp.float32))
        log_coeffs = nn.log_sigmoid(-k.astype(jnp.float32))   # log(1-z)
        log_h_tilde = nn.log_g(v.astype(jnp.float32))
        log_h0 = None if h0 is None else jnp.log(h0.astype(jnp.float32))
        h = scan_lib.scan_log_space(log_coeffs, log_z + log_h_tilde, log_h0,
                                    strategy=strategy)
        return h.astype(x.dtype if compute_dtype is None else compute_dtype)
    z = jax.nn.sigmoid(k)
    a = 1.0 - z
    b = z * v
    return scan_lib.scan_linear(a, b, h0, strategy=strategy)


def _fused_parallel(params, x: Array, h0: Optional[Array], *, mode: str,
                    compute_dtype=None) -> Array:
    """Whole layer in one Pallas call (kernels/fused_mingru)."""
    from repro.kernels.fused_mingru import ops as fused_ops
    from repro.kernels.scan.ops import call_with_flat_lead
    wz, wh = params["wz"]["kernel"], params["wh"]["kernel"]
    bz, bh = params["wz"].get("bias"), params["wh"].get("bias")
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        wz, wh = wz.astype(compute_dtype), wh.astype(compute_dtype)
        bz = None if bz is None else bz.astype(compute_dtype)
        bh = None if bh is None else bh.astype(compute_dtype)
    if h0 is None:                          # kernel wants (B, T, D)
        return call_with_flat_lead(
            lambda xf: fused_ops.fused_mingru(xf, wz, bz, wh, bh,
                                              mode=mode), (x, 2))
    return call_with_flat_lead(
        lambda xf, h0f: fused_ops.fused_mingru(xf, wz, bz, wh, bh, h0f,
                                               mode=mode), (x, 2), (h0, 1))


def gates(params, x: Array, *, mode: str = "log", compute_dtype=None):
    """Return the (a, b) recurrence inputs -- used by the Pallas fused path
    and by the sequence-parallel layer which must scan externally.

    Note on modes: these are always *linear-space* scan inputs, even for
    ``mode="log"`` -- (a, b) = (1-z, z*g(v)).  Scanning them linearly is
    mathematically identical to ``parallel(mode="log")``'s log-space
    Heinsen scan (h_t = (1-z_t) h_{t-1} + z_t g(v_t) either way); the
    parameterisations differ only in rounding.  In fp32 the linear scan is
    fine at any practical T (gates in (0,1) keep it non-amplifying), which
    is why the fused kernel scans linearly in fp32 on-chip.  In bf16 the
    linear form drifts measurably by T ~ 4096 while the log form does not
    -- ``tests/test_kernels.py::test_log_vs_linear_bf16_drift_at_4096``
    quantifies this, motivating the log-space kernel for low-precision
    inputs.
    """
    k = nn.dense_apply(params["wz"], x, compute_dtype)
    v = nn.dense_apply(params["wh"], x, compute_dtype)
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    return 1.0 - z, z * h_tilde


# ---------------------------------------------------------------------------
# Sequential step (decode)
# ---------------------------------------------------------------------------

def step(params, x_t: Array, h_prev: Array, *, mode: str = "log",
         compute_dtype=None, scan_strategy: Optional[str] = None) -> Array:
    """x_t: (..., d_in), h_prev: (..., d_hidden) -> h_t.

    ``scan_strategy`` mirrors ``parallel``'s contract for the decode hot
    path: ``"auto"``/``"fused"`` run the whole step (both GEMVs + gates +
    state update) in the fused Pallas decode kernel
    (``kernels/decode_step``); ``None`` or any other strategy runs the
    pure-jnp reference below (the oracle the kernel is tested against).
    """
    if scan_strategy is not None and \
            scan_lib.resolve_strategy(scan_strategy) == "fused":
        return _fused_step(params, x_t, h_prev, mode=mode,
                           compute_dtype=compute_dtype)
    z = jax.nn.sigmoid(nn.dense_apply(params["wz"], x_t, compute_dtype))
    v = nn.dense_apply(params["wh"], x_t, compute_dtype)
    h_tilde = nn.g(v) if mode == "log" else v
    return (1.0 - z) * h_prev + z * h_tilde


def _fused_step_args(params, x: Array, compute_dtype):
    """Shared fused-path prep: extract wz/bz/wh/bh and apply the
    compute-dtype cast (to x and every weight/bias) in one place for the
    step and chunk dispatchers."""
    wz, wh = params["wz"]["kernel"], params["wh"]["kernel"]
    bz, bh = params["wz"].get("bias"), params["wh"].get("bias")
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        wz, wh = wz.astype(compute_dtype), wh.astype(compute_dtype)
        bz = None if bz is None else bz.astype(compute_dtype)
        bh = None if bh is None else bh.astype(compute_dtype)
    return x, wz, bz, wh, bh


def _fused_step(params, x_t: Array, h_prev: Array, *, mode: str,
                compute_dtype=None) -> Array:
    """Whole cell step in one Pallas call (kernels/decode_step)."""
    from repro.kernels.decode_step import ops as step_ops
    x_t, wz, bz, wh, bh = _fused_step_args(params, x_t, compute_dtype)
    return step_ops.fused_mingru_step(x_t, wz, bz, wh, bh, h_prev, mode=mode)


def step_chunk(params, x: Array, h_prev: Array, valid: Array, *,
               mode: str = "log", compute_dtype=None,
               scan_strategy: Optional[str] = None) -> Array:
    """Packed varlen decode chunk: x: (..., C, d_in), h_prev: (...,
    d_hidden), valid: (...,) int32 in [1, C] -> hs: (..., C, d_hidden).

    Row b advances through its first ``valid[b]`` tokens with the *exact*
    per-token arithmetic of :func:`step` and freezes after (positions >=
    ``valid[b]-1`` all hold the final state, so ``hs[..., -1, :]`` is the
    carry).  ``scan_strategy`` mirrors ``step``'s contract:
    ``"auto"``/``"fused"`` run the whole chunk in one Pallas call
    (``kernels/decode_step`` chunk kernels -- the gate weights stream
    from HBM once for the whole chunk, the serving prompt-packing win);
    anything else is the pure-jnp masked sequential reference.
    """
    if scan_strategy is not None and \
            scan_lib.resolve_strategy(scan_strategy) == "fused":
        from repro.kernels.decode_step import ops as step_ops
        x, wz, bz, wh, bh = _fused_step_args(params, x, compute_dtype)
        return step_ops.fused_mingru_chunk(x, wz, bz, wh, bh, h_prev,
                                           valid, mode=mode)

    def body(h, inp):
        x_t, t = inp
        h_new = step(params, x_t, h, mode=mode, compute_dtype=compute_dtype)
        h = jnp.where((t < valid)[..., None], h_new, h).astype(h.dtype)
        return h, h

    _, hs = jax.lax.scan(
        body, h_prev,
        (jnp.moveaxis(x, -2, 0), jnp.arange(x.shape[-2])))
    return jnp.moveaxis(hs, 0, -2)
