"""minGRU (the paper's Section 3.1).

    z_t  = sigma(Linear_dh(x_t))
    h~_t = Linear_dh(x_t)            (vanilla)  |  g(Linear_dh(x_t)) (log mode)
    h_t  = (1 - z_t) * h_{t-1} + z_t * h~_t

Two numerical modes, both from the paper:
  * ``linear``  -- Appendix A: scan directly on (a, b) = (1-z, z*h~)
  * ``log``     -- Appendix B: Heinsen log-space scan; requires h~ > 0 via g()

Each mode has a parallel (training / prefill) and a sequential step
(decode) form; parallel == rolled-out sequential is tested exhaustively.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core import scan as scan_lib

Array = jax.Array


def init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32,
         use_bias: bool = True):
    kz, kh = jax.random.split(key)
    return {
        "wz": nn.dense_init(kz, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
        "wh": nn.dense_init(kh, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
    }


def n_params(d_in: int, d_hidden: int, use_bias: bool = False) -> int:
    return 2 * d_in * d_hidden + (2 * d_hidden if use_bias else 0)


# ---------------------------------------------------------------------------
# Parallel (training / prefill) modes
# ---------------------------------------------------------------------------

def parallel(params, x: Array, h0: Optional[Array] = None, *,
             mode: str = "log", scan_strategy: str = "associative",
             compute_dtype=None) -> Array:
    """x: (..., T, d_in) -> h: (..., T, d_hidden)."""
    k = nn.dense_apply(params["wz"], x, compute_dtype)   # gate pre-activation
    v = nn.dense_apply(params["wh"], x, compute_dtype)   # candidate pre-act

    if mode == "log":
        # Appendix B Algorithm 6, scanned in fp32 for stability.
        log_z = nn.log_sigmoid(k.astype(jnp.float32))
        log_coeffs = nn.log_sigmoid(-k.astype(jnp.float32))   # log(1-z)
        log_h_tilde = nn.log_g(v.astype(jnp.float32))
        log_h0 = None if h0 is None else jnp.log(h0.astype(jnp.float32))
        h = scan_lib.scan_log_space(log_coeffs, log_z + log_h_tilde, log_h0)
        return h.astype(x.dtype if compute_dtype is None else compute_dtype)
    elif mode == "linear":
        z = jax.nn.sigmoid(k)
        a = 1.0 - z
        b = z * v
        return scan_lib.scan_linear(a, b, h0, strategy=scan_strategy)
    raise ValueError(f"unknown minGRU mode {mode!r}")


def gates(params, x: Array, *, mode: str = "log", compute_dtype=None):
    """Return the (a, b) recurrence inputs -- used by the Pallas fused path
    and by the sequence-parallel layer which must scan externally."""
    k = nn.dense_apply(params["wz"], x, compute_dtype)
    v = nn.dense_apply(params["wh"], x, compute_dtype)
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    return 1.0 - z, z * h_tilde


# ---------------------------------------------------------------------------
# Sequential step (decode)
# ---------------------------------------------------------------------------

def step(params, x_t: Array, h_prev: Array, *, mode: str = "log",
         compute_dtype=None) -> Array:
    """x_t: (..., d_in), h_prev: (..., d_hidden) -> h_t."""
    z = jax.nn.sigmoid(nn.dense_apply(params["wz"], x_t, compute_dtype))
    v = nn.dense_apply(params["wh"], x_t, compute_dtype)
    h_tilde = nn.g(v) if mode == "log" else v
    return (1.0 - z) * h_prev + z * h_tilde
