"""Core: the paper's contribution (parallel-scan minimal RNNs)."""

from repro.core import blocks, gru, lstm, min_gru, min_lstm, nn, scan  # noqa: F401
