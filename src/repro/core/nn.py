"""Minimal functional NN building blocks (no flax dependency).

Parameters are plain nested dicts of jax arrays.  Every layer is a pair of
functions: ``*_init(key, ...) -> params`` and ``*_apply(params, x) -> y``.
Sharding is attached later by path-based rules (repro.distributed.sharding).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def lecun_normal(key, shape, dtype=jnp.float32, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = math.sqrt(1.0 / max(1, fan_in))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def normal_init(key, shape, std, dtype=jnp.float32):
    return (std * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, *, use_bias: bool = True,
               dtype=jnp.float32, bias_init: float = 0.0):
    p = {"kernel": lecun_normal(key, (in_dim, out_dim), dtype)}
    if use_bias:
        p["bias"] = jnp.full((out_dim,), bias_init, dtype)
    return p


def dense_apply(p, x: Array, compute_dtype=None) -> Array:
    k = p["kernel"]
    if compute_dtype is not None:
        k = k.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ k
    if "bias" in p:
        b = p["bias"]
        if compute_dtype is not None:
            b = b.astype(compute_dtype)
        y = y + b
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x: Array, eps: float = 1e-6,
                  zero_centered: bool = False) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:          # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (y * scale).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dtype)


def norm_init(kind: str, dim: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return rmsnorm_init(dim, dtype)
    if kind == "layernorm":
        return layernorm_init(dim, dtype)
    raise ValueError(kind)


def norm_apply(kind: str, p, x: Array, **kw) -> Array:
    if kind == "rmsnorm":
        return rmsnorm_apply(p, x, **kw)
    if kind == "layernorm":
        return layernorm_apply(p, x, **kw)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Causal depthwise conv (the paper's / mamba's "Conv4" temporal mixer)
# ---------------------------------------------------------------------------

def causal_conv_init(key, dim: int, kernel_size: int = 4, dtype=jnp.float32):
    std = math.sqrt(1.0 / (kernel_size))
    return {"kernel": normal_init(key, (kernel_size, dim), std, dtype),
            "bias": jnp.zeros((dim,), dtype)}


def causal_conv_apply(p, x: Array, prefix: Optional[Array] = None) -> Array:
    """x: (..., T, D) depthwise causal conv along T.

    ``prefix`` (default zeros) is the (..., K-1, D) window of inputs that
    precede ``x`` -- passing the carried conv state here makes chunked
    prefill bit-exact with an unchunked pass (same slide-multiply-add
    schedule, only the left pad values change).
    """
    k = p["kernel"].astype(x.dtype)          # (K, D)
    ksize = k.shape[0]
    if prefix is None:
        pad = [(0, 0)] * (x.ndim - 2) + [(ksize - 1, 0), (0, 0)]
        xp = jnp.pad(x, pad)
    else:
        xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=-2)
    # sum_k x[t - (K-1) + k] * k[k]  -- small K: unrolled adds (fuses well)
    y = jnp.zeros_like(x)
    t = x.shape[-2]
    for i in range(ksize):
        y = y + jax.lax.slice_in_dim(xp, i, i + t, axis=-2) * k[i]
    return y + p["bias"].astype(x.dtype)


def causal_conv_step(p, x_t: Array, conv_state: Array):
    """Single decode step. conv_state: (..., K-1, D) trailing inputs."""
    k = p["kernel"].astype(x_t.dtype)
    window = jnp.concatenate([conv_state, x_t[..., None, :]], axis=-2)
    y = jnp.einsum("...kd,kd->...d", window, k) + p["bias"].astype(x_t.dtype)
    return y, window[..., 1:, :]


# ---------------------------------------------------------------------------
# Variable-length (right-padded batch) state gathers
#
# Batched prefill right-pads prompts to a shared T.  Because every sequence
# mixer in the zoo is causal, positions < length are bit-identical to an
# unpadded run, so the decode state of request b is simply the state *at
# position lengths[b]-1* -- these helpers extract it.
# ---------------------------------------------------------------------------

def gather_last(x: Array, lengths: Array) -> Array:
    """x: (B, T, ...) -> (B, ...), row b taken at position lengths[b]-1."""
    idx = (lengths - 1).reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.take_along_axis(x, idx.astype(jnp.int32), axis=1)[:, 0]


def gather_conv_window(x: Array, lengths: Array, width: int,
                       prefix: Optional[Array] = None) -> Array:
    """Trailing ``width`` inputs after consuming ``lengths[b]`` tokens.

    x: (B, T, D); returns (B, width, D) = rows [len-width, len-1] of
    ``concat(prefix, x)`` where ``prefix`` (default zeros) holds the
    ``width`` inputs that preceded ``x`` (carried conv state on resume).
    """
    bsz = x.shape[0]
    if prefix is None:
        prefix = jnp.zeros((bsz, width) + x.shape[2:], x.dtype)
    ext = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    idx = lengths[:, None].astype(jnp.int32) + jnp.arange(width)[None, :]
    idx = idx.reshape(idx.shape + (1,) * (x.ndim - 2))
    return jnp.take_along_axis(ext, idx, axis=1)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "gelu": gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


# ---------------------------------------------------------------------------
# The paper's g() positivity transform (Appendix B, Listing 6)
# ---------------------------------------------------------------------------

def g(x: Array) -> Array:
    """g(x) = x + 0.5 if x >= 0 else sigmoid(x); ensures h_tilde > 0."""
    return jnp.where(x >= 0, x + 0.5, jax.nn.sigmoid(x))


def log_g(x: Array) -> Array:
    """log g(x), computed stably: log(x+0.5) / -softplus(-x)."""
    return jnp.where(x >= 0,
                     jnp.log(jax.nn.relu(x) + 0.5),
                     -jax.nn.softplus(-x))


def log_sigmoid(x: Array) -> Array:
    """log sigma(x) = -softplus(-x)."""
    return -jax.nn.softplus(-x)
