"""Parallel-scan primitives for first-order linear recurrences.

The paper's central algorithmic device: every minGRU/minLSTM (and the SSD
special case used by mamba2/zamba2) reduces to

    h_t = a_t * h_{t-1} + b_t                (elementwise over features)

which is associative under the combine

    (a_i, b_i) o (a_j, b_j) = (a_i * a_j, a_j * b_i + b_j)   (i before j)

and therefore computable in O(log T) depth.  This module provides every
execution strategy the framework uses:

  * ``scan_sequential``     -- lax.scan reference / serving-step oracle
  * ``scan_associative``    -- jax.lax.associative_scan (training default)
  * ``scan_log_space``      -- Heinsen (2023) log-space scan for stability
  * ``scan_chunked``        -- two-level chunked scan (structure mirrors the
                               Pallas kernel; used for very long sequences)
  * ``scan_sequence_parallel`` -- shard_map body: sequence-sharded scan with
                               a single tiny carry-exchange collective

Array convention: time axis is ``axis`` (default -2), i.e. shapes are
``(..., T, D)``; ``h0`` has shape ``(..., D)``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


# ---------------------------------------------------------------------------
# Combine rule
# ---------------------------------------------------------------------------

def combine(left: Tuple[Array, Array], right: Tuple[Array, Array]):
    """Associative combine for h_t = a_t h_{t-1} + b_t segments."""
    a_l, b_l = left
    a_r, b_r = right
    return a_l * a_r, a_r * b_l + b_r


# ---------------------------------------------------------------------------
# Sequential reference (also the serving step)
# ---------------------------------------------------------------------------

def scan_sequential(a: Array, b: Array, h0: Optional[Array] = None,
                    axis: int = -2) -> Array:
    """O(T) lax.scan reference. Ground truth for every other strategy."""
    a = jnp.moveaxis(a, axis, 0)
    b = jnp.moveaxis(b, axis, 0)
    if h0 is None:
        h0 = jnp.zeros_like(b[0])

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = lax.scan(step, h0, (a, b))
    return jnp.moveaxis(hs, 0, axis)


def scan_step(a_t: Array, b_t: Array, h_prev: Array) -> Array:
    """Single recurrence step (decode path)."""
    return a_t * h_prev + b_t


# ---------------------------------------------------------------------------
# Associative scan (training default)
# ---------------------------------------------------------------------------

def scan_associative(a: Array, b: Array, h0: Optional[Array] = None,
                     axis: int = -2) -> Array:
    """Work-efficient parallel scan via jax.lax.associative_scan."""
    a_cum, b_cum = lax.associative_scan(combine, (a, b), axis=axis)
    if h0 is None:
        return b_cum
    return b_cum + a_cum * jnp.expand_dims(h0, axis)


def scan_associative_with_aggregate(a: Array, b: Array, axis: int = -2):
    """As scan_associative but also returns the cumulative coefficients.

    Needed by the chunked / sequence-parallel strategies, which must combine
    an incoming carry: h_t = B_t + A_t * h_in.
    """
    return lax.associative_scan(combine, (a, b), axis=axis)


# ---------------------------------------------------------------------------
# Log-space scan (Heinsen 2023) -- the paper's Appendix B implementation
# ---------------------------------------------------------------------------

def logcumsumexp(x: Array, axis: int = -2) -> Array:
    """Numerically-stable cumulative logsumexp via associative logaddexp."""
    return lax.associative_scan(jnp.logaddexp, x, axis=axis)


def scan_log_space(log_a: Array, log_b: Array,
                   log_h0: Optional[Array] = None, axis: int = -2,
                   strategy: str = "associative") -> Array:
    """Heinsen scan: inputs are log coefficients / log values, output is h.

    h_t = exp(a*_t + logcumsumexp(log_b - a*)_t)  with a*_t = cumsum(log_a).
    Requires b_t > 0 (the paper guarantees this via the g() transform).
    If ``log_h0`` is given it is prepended exactly as in the paper's
    ``torch.cat([log_h0, ...])``.

    ``strategy="pallas"`` routes to the in-kernel logaddexp ladder
    (``repro.kernels.scan.ops.log_space_scan``): same math, chunked in
    VMEM with a log-space cross-chunk carry; any other value runs the
    ``lax.associative_scan`` formulation below.
    """
    if strategy == "pallas":
        from repro.kernels.scan import ops as scan_kernel_ops
        if axis not in (-2, log_a.ndim - 2):
            raise ValueError("pallas log scan requires time axis -2")
        return scan_kernel_ops.log_space_scan_auto(log_a, log_b, log_h0)
    if log_h0 is not None:
        zero = jnp.zeros_like(jnp.take(log_a, jnp.array([0]), axis=axis))
        log_a_ext = jnp.concatenate([zero, log_a], axis=axis)
        log_b_ext = jnp.concatenate(
            [jnp.expand_dims(log_h0, axis), log_b], axis=axis)
        h = scan_log_space(log_a_ext, log_b_ext, None, axis=axis)
        # drop the h0 position
        t = h.shape[axis]
        return lax.slice_in_dim(h, 1, t, axis=axis)
    a_star = jnp.cumsum(log_a, axis=axis)
    log_h = a_star + logcumsumexp(log_b - a_star, axis=axis)
    return jnp.exp(log_h)


# ---------------------------------------------------------------------------
# Chunked two-level scan (mirrors the Pallas kernel's structure)
# ---------------------------------------------------------------------------

def scan_chunked(a: Array, b: Array, h0: Optional[Array] = None,
                 chunk: int = 256, axis: int = -2) -> Array:
    """Two-level scan: intra-chunk parallel, inter-chunk sequential.

    This is the HBM->VMEM blocking the Pallas kernel uses: per-chunk state
    stays on-chip, and only the O(T/chunk) chunk carries are sequential.
    """
    a = jnp.moveaxis(a, axis, -2)
    b = jnp.moveaxis(b, axis, -2)
    batch_shape = a.shape[:-2]
    t, d = a.shape[-2], a.shape[-1]
    if t % chunk != 0:
        pad = chunk - t % chunk
        # pad with identity elements (a=1, b=0)
        a = jnp.concatenate(
            [a, jnp.ones(batch_shape + (pad, d), a.dtype)], axis=-2)
        b = jnp.concatenate(
            [b, jnp.zeros(batch_shape + (pad, d), b.dtype)], axis=-2)
    nc = a.shape[-2] // chunk
    a_c = a.reshape(batch_shape + (nc, chunk, d))
    b_c = b.reshape(batch_shape + (nc, chunk, d))

    # level 1: intra-chunk inclusive scan (parallel over chunks)
    a_cum, b_cum = scan_associative_with_aggregate(a_c, b_c, axis=-2)

    # level 2: exclusive scan over chunk aggregates (sequential, nc steps)
    agg_a = a_cum[..., -1, :]   # (..., nc, d)
    agg_b = b_cum[..., -1, :]
    carry0 = (jnp.zeros(batch_shape + (d,), b.dtype) if h0 is None
              else h0.astype(b.dtype))

    def step(h, ab):
        a_k, b_k = ab
        return a_k * h + b_k, h   # emit carry *before* applying this chunk

    agg_a_t = jnp.moveaxis(agg_a, -2, 0)
    agg_b_t = jnp.moveaxis(agg_b, -2, 0)
    _, carries = lax.scan(step, carry0, (agg_a_t, agg_b_t))
    carries = jnp.moveaxis(carries, 0, -2)          # (..., nc, d)

    h = b_cum + a_cum * carries[..., :, None, :]
    h = h.reshape(batch_shape + (nc * chunk, d))[..., :t, :]
    return jnp.moveaxis(h, -2, axis)


# ---------------------------------------------------------------------------
# Sequence-parallel scan (shard_map body)
# ---------------------------------------------------------------------------

def scan_sequence_parallel(a: Array, b: Array, axis_name: str,
                           h0: Optional[Array] = None,
                           axis: int = -2) -> Array:
    """Scan whose time axis is sharded across mesh axis ``axis_name``.

    Must be called inside shard_map with ``a``/``b`` carrying the *local*
    sequence shard.  Strategy:

      1. local inclusive scan  -> (A_loc, B_loc)
      2. all-gather each device's aggregate (last element) -- 2*D floats
         per device, the only collective
      3. every device combines the aggregates of the devices before it to
         obtain its incoming carry (exclusive prefix over n_dev elements)
      4. fix-up: h = B_loc + A_loc * carry_in
    """
    a_cum, b_cum = scan_associative_with_aggregate(a, b, axis=axis)
    agg_a = jnp.take(a_cum, jnp.array([-1]), axis=axis)
    agg_b = jnp.take(b_cum, jnp.array([-1]), axis=axis)
    # gather aggregates from every device: leading axis n_dev
    all_a = lax.all_gather(agg_a, axis_name)     # (n_dev, ..., 1, D)
    all_b = lax.all_gather(agg_b, axis_name)
    n_dev = all_a.shape[0]
    idx = lax.axis_index(axis_name)

    # derive the zero carry from varying data so shard_map's VMA typing
    # sees a consistent carry type through the scan
    carry0 = agg_b * 0
    if h0 is not None:
        carry0 = carry0 + jnp.expand_dims(h0, axis).astype(b.dtype)

    def step(h, ab):
        a_k, b_k = ab
        return a_k * h + b_k, h

    _, carries = lax.scan(step, carry0, (all_a, all_b))   # (n_dev, ..., 1, D)
    carry_in = jnp.take(carries, idx, axis=0)
    return b_cum + a_cum * carry_in


# ---------------------------------------------------------------------------
# Strategy dispatch
# ---------------------------------------------------------------------------

# "fused" = the Pallas fused projection+scan kernels (minGRU/minLSTM layers
# only; resolved by the cell's ``parallel``, not by ``scan_linear``).
# "auto" = backend-aware default: the fused Pallas path everywhere -- real
# TPU kernels on TPU, interpret-mode (bit-compatible semantics, CPU
# execution) elsewhere, via kernels/*/ops.DEFAULT_INTERPRET.
STRATEGIES = ("associative", "sequential", "chunked", "pallas", "fused",
              "auto")


def resolve_strategy(strategy: str) -> str:
    """Resolve the config-level ``scan_strategy`` to a concrete strategy."""
    if strategy == "auto":
        return "fused"
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown scan strategy {strategy!r}")
    return strategy


def scan_linear(a: Array, b: Array, h0: Optional[Array] = None,
                axis: int = -2, strategy: str = "associative",
                chunk: int = 256) -> Array:
    """Unified entry point used by the model layers."""
    if strategy == "associative":
        return scan_associative(a, b, h0, axis=axis)
    if strategy == "sequential":
        return scan_sequential(a, b, h0, axis=axis)
    if strategy == "chunked":
        return scan_chunked(a, b, h0, chunk=chunk, axis=axis)
    if strategy == "pallas":
        # the TPU kernel path (interpret mode on CPU); time axis must be -2
        from repro.kernels.scan import ops as scan_kernel_ops
        if axis not in (-2, a.ndim - 2):
            raise ValueError("pallas scan requires time axis -2")
        return scan_kernel_ops.linear_scan_auto(a, b, h0)
    raise ValueError(f"unknown scan strategy {strategy!r}")
