"""minLSTM (the paper's Section 3.2).

    f_t  = sigma(Linear_dh(x_t))
    i_t  = sigma(Linear_dh(x_t))
    h~_t = Linear_dh(x_t)           (vanilla) | g(Linear_dh(x_t)) (log mode)
    f'_t, i'_t = f/(f+i), i/(f+i)   (length-independence normalization)
    h_t  = f'_t * h_{t-1} + i'_t * h~_t

``normalize=False`` gives the unnormalized variant (time-dependent scale,
discussed in Section 3.2.3 footnote 2).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core import scan as scan_lib

Array = jax.Array


def init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32,
         use_bias: bool = True, forget_bias: float = 0.0):
    """forget_bias > 0 reproduces the paper's Fig. 5 retention init."""
    kf, ki, kh = jax.random.split(key, 3)
    p = {
        "wf": nn.dense_init(kf, d_in, d_hidden, use_bias=use_bias, dtype=dtype,
                            bias_init=forget_bias),
        "wi": nn.dense_init(ki, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
        "wh": nn.dense_init(kh, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
    }
    return p


def n_params(d_in: int, d_hidden: int, use_bias: bool = False) -> int:
    return 3 * d_in * d_hidden + (3 * d_hidden if use_bias else 0)


def _normalized_log_gates(kf: Array, ki: Array):
    """Appendix B Algorithm 8: log f', log i' from gate pre-activations."""
    diff = jax.nn.softplus(-kf) - jax.nn.softplus(-ki)
    log_f = -jax.nn.softplus(diff)
    log_i = -jax.nn.softplus(-diff)
    return log_f, log_i


def parallel(params, x: Array, h0: Optional[Array] = None, *,
             mode: str = "log", normalize: bool = True,
             scan_strategy: str = "associative", compute_dtype=None) -> Array:
    kf = nn.dense_apply(params["wf"], x, compute_dtype)
    ki = nn.dense_apply(params["wi"], x, compute_dtype)
    v = nn.dense_apply(params["wh"], x, compute_dtype)

    if mode == "log":
        kf32, ki32 = kf.astype(jnp.float32), ki.astype(jnp.float32)
        if normalize:
            log_f, log_i = _normalized_log_gates(kf32, ki32)
        else:
            log_f = nn.log_sigmoid(kf32)
            log_i = nn.log_sigmoid(ki32)
        log_h_tilde = nn.log_g(v.astype(jnp.float32))
        log_h0 = None if h0 is None else jnp.log(h0.astype(jnp.float32))
        h = scan_lib.scan_log_space(log_f, log_i + log_h_tilde, log_h0)
        return h.astype(x.dtype if compute_dtype is None else compute_dtype)
    elif mode == "linear":
        f = jax.nn.sigmoid(kf)
        i = jax.nn.sigmoid(ki)
        if normalize:
            denom = f + i
            f, i = f / denom, i / denom
        return scan_lib.scan_linear(f, i * v, h0, strategy=scan_strategy)
    raise ValueError(f"unknown minLSTM mode {mode!r}")


def gates(params, x: Array, *, mode: str = "log", normalize: bool = True,
          compute_dtype=None):
    """(a, b) recurrence inputs for external scans (Pallas / seq-parallel)."""
    kf = nn.dense_apply(params["wf"], x, compute_dtype)
    ki = nn.dense_apply(params["wi"], x, compute_dtype)
    v = nn.dense_apply(params["wh"], x, compute_dtype)
    f = jax.nn.sigmoid(kf)
    i = jax.nn.sigmoid(ki)
    if normalize:
        denom = f + i
        f, i = f / denom, i / denom
    h_tilde = nn.g(v) if mode == "log" else v
    return f, i * h_tilde


def step(params, x_t: Array, h_prev: Array, *, mode: str = "log",
         normalize: bool = True, compute_dtype=None) -> Array:
    f = jax.nn.sigmoid(nn.dense_apply(params["wf"], x_t, compute_dtype))
    i = jax.nn.sigmoid(nn.dense_apply(params["wi"], x_t, compute_dtype))
    v = nn.dense_apply(params["wh"], x_t, compute_dtype)
    h_tilde = nn.g(v) if mode == "log" else v
    if normalize:
        denom = f + i
        f, i = f / denom, i / denom
    return f * h_prev + i * h_tilde
