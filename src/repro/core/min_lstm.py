"""minLSTM (the paper's Section 3.2).

    f_t  = sigma(Linear_dh(x_t))
    i_t  = sigma(Linear_dh(x_t))
    h~_t = Linear_dh(x_t)           (vanilla) | g(Linear_dh(x_t)) (log mode)
    f'_t, i'_t = f/(f+i), i/(f+i)   (length-independence normalization)
    h_t  = f'_t * h_{t-1} + i'_t * h~_t

``normalize=False`` gives the unnormalized variant (time-dependent scale,
discussed in Section 3.2.3 footnote 2).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.core import scan as scan_lib

Array = jax.Array


def init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32,
         use_bias: bool = True, forget_bias: float = 0.0):
    """forget_bias > 0 reproduces the paper's Fig. 5 retention init."""
    kf, ki, kh = jax.random.split(key, 3)
    p = {
        "wf": nn.dense_init(kf, d_in, d_hidden, use_bias=use_bias, dtype=dtype,
                            bias_init=forget_bias),
        "wi": nn.dense_init(ki, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
        "wh": nn.dense_init(kh, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
    }
    return p


def n_params(d_in: int, d_hidden: int, use_bias: bool = False) -> int:
    return 3 * d_in * d_hidden + (3 * d_hidden if use_bias else 0)


def _normalized_log_gates(kf: Array, ki: Array):
    """Appendix B Algorithm 8: log f', log i' from gate pre-activations."""
    diff = jax.nn.softplus(-kf) - jax.nn.softplus(-ki)
    log_f = -jax.nn.softplus(diff)
    log_i = -jax.nn.softplus(-diff)
    return log_f, log_i


def normalized_gates(kf: Array, ki: Array):
    """Linear-space f' = f/(f+i), i' = i/(f+i), computed stably.

    Naive f/(f+i) hits 0/0 = NaN once both sigmoids underflow (pre-
    activations below ~-104 in fp32); this is Algorithm 8's log form
    exponentiated -- f' = sigmoid(-diff), i' = sigmoid(diff) -- which is
    exact and finite everywhere.  Used by the linear-mode layer path and
    by the fused Pallas kernel (forward and rematerialised backward).
    """
    diff = jax.nn.softplus(-kf) - jax.nn.softplus(-ki)
    return jax.nn.sigmoid(-diff), jax.nn.sigmoid(diff)


def parallel(params, x: Array, h0: Optional[Array] = None, *,
             mode: str = "log", normalize: bool = True,
             scan_strategy: str = "associative", compute_dtype=None) -> Array:
    """See ``min_gru.parallel`` for the scan_strategy contract; ``"auto"``/
    ``"fused"`` run the whole layer in the Pallas fused minLSTM kernel."""
    if mode not in ("log", "linear"):
        raise ValueError(f"unknown minLSTM mode {mode!r}")
    strategy = scan_lib.resolve_strategy(scan_strategy)
    if strategy == "fused":
        return _fused_parallel(params, x, h0, mode=mode, normalize=normalize,
                               compute_dtype=compute_dtype)
    kf = nn.dense_apply(params["wf"], x, compute_dtype)
    ki = nn.dense_apply(params["wi"], x, compute_dtype)
    v = nn.dense_apply(params["wh"], x, compute_dtype)

    if mode == "log":
        kf32, ki32 = kf.astype(jnp.float32), ki.astype(jnp.float32)
        if normalize:
            log_f, log_i = _normalized_log_gates(kf32, ki32)
        else:
            log_f = nn.log_sigmoid(kf32)
            log_i = nn.log_sigmoid(ki32)
        log_h_tilde = nn.log_g(v.astype(jnp.float32))
        log_h0 = None if h0 is None else jnp.log(h0.astype(jnp.float32))
        h = scan_lib.scan_log_space(log_f, log_i + log_h_tilde, log_h0,
                                    strategy=strategy)
        return h.astype(x.dtype if compute_dtype is None else compute_dtype)
    if normalize:
        f, i = normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    return scan_lib.scan_linear(f, i * v, h0, strategy=strategy)


def _fused_parallel(params, x: Array, h0: Optional[Array], *, mode: str,
                    normalize: bool, compute_dtype=None) -> Array:
    """Whole layer in one Pallas call (kernels/fused_minlstm)."""
    from repro.kernels.fused_minlstm import ops as fused_ops
    from repro.kernels.scan.ops import call_with_flat_lead
    ws = [params[k]["kernel"] for k in ("wf", "wi", "wh")]
    bs = [params[k].get("bias") for k in ("wf", "wi", "wh")]
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        ws = [w.astype(compute_dtype) for w in ws]
        bs = [None if b is None else b.astype(compute_dtype) for b in bs]
    wf, wi, wh = ws
    bf, bi, bh = bs
    if h0 is None:                          # kernel wants (B, T, D)
        return call_with_flat_lead(
            lambda xf: fused_ops.fused_minlstm(
                xf, wf, bf, wi, bi, wh, bh, mode=mode, normalize=normalize),
            (x, 2))
    return call_with_flat_lead(
        lambda xf, h0f: fused_ops.fused_minlstm(
            xf, wf, bf, wi, bi, wh, bh, h0f, mode=mode, normalize=normalize),
        (x, 2), (h0, 1))


def gates(params, x: Array, *, mode: str = "log", normalize: bool = True,
          compute_dtype=None):
    """(a, b) recurrence inputs for external scans (Pallas / seq-parallel).

    As with ``min_gru.gates``, these are linear-space inputs even for
    ``mode="log"`` -- mathematically identical to the log-space scan,
    differing only in rounding (see min_gru.gates for the bf16 caveat)."""
    kf = nn.dense_apply(params["wf"], x, compute_dtype)
    ki = nn.dense_apply(params["wi"], x, compute_dtype)
    v = nn.dense_apply(params["wh"], x, compute_dtype)
    if normalize:
        f, i = normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    h_tilde = nn.g(v) if mode == "log" else v
    return f, i * h_tilde


def step(params, x_t: Array, h_prev: Array, *, mode: str = "log",
         normalize: bool = True, compute_dtype=None,
         scan_strategy: Optional[str] = None) -> Array:
    """x_t: (..., d_in), h_prev: (..., d_hidden) -> h_t.

    ``scan_strategy="auto"``/``"fused"`` runs the whole step in the fused
    Pallas decode kernel (``kernels/decode_step``); otherwise pure jnp.
    Both paths normalise via the stable ``normalized_gates`` form --
    the naive f/(f+i) quotient NaNs once both sigmoids underflow.
    """
    if scan_strategy is not None and \
            scan_lib.resolve_strategy(scan_strategy) == "fused":
        return _fused_step(params, x_t, h_prev, mode=mode,
                           normalize=normalize, compute_dtype=compute_dtype)
    kf = nn.dense_apply(params["wf"], x_t, compute_dtype)
    ki = nn.dense_apply(params["wi"], x_t, compute_dtype)
    v = nn.dense_apply(params["wh"], x_t, compute_dtype)
    h_tilde = nn.g(v) if mode == "log" else v
    if normalize:
        f, i = normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    return f * h_prev + i * h_tilde


def _fused_step_args(params, x: Array, compute_dtype):
    """Shared fused-path prep: extract wf/bf/wi/bi/wh/bh and apply the
    compute-dtype cast (to x and every weight/bias) in one place for the
    step and chunk dispatchers."""
    ws = [params[k]["kernel"] for k in ("wf", "wi", "wh")]
    bs = [params[k].get("bias") for k in ("wf", "wi", "wh")]
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        ws = [w.astype(compute_dtype) for w in ws]
        bs = [None if b is None else b.astype(compute_dtype) for b in bs]
    return (x,) + tuple(ws) + tuple(bs)


def _fused_step(params, x_t: Array, h_prev: Array, *, mode: str,
                normalize: bool, compute_dtype=None) -> Array:
    """Whole cell step in one Pallas call (kernels/decode_step)."""
    from repro.kernels.decode_step import ops as step_ops
    x_t, wf, wi, wh, bf, bi, bh = _fused_step_args(params, x_t,
                                                   compute_dtype)
    return step_ops.fused_minlstm_step(x_t, wf, bf, wi, bi, wh, bh, h_prev,
                                       mode=mode, normalize=normalize)


def step_chunk(params, x: Array, h_prev: Array, valid: Array, *,
               mode: str = "log", normalize: bool = True,
               compute_dtype=None,
               scan_strategy: Optional[str] = None) -> Array:
    """Packed varlen decode chunk; contract as ``min_gru.step_chunk``
    (``"auto"``/``"fused"`` -> one Pallas chunk call with the weights
    streamed once, else the pure-jnp masked sequential reference)."""
    if scan_strategy is not None and \
            scan_lib.resolve_strategy(scan_strategy) == "fused":
        from repro.kernels.decode_step import ops as step_ops
        x, wf, wi, wh, bf, bi, bh = _fused_step_args(params, x,
                                                     compute_dtype)
        return step_ops.fused_minlstm_chunk(x, wf, bf, wi, bi, wh, bh,
                                            h_prev, valid, mode=mode,
                                            normalize=normalize)

    def body(h, inp):
        x_t, t = inp
        h_new = step(params, x_t, h, mode=mode, normalize=normalize,
                     compute_dtype=compute_dtype)
        h = jnp.where((t < valid)[..., None], h_new, h).astype(h.dtype)
        return h, h

    _, hs = jax.lax.scan(
        body, h_prev,
        (jnp.moveaxis(x, -2, 0), jnp.arange(x.shape[-2])))
    return jnp.moveaxis(hs, 0, -2)
