"""The paper's minRNN residual block (Appendix C.2).

Pre-norm residual structure with the paper's task-dependent components:

    x = x + Down( minRNN( [Conv4]( Norm(x) ) ) )          # mixer sub-block
    x = x + MLP( Norm(x) )                                # optional

``expansion`` is the paper's state-expansion factor alpha (d_h = alpha*d_x)
with a down-projection back to d_model.  A sequential ``step`` form carries
(conv window, h) state for decode.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import min_gru, min_lstm, nn
from repro.core import scan as scan_lib
from repro.distributed import context as mesh_ctx

Array = jax.Array


def fuse_block_tier(cfg: "MinRNNBlockConfig", params=None,
                    scan_strategy: Optional[str] = None) -> str:
    """Which decode kernel tier this block will actually run.

    Returns ``"block-fused"`` (whole block in one pallas_call,
    kernels/block_step), ``"cell-fused"`` (cell-only kernel,
    kernels/decode_step) or ``"unfused"`` (pure-jnp cell step).  The
    block tier requires: ``fuse_block`` not "off", an rmsnorm block (the
    kernel pins the rmsnorm arithmetic), and -- when ``params`` are
    given inside a ``serving_tp`` shard_map -- unsliced row-parallel
    kernels: a TP-sharded layer's down/mlp_out products need a psum
    over the model axis, which must stay outside the kernel, so sharded
    layers fall back to the cell tier.  The serving engine surfaces
    this in its stats line."""
    strategy = scan_strategy if scan_strategy is not None \
        else cfg.scan_strategy
    if scan_lib.resolve_strategy(strategy) != "fused":
        return "unfused"
    if cfg.fuse_block == "off" or cfg.norm != "rmsnorm":
        return "cell-fused"
    if params is not None and mesh_ctx.serving_tp_axis() is not None:
        if params["down"]["kernel"].shape[0] != cfg.d_hidden:
            return "cell-fused"
        if cfg.use_mlp and \
                params["mlp_out"]["kernel"].shape[0] != cfg.d_mlp:
            return "cell-fused"
    return "block-fused"


def _row_parallel_apply(p, x: Array, compute_dtype, full_in_dim: int
                        ) -> Array:
    """``dense_apply`` that understands tensor-parallel serving.

    Inside a ``serving_tp`` shard_map the col-parallel projections
    (gates, ``mlp_in``) hand each model shard a ``d_hidden/model`` (resp.
    ``d_ff/model``) column block, so the row-parallel projections that
    contract over that dim (``down``, ``mlp_out``) see a *sliced* kernel:
    ``kernel.shape[0] < full_in_dim``.  Their local product is then a
    partial sum that must be ``psum``'d over the model axis BEFORE the
    (replicated) bias is added -- ``dense_apply`` would add the bias into
    every partial.  Outside a shard_map, or when the kernel is unsliced
    (pure DP; a replicated draft model riding a TP trace; non-divisible
    dims that ``sharding.spec_for_param`` left replicated), this is
    exactly ``dense_apply`` -- the shape check keeps partially sharded
    layouts self-consistent without any configuration plumbing."""
    axis = mesh_ctx.serving_tp_axis()
    k = p["kernel"]
    if axis is None or k.shape[0] == full_in_dim:
        return nn.dense_apply(p, x, compute_dtype)
    if compute_dtype is not None:
        k = k.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = jax.lax.psum(x @ k, axis)
    if "bias" in p:
        b = p["bias"]
        if compute_dtype is not None:
            b = b.astype(compute_dtype)
        y = y + b
    return y


@dataclass(frozen=True)
class MinRNNBlockConfig:
    d_model: int
    cell: str = "mingru"            # mingru | minlstm
    expansion: float = 1.0          # alpha
    use_conv: bool = False
    conv_kernel: int = 4
    use_mlp: bool = False
    mlp_factor: float = 4.0
    mode: str = "log"               # log | linear scan parameterization
    norm: str = "rmsnorm"
    dropout: float = 0.0
    # core.scan.STRATEGIES; "auto" = fused Pallas kernels (real on TPU,
    # interpret parity elsewhere).  Callers of ``apply`` may override.
    scan_strategy: str = "auto"
    # whole-block decode fusion (kernels/block_step): "auto"/"on" run
    # norm -> conv -> cell -> down -> MLP as ONE pallas_call per step /
    # chunk when the scan strategy resolves to "fused"; "off" keeps the
    # cell-only kernel.  Falls back to the cell tier for non-rmsnorm
    # blocks and for tensor-parallel-sliced layers (the TP psum must
    # stay outside the kernel).  ``block_dh`` = Dh feature tile on real
    # backends (0 = kernel default; autotune plans set it).
    fuse_block: str = "auto"        # auto | on | off
    block_dh: int = 0

    @property
    def d_hidden(self) -> int:
        return int(self.d_model * self.expansion)

    @property
    def d_mlp(self) -> int:
        return int(self.d_model * self.mlp_factor)


_CELLS = {"mingru": min_gru, "minlstm": min_lstm}


def init(key, cfg: MinRNNBlockConfig, *, dtype=jnp.float32):
    keys = jax.random.split(key, 5)
    cell = _CELLS[cfg.cell]
    p = {
        "norm_rnn": nn.norm_init(cfg.norm, cfg.d_model, dtype),
        "rnn": cell.init(keys[0], cfg.d_model, cfg.d_hidden, dtype=dtype),
        "down": nn.dense_init(keys[1], cfg.d_hidden, cfg.d_model,
                              use_bias=False, dtype=dtype),
    }
    if cfg.use_conv:
        p["conv"] = nn.causal_conv_init(keys[2], cfg.d_model,
                                        cfg.conv_kernel, dtype)
    if cfg.use_mlp:
        p["norm_mlp"] = nn.norm_init(cfg.norm, cfg.d_model, dtype)
        p["mlp_in"] = nn.dense_init(keys[3], cfg.d_model, cfg.d_mlp,
                                    dtype=dtype)
        p["mlp_out"] = nn.dense_init(keys[4], cfg.d_mlp, cfg.d_model,
                                     dtype=dtype)
    return p


def apply(params, cfg: MinRNNBlockConfig, x: Array, *,
          h0: Optional[Array] = None, state0: Optional[dict] = None,
          lengths: Optional[Array] = None, compute_dtype=None,
          scan_strategy: Optional[str] = None, dropout_rng=None,
          deterministic: bool = True, return_state: bool = False):
    """x: (..., T, d_model) parallel (training / prefill) form.

    With ``return_state`` also returns the decode-ready state (final h and
    conv window) so prefill can hand off to sequential decoding.

    ``lengths`` (B,) supports right-padded variable-length batches: the
    returned state is taken at each row's true terminal position (the
    recurrence is causal, so padded positions never influence it).
    ``state0`` (a previous ``return_state`` dict) resumes the block from a
    carried (h, conv window) -- the chunked-prefill path.

    ``scan_strategy`` overrides ``cfg.scan_strategy`` (default ``None`` =
    use the config's; "auto" = fused Pallas kernels, with carried h0 /
    lengths composing exactly because the fused scan is causal and
    chunk-associative) and is forwarded to the cell (see
    min_gru.parallel) -- so the classifier/DT heads and every other
    trunk over these blocks hit the fused path by default too.
    """
    if scan_strategy is None:
        scan_strategy = cfg.scan_strategy
    cell = _CELLS[cfg.cell]
    y = nn.norm_apply(cfg.norm, params["norm_rnn"], x)
    state = {}
    if state0 is not None:
        h0 = state0["h"]
    conv0 = state0.get("conv") if (state0 is not None and cfg.use_conv) \
        else None
    if cfg.use_conv:
        if return_state:
            width = cfg.conv_kernel - 1
            if lengths is not None or conv0 is not None:
                lens = lengths if lengths is not None \
                    else jnp.full(y.shape[:1], y.shape[-2], jnp.int32)
                state["conv"] = nn.gather_conv_window(y, lens, width,
                                                      prefix=conv0)
            else:
                pad = max(width - y.shape[-2], 0)
                win = y[..., -width:, :]
                if pad:
                    win = jnp.concatenate(
                        [jnp.zeros(y.shape[:-2] + (pad, y.shape[-1]),
                                   y.dtype), win], axis=-2)
                state["conv"] = win
        y = nn.causal_conv_apply(params["conv"], y, prefix=conv0)
    h = cell.parallel(params["rnn"], y, h0, mode=cfg.mode,
                      scan_strategy=scan_strategy,
                      compute_dtype=compute_dtype)
    if return_state:
        state["h"] = nn.gather_last(h, lengths) if lengths is not None \
            else h[..., -1, :]
    y = nn.dense_apply(params["down"], h, compute_dtype)
    y = _dropout(y, cfg.dropout, dropout_rng, deterministic)
    x = x + y
    if cfg.use_mlp:
        y = nn.norm_apply(cfg.norm, params["norm_mlp"], x)
        y = nn.gelu(nn.dense_apply(params["mlp_in"], y, compute_dtype))
        y = nn.dense_apply(params["mlp_out"], y, compute_dtype)
        y = _dropout(y, cfg.dropout, dropout_rng, deterministic)
        x = x + y
    if return_state:
        return x, state
    return x


def init_state(cfg: MinRNNBlockConfig, batch_shape: Tuple[int, ...],
               dtype=jnp.float32):
    """Decode-time carried state for one block."""
    state = {"h": jnp.zeros(batch_shape + (cfg.d_hidden,), dtype)}
    if cfg.use_conv:
        state["conv"] = jnp.zeros(
            batch_shape + (cfg.conv_kernel - 1, cfg.d_model), dtype)
    return state


def step(params, cfg: MinRNNBlockConfig, x_t: Array, state, *,
         compute_dtype=None, scan_strategy: Optional[str] = None):
    """Single-token decode. x_t: (..., d_model).

    ``scan_strategy`` defaults to ``cfg.scan_strategy`` (``"auto"`` = the
    fused Pallas decode-step kernel for the cell, ``kernels/decode_step``;
    real kernel on TPU, interpret parity elsewhere).  Pass e.g.
    ``"sequential"`` to force the pure-jnp cell step (the parity oracle).
    Norm / conv window / down-projection / MLP stay in XLA either way.

    This is the serving engine's only model entry point: ``lm.superstep``
    drives both prompt consumption (teacher-forced) and decode (sampled)
    through this step for every slot in the batch, so prefill and decode
    share one code path and one kernel.
    """
    if scan_strategy is None:
        scan_strategy = cfg.scan_strategy
    if fuse_block_tier(cfg, params, scan_strategy) == "block-fused":
        from repro.kernels.block_step import ops as block_ops
        return block_ops.fused_block_step(
            params, x_t, state, cell=cfg.cell, mode=cfg.mode,
            use_conv=cfg.use_conv, use_mlp=cfg.use_mlp,
            compute_dtype=compute_dtype, block_dh=cfg.block_dh)
    cell = _CELLS[cfg.cell]
    y = nn.norm_apply(cfg.norm, params["norm_rnn"], x_t)
    new_state = dict(state)
    if cfg.use_conv:
        y, new_state["conv"] = nn.causal_conv_step(params["conv"], y,
                                                   state["conv"])
    h = cell.step(params["rnn"], y, state["h"], mode=cfg.mode,
                  compute_dtype=compute_dtype, scan_strategy=scan_strategy)
    new_state["h"] = h
    y = _row_parallel_apply(params["down"], h, compute_dtype, cfg.d_hidden)
    x_t = x_t + y
    if cfg.use_mlp:
        y = nn.norm_apply(cfg.norm, params["norm_mlp"], x_t)
        y = nn.gelu(nn.dense_apply(params["mlp_in"], y, compute_dtype))
        y = _row_parallel_apply(params["mlp_out"], y, compute_dtype,
                                cfg.d_mlp)
        x_t = x_t + y
    return x_t, new_state


def _conv_chunk(p, y, window, valid, *, return_windows: bool = False):
    """Varlen chunked causal conv: a ``lax.scan`` of ``causal_conv_step``
    over the chunk axis -- the same per-token einsum as single-token
    decode (bit-exact where ``causal_conv_apply``'s unrolled slide-add
    schedule is not), with row b's carried window frozen once ``t >=
    valid[b]``.  y: (B, C, D), window: (B, K-1, D), valid: (B,) int32.

    ``return_windows`` additionally stacks the carried window *after*
    every position -- (B, C, K-1, D), frozen rows re-emitting their
    final window -- so speculative verify can roll the conv state back
    to any committed position with one gather (no recompute)."""

    def body(win, inp):
        y_t, t = inp
        out, win_new = nn.causal_conv_step(p, y_t, win)
        win = jnp.where((t < valid)[:, None, None], win_new, win)
        return win, (out, win if return_windows else None)

    win, (outs, wins) = jax.lax.scan(
        body, window, (jnp.moveaxis(y, 1, 0), jnp.arange(y.shape[1])))
    outs = jnp.moveaxis(outs, 0, 1)
    if return_windows:
        return outs, win, jnp.moveaxis(wins, 0, 1)
    return outs, win


def step_chunk(params, cfg: MinRNNBlockConfig, x: Array, state, valid, *,
               compute_dtype=None, scan_strategy: Optional[str] = None,
               return_positions: bool = False):
    """Packed varlen decode chunk of one block.  x: (B, C, d_model),
    valid: (B,) int32 in [1, C] -> ((B, C, d_model), new state).

    The serving superstep's prompt-packing form of :func:`step`: row b
    consumes its first ``valid[b]`` positions with per-token arithmetic
    identical to ``valid[b]`` sequential ``step`` calls (norm / conv /
    down / MLP are causal or positionwise, and the cell rides
    ``step_chunk``'s masked sequential recurrence -- one weight stream
    per chunk under the fused strategy), and its carried (conv window,
    h) state freezes at ``valid[b]``.  Positions >= ``valid[b]`` hold
    garbage the caller must mask (the superstep reads position
    ``valid[b]-1`` only).

    ``return_positions`` also returns the carried state after EVERY
    position -- ``{"h": (B, C, d_hidden)[, "conv": (B, C, K-1,
    d_model)]}`` -- the speculative-decoding rollback primitive: the
    cell chunk already emits its per-position states (that is what the
    varlen chunk kernels compute), so restoring the prefix state at the
    first rejected draft is a single O(d_hidden) gather per slot."""
    if scan_strategy is None:
        scan_strategy = cfg.scan_strategy
    if fuse_block_tier(cfg, params, scan_strategy) == "block-fused":
        from repro.kernels.block_step import ops as block_ops
        return block_ops.fused_block_chunk(
            params, x, state, valid, cell=cfg.cell, mode=cfg.mode,
            use_conv=cfg.use_conv, use_mlp=cfg.use_mlp,
            compute_dtype=compute_dtype, block_dh=cfg.block_dh,
            return_positions=return_positions)
    cell = _CELLS[cfg.cell]
    y = nn.norm_apply(cfg.norm, params["norm_rnn"], x)
    new_state = dict(state)
    pos_states = {}
    if cfg.use_conv:
        if return_positions:
            y, new_state["conv"], pos_states["conv"] = _conv_chunk(
                params["conv"], y, state["conv"], valid,
                return_windows=True)
        else:
            y, new_state["conv"] = _conv_chunk(params["conv"], y,
                                               state["conv"], valid)
    hs = cell.step_chunk(params["rnn"], y, state["h"], valid,
                         mode=cfg.mode, compute_dtype=compute_dtype,
                         scan_strategy=scan_strategy)
    new_state["h"] = hs[:, -1]          # frozen rows: == hs[:, valid-1]
    pos_states["h"] = hs
    y = _row_parallel_apply(params["down"], hs, compute_dtype, cfg.d_hidden)
    x = x + y
    if cfg.use_mlp:
        y = nn.norm_apply(cfg.norm, params["norm_mlp"], x)
        y = nn.gelu(nn.dense_apply(params["mlp_in"], y, compute_dtype))
        y = _row_parallel_apply(params["mlp_out"], y, compute_dtype,
                                cfg.d_mlp)
        x = x + y
    if return_positions:
        return x, new_state, pos_states
    return x, new_state


def _dropout(x, rate, rng, deterministic):
    if deterministic or rate == 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)
