"""Traditional LSTM (Hochreiter & Schmidhuber, 1997) -- sequential baseline.

    f_t = sigma(Linear([x_t, h_{t-1}]))     i_t = sigma(Linear([x_t, h_{t-1}]))
    o_t = sigma(Linear([x_t, h_{t-1}]))     c~_t = tanh(Linear([x_t, h_{t-1}]))
    c_t = f_t * c_{t-1} + i_t * c~_t        h_t = o_t * tanh(c_t)

Fused 4-gate weight layout; O(4*dh*(dx+dh)) parameters as in the paper.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import nn

Array = jax.Array


def init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32,
         use_bias: bool = True):
    kx, kh = jax.random.split(key)
    return {
        "wx": nn.dense_init(kx, d_in, 4 * d_hidden, use_bias=use_bias,
                            dtype=dtype),
        "wh": nn.dense_init(kh, d_hidden, 4 * d_hidden, use_bias=False,
                            dtype=dtype),
    }


def n_params(d_in: int, d_hidden: int, use_bias: bool = False) -> int:
    return 4 * d_hidden * (d_in + d_hidden) + (4 * d_hidden if use_bias else 0)


def step(params, x_t: Array, state: Tuple[Array, Array],
         compute_dtype=None) -> Tuple[Array, Array]:
    h_prev, c_prev = state
    gx = nn.dense_apply(params["wx"], x_t, compute_dtype)
    gh = h_prev @ params["wh"]["kernel"].astype(h_prev.dtype)
    fx, ix, ox, cx = jnp.split(gx, 4, axis=-1)
    fh, ih, oh, ch = jnp.split(gh, 4, axis=-1)
    f = jax.nn.sigmoid(fx + fh)
    i = jax.nn.sigmoid(ix + ih)
    o = jax.nn.sigmoid(ox + oh)
    c_tilde = jnp.tanh(cx + ch)
    c = f * c_prev + i * c_tilde
    h = o * jnp.tanh(c)
    return h, c


def forward(params, x: Array, state0=None, compute_dtype=None) -> Array:
    dh = params["wh"]["kernel"].shape[0]
    if state0 is None:
        z = jnp.zeros(x.shape[:-2] + (dh,), x.dtype)
        state0 = (z, z)
    xs = jnp.moveaxis(x, -2, 0)

    def body(state, x_t):
        h, c = step(params, x_t, state, compute_dtype)
        return (h, c), h

    _, hs = lax.scan(body, state0, xs)
    return jnp.moveaxis(hs, 0, -2)
