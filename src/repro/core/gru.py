"""Traditional GRU (Cho et al., 2014) -- the paper's sequential baseline.

    z_t = sigma(Linear([x_t, h_{t-1}]))
    r_t = sigma(Linear([x_t, h_{t-1}]))
    h~_t = tanh(Linear([x_t, r_t * h_{t-1}]))
    h_t = (1 - z_t) * h_{t-1} + z_t * h~_t

Sequential-only (BPTT): used for the Fig. 1 runtime comparison and for the
param-count ratio checks.  Fused 3-gate weight layout.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import nn

Array = jax.Array


def init(key, d_in: int, d_hidden: int, *, dtype=jnp.float32,
         use_bias: bool = True):
    kx, kh = jax.random.split(key)
    p = {
        "wx": nn.dense_init(kx, d_in, 3 * d_hidden, use_bias=use_bias,
                            dtype=dtype),
        "wh": nn.dense_init(kh, d_hidden, 3 * d_hidden, use_bias=False,
                            dtype=dtype),
    }
    return p


def n_params(d_in: int, d_hidden: int, use_bias: bool = False) -> int:
    return 3 * d_hidden * (d_in + d_hidden) + (3 * d_hidden if use_bias else 0)


def step(params, x_t: Array, h_prev: Array, compute_dtype=None) -> Array:
    dh = h_prev.shape[-1]
    gx = nn.dense_apply(params["wx"], x_t, compute_dtype)
    gh = h_prev @ params["wh"]["kernel"].astype(h_prev.dtype)
    zx, rx, hx = jnp.split(gx, 3, axis=-1)
    zh, rh, hh = jnp.split(gh, 3, axis=-1)
    z = jax.nn.sigmoid(zx + zh)
    r = jax.nn.sigmoid(rx + rh)
    h_tilde = jnp.tanh(hx + r * hh)
    return (1.0 - z) * h_prev + z * h_tilde


def forward(params, x: Array, h0: Optional[Array] = None,
            compute_dtype=None) -> Array:
    """x: (..., T, d_in) -> (..., T, d_hidden), sequential lax.scan (BPTT)."""
    dh = params["wh"]["kernel"].shape[0]
    if h0 is None:
        h0 = jnp.zeros(x.shape[:-2] + (dh,), x.dtype)
    xs = jnp.moveaxis(x, -2, 0)

    def body(h, x_t):
        h = step(params, x_t, h, compute_dtype)
        return h, h

    _, hs = lax.scan(body, h0, xs)
    return jnp.moveaxis(hs, 0, -2)
