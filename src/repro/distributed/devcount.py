"""Pre-jax device-count bootstrap for the serving launchers.

XLA pins the host-platform device count the moment its backend
initialises, and merely importing the repro model stack triggers that
(the Pallas kernel modules consult ``jax.default_backend()`` at import
time).  So a launcher that wants an N-device CPU mesh must set
``XLA_FLAGS`` BEFORE its own imports run -- too early for
``serve_mesh.ensure_host_devices``, whose module imports jax.  This
module is deliberately jax-free: entry points import it first, scan
their argv for ``--mesh DxM`` and export the flag, then proceed with
normal imports.  ``ensure_host_devices`` still runs later as the
validating backstop (it raises actionably if the count did not take).
"""

from __future__ import annotations

import os
import re
from typing import List, Optional

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def mesh_size_from_argv(argv: List[str]) -> Optional[int]:
    """Device count implied by a ``--mesh DxM`` / ``--mesh=DxM`` arg, or
    None.  Malformed specs are left for argparse/MeshPlan to reject."""
    spec = None
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            spec = argv[i + 1]
        elif a.startswith("--mesh="):
            spec = a.split("=", 1)[1]
    if spec is None:
        return None
    m = re.fullmatch(r"(\d+)x(\d+)", spec.strip())
    return int(m.group(1)) * int(m.group(2)) if m else None


def max_mesh_size_from_shapes_argv(argv: List[str]) -> Optional[int]:
    """Largest device count implied by ``--mesh-shapes DxM [DxM ...]``
    (the bench sweep flag), or None when absent/malformed."""
    sizes = []
    i = 0
    while i < len(argv):
        a = argv[i]
        vals: List[str] = []
        if a == "--mesh-shapes":
            i += 1
            while i < len(argv) and not argv[i].startswith("-"):
                vals.append(argv[i])
                i += 1
        elif a.startswith("--mesh-shapes="):
            vals = a.split("=", 1)[1].split()
            i += 1
        else:
            i += 1
            continue
        for v in vals:
            m = re.fullmatch(r"(\d+)x(\d+)", v.strip())
            if m:
                sizes.append(int(m.group(1)) * int(m.group(2)))
    return max(sizes) if sizes else None


def force_host_devices(n: Optional[int]) -> None:
    """Export the virtual-device flag for ``n`` devices (no-op for
    None / <=1 / an XLA_FLAGS that already forces a count)."""
    if n is None or n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + " " if flags else "") + f"{_FORCE_FLAG}={n}"


def force_host_devices_from_argv(argv: Optional[List[str]] = None) -> None:
    """Export ``--xla_force_host_platform_device_count=N`` for a
    ``--mesh`` found in ``argv`` (default ``sys.argv[1:]``).  Must run
    before anything imports the model stack.  A count already forced in
    ``XLA_FLAGS`` is respected untouched."""
    if argv is None:
        import sys
        argv = sys.argv[1:]
    sizes = [n for n in (mesh_size_from_argv(argv),
                         max_mesh_size_from_shapes_argv(argv))
             if n is not None]
    force_host_devices(max(sizes) if sizes else None)
