"""Sharding rules: parameter-path regexes -> logical axes -> mesh axes.

Parallelism recipe (DESIGN.md §4):

  * ``dp``     batch axis           -> ("pod", "data")
  * ``fsdp``   weight input dims    -> ("data",)   ZeRO-3 within a pod
  * ``tp``     heads / ffn / vocab  -> ("model",)  Megatron tensor parallel
  * ``expert`` MoE expert dim       -> ("model",)  expert parallelism
  * ``sp``     long-context seq dim -> ("data",)   sequence parallel

Multi-pod keeps params replicated across ``pod`` (FSDP gathers stay on ICI;
only gradient all-reduce crosses DCN), which is the standard 1000+-node
topology-aware layout.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical -> physical mapping
# ---------------------------------------------------------------------------

def logical_mapping(mesh: Mesh, pure_dp: bool = False
                    ) -> Dict[str, Tuple[str, ...]]:
    """pure_dp: small-model layout -- every mesh axis is data parallelism,
    weights replicated (the right production answer when the model fits on
    one chip; EXPERIMENTS.md §Perf, mingru-lm hillclimb)."""
    has_pod = "pod" in mesh.axis_names
    if pure_dp:
        axes = ("pod", "data", "model") if has_pod else ("data", "model")
        return {"dp": axes, "fsdp": (), "tp": (), "expert": (), "sp": ()}
    return {
        "dp": ("pod", "data") if has_pod else ("data",),
        "fsdp": ("data",),
        "tp": ("model",),
        "expert": ("model",),
        "sp": ("data",),
    }


# ---------------------------------------------------------------------------
# Parameter rules (first match wins; dims given WITHOUT the stacked-layer
# leading axis -- it is auto-prepended for scanned-layer params)
# ---------------------------------------------------------------------------

PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # embeddings: vocab-parallel (Megatron). The contracting d_model dim is
    # deliberately NOT fsdp-sharded: sharding it makes XLA all-reduce the
    # (B,S,V)-sized partial logits over `data` (~60 GB/step/device measured
    # on whisper train_4k); vocab-sharded tables keep the loss collective
    # down to a (B,S) logsumexp psum over `model`.
    (r"embed/table$", ("tp", None)),
    (r"unembed/kernel$", (None, "tp")),
    (r"(patch_proj|frame_proj)/kernel$", (None, "tp")),
    (r"(enc_pos|dec_pos)/table$", (None, None)),
    # MoE experts (E, d_in, d_out)
    (r"(gate_w|up_w)/kernel$", ("expert", "fsdp", None)),
    (r"down_w/kernel$", ("expert", None, "fsdp")),
    (r"router/kernel$", (None, None)),
    # MLA
    (r"wq_a/kernel$", ("fsdp", None)),
    (r"wq_b/kernel$", (None, "tp")),
    (r"wkv_a/kernel$", ("fsdp", None)),
    (r"w[kv]_b/kernel$", (None, "tp")),
    # attention / minRNN cell / generic projections
    (r"(wq|wk|wv)/kernel$", ("fsdp", "tp")),
    (r"wo/kernel$", ("tp", "fsdp")),
    (r"rnn/w[zhfi]/kernel$", ("fsdp", "tp")),
    (r"rnn/w[zhfi]/bias$", ("tp",)),
    # MLP family (paper block's mlp_in/out included)
    (r"(gate|up|mlp_in|in_proj)/kernel$", ("fsdp", "tp")),
    (r"(down|mlp_out|out_proj)/kernel$", ("tp", "fsdp")),
    (r"(gate|up|mlp_in|in_proj)/bias$", ("tp",)),
    # depthwise conv (K, D)
    (r"conv/kernel$", (None, "tp")),
    (r"conv/bias$", ("tp",)),
    # SSD per-head params
    (r"(a_log|dt_bias|d_skip)$", ("tp",)),
    # everything else (norms, small biases): replicated
    (r".*", None),
]

_STACKED_MARKERS = ("/blocks/", "/dense_blocks/", "encoder/", "decoder/")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axes_size(mesh: Mesh, phys: Tuple[str, ...]) -> int:
    n = 1
    for a in phys:
        n *= mesh.shape[a]
    return n


def spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh,
                   mapping: Dict[str, Tuple[str, ...]]) -> P:
    """First matching rule wins; any dim not divisible by its mapped mesh
    axes falls back to replicated (jit in_shardings require exact tiling)."""
    ndim = len(shape)
    for pattern, logical in PARAM_RULES:
        if re.search(pattern, path):
            if logical is None:
                return P()
            axes: List[Any] = [None] * ndim
            offset = ndim - len(logical)      # leading stacked-layer dims
            if offset < 0:                    # rule longer than array: skip
                continue
            for i, name in enumerate(logical):
                if name is None:
                    continue
                phys = mapping[name]
                if not phys:                  # axis disabled (pure_dp)
                    continue
                if shape[offset + i] % _axes_size(mesh, phys) != 0:
                    continue                  # non-divisible -> replicate
                axes[offset + i] = phys if len(phys) > 1 else phys[0]
            return P(*axes)
    return P()


def params_pspecs(params_shapes, mesh: Mesh, pure_dp: bool = False):
    """params (arrays or ShapeDtypeStructs) -> matching tree of PartitionSpec."""
    mapping = logical_mapping(mesh, pure_dp)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = [spec_for_param(_path_str(path), leaf.shape, mesh, mapping)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def params_shardings(params_shapes, mesh: Mesh, pure_dp: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(params_shapes, mesh, pure_dp))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, batch: Dict[str, Any], pure_dp: bool = False):
    """Training / prefill batch: leading batch dim over dp."""
    dp = logical_mapping(mesh, pure_dp)["dp"]

    def spec(leaf):
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def cache_pspecs(cfg, mesh: Mesh, cache, batch_size: int):
    """Decode caches.

    Attention kv caches shard their LENGTH dim over ``model`` (uniform
    across GQA/MQA/MLA head counts -- softmax over a sharded length is a
    cheap all-reduce of (max, sum)); batch over dp.  Long-context bs=1
    cells additionally shard length over ``data`` (sequence parallel).
    """
    mapping = logical_mapping(mesh)
    dp = mapping["dp"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_sharded = batch_size % dp_size == 0 and batch_size >= dp_size
    bdim = dp if batch_sharded else None
    # length dim: model always; + data when batch is unsharded (long ctx)
    sdim = "model" if batch_sharded else ("data", "model")

    def _div(leaf, dim, axes):
        if axes is None:
            return None
        ax = (axes,) if isinstance(axes, str) else axes
        return axes if leaf.shape[dim] % _axes_size(mesh, ax) == 0 else None

    def spec(key, leaf):
        nd = leaf.ndim
        if key == "pos":
            return P(_div(leaf, 0, bdim))
        if key in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S, KV, hd)
            return P(None, _div(leaf, 1, bdim), _div(leaf, 2, sdim),
                     None, None)
        if key in ("ckv", "krope"):
            # (L, B, S, latent)
            return P(None, _div(leaf, 1, bdim), _div(leaf, 2, sdim), None)
        if key == "ssm":
            # (L, B, H, P, N)
            return P(None, _div(leaf, 1, bdim), _div(leaf, 2, "model"),
                     None, None)
        if key == "conv":
            # (L, B, K-1, D)
            return P(None, _div(leaf, 1, bdim), None, _div(leaf, 3, "model"))
        if key == "h":
            # (L, B, dh)
            return P(None, _div(leaf, 1, bdim), _div(leaf, 2, "model"))
        return P(*([None] * nd))

    return {k: jax.tree.map(lambda l, kk=k: spec(kk, l), v)
            for k, v in cache.items()}


def token_pspec(mesh: Mesh, batch_size: int):
    dp = logical_mapping(mesh)["dp"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    return P(dp) if batch_size % dp_size == 0 and batch_size >= dp_size \
        else P(None)
