"""Activation sharding constraints (MaxText-style logical annotations).

XLA's sharding propagation goes wrong at two recurring places: reshapes
that split a sharded fused dim into (heads, head_dim) when heads < tp, and
gathers along a sharded vocab dim.  ``constrain`` pins activations to valid
shardings (skipping any dim the mesh doesn't divide) so propagation never
invents a multi-GB collective.  No-op outside a mesh context -- single
-device tests and CPU training paths are unaffected.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import context as mesh_ctx
from repro.distributed.sharding import logical_mapping


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """logical: one of "dp" | "tp" | "sp" | None per dim of x."""
    mesh = mesh_ctx.current_mesh()
    if mesh is None:
        return x
    mapping = logical_mapping(mesh, mesh_ctx.pure_dp())
    axes = []
    for dim, name in zip(x.shape, logical):
        if name is None:
            axes.append(None)
            continue
        phys = mapping[name]
        if not phys:
            axes.append(None)
            continue
        size = 1
        for a in phys:
            size *= mesh.shape[a]
        if dim % size != 0:
            axes.append(None)
        else:
            axes.append(phys if len(phys) > 1 else phys[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*axes)))
