"""Mesh context: lets deep layers (MoE EP, sequence-parallel scan) find the
active mesh without threading it through every apply() signature."""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh

_ACTIVE_MESH: Optional[Mesh] = None
_PURE_DP: bool = False
_SERVING_TP_AXIS: Optional[str] = None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], pure_dp: bool = False):
    global _ACTIVE_MESH, _PURE_DP
    prev, prev_dp = _ACTIVE_MESH, _PURE_DP
    _ACTIVE_MESH, _PURE_DP = mesh, pure_dp
    try:
        yield mesh
    finally:
        _ACTIVE_MESH, _PURE_DP = prev, prev_dp


@contextlib.contextmanager
def serving_tp(axis: Optional[str]):
    """Mark the enclosed trace as running INSIDE a shard_map whose
    ``axis`` shards ``d_hidden``/``d_ff`` weight blocks (tensor-parallel
    serving).  Row-parallel projections (``blocks._row_parallel_apply``)
    consult :func:`serving_tp_axis` at trace time to decide whether their
    partial products need a ``psum`` over that axis.  ``None`` is inert
    (pure data parallelism / single device)."""
    global _SERVING_TP_AXIS
    prev = _SERVING_TP_AXIS
    _SERVING_TP_AXIS = axis
    try:
        yield axis
    finally:
        _SERVING_TP_AXIS = prev


def serving_tp_axis() -> Optional[str]:
    return _SERVING_TP_AXIS


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def pure_dp() -> bool:
    return _PURE_DP


def dp_axes(mesh: Mesh):
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Optional[Mesh], name: str) -> int:
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """Version-portable shard_map.

    Newer jax exposes ``jax.shard_map`` (with ``check_vma``); older releases
    only have ``jax.experimental.shard_map.shard_map`` (where the same knob
    is called ``check_rep``).  All internal callers go through here.
    """
    kw = {}
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
