"""Mesh-sharded serving: the slot pool over ``data``, gate projections
over ``model``.

The serving superstep (``lm.superstep``) is one jitted scan whose body is
purely per-slot arithmetic, which makes it trivially data-parallel: shard
every batch-leading leaf of the slot state over the ``data`` axis and run
the SAME body per shard under ``shard_map`` -- no collectives, per-row
bit-exact with the single-device engine.  Tensor parallelism composes on
top for the weight-bound regime (full config: the decode round is an HBM
weight stream, see benchmarks/engine_throughput.py): the gate / down /
MLP kernels shard their ``d_hidden`` / ``d_ff`` dim over ``model`` via
the existing ``sharding.PARAM_RULES``, each shard's fused Pallas step
kernels run on their local ``d_hidden/model`` column block, and the
row-parallel projections ``psum`` their partials per layer
(``blocks._row_parallel_apply``) -- Megatron-style, one reduction per
mixer sub-block and one per MLP.  The residual stream, norms, depthwise
conv and the (tiny, vocab=256) embedding/unembedding stay replicated per
model shard, so sampling sees full logits with NO collective at the
readout.  TP streams are argmax-equivalent, not bit-identical, to single
device: splitting the down-projection's contraction reorders the fp32
reduction, perturbing logits by ~1 ulp (documented + tested; pure DP is
bit-exact because per-row arithmetic is untouched).

Per-shard accounting: the superstep's scalar counters are emitted with a
``P("data")`` out-spec (reshaped to (1,) inside the body), so the host
receives one value per data shard and the slot-step identity can be
checked per shard AND globally (``scheduler.ShardStats``).

Caveat: the in-loop non-finite health guard reduces each model shard's
LOCAL ``h`` block; a genuine overflow confined to one shard's block
would desynchronise slot liveness across model shards.  Injected faults
(``serving/faults.py``) poison whole rows so every shard agrees; on a
fault-free trace the guard is the identity.

DP-shard failover (``serving/recovery.py`` + the ``shard_crash`` chaos
point): a "crashed" data shard stays IN the mesh -- the device topology
is fixed at backend init -- but the engine marks its contiguous row
group (:func:`shard_rows`) permanently dead (``alive=False``, never
staged), so the shard's device keeps lock-stepping empty rows (counted
as its own ``wasted_slot_steps``, keeping the per-shard slot-step
identity exact) while its drained requests re-run on the survivors.
This models losing a shard's *state*, the recoverable failure a
fixed-state RNN makes cheap; losing the device itself needs a restart
onto a smaller mesh via the engine snapshot/journal path.
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import context as mesh_ctx
from repro.distributed import sharding

_FORCE_FLAG = "--xla_force_host_platform_device_count"


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """``data`` x ``model`` serving mesh shape (the ``--mesh dxm`` flag).

    ``data`` shards the slot pool over B (throughput: d independent HBM
    weight streams each serving B/d slots); ``model`` shards ``d_hidden``
    (latency in the weight-bound regime: each chip streams 1/m of the
    gate/down/MLP bytes per round, paying a per-layer psum).
    """
    data: int = 1
    model: int = 1

    def __post_init__(self):
        if self.data < 1 or self.model < 1:
            raise ValueError(f"mesh axes must be >= 1, got "
                             f"{self.data}x{self.model}")

    @classmethod
    def parse(cls, spec) -> Optional["MeshPlan"]:
        """``None`` | ``MeshPlan`` | ``"dxm"`` string -> MeshPlan or None."""
        if spec is None or isinstance(spec, cls):
            return spec
        m = re.fullmatch(r"(\d+)x(\d+)", str(spec).strip())
        if not m:
            raise ValueError(
                f"mesh spec must look like '4x1' or '2x2' "
                f"(data x model), got {spec!r}")
        return cls(int(m.group(1)), int(m.group(2)))

    @property
    def size(self) -> int:
        return self.data * self.model

    def build(self) -> Mesh:
        devs = jax.devices()
        if len(devs) < self.size:
            raise RuntimeError(
                f"mesh {self} needs {self.size} devices but jax sees "
                f"{len(devs)}.  On CPU, force virtual devices BEFORE jax "
                f"initialises: XLA_FLAGS='{_FORCE_FLAG}={self.size}' (the "
                f"launchers do this for you via ensure_host_devices when "
                f"--mesh is passed early enough; under pytest set "
                f"REPRO_FORCE_DEVICES={self.size}).")
        return Mesh(np.asarray(devs[:self.size]).reshape(
            self.data, self.model), ("data", "model"))

    def __str__(self) -> str:
        return f"{self.data}x{self.model}"


def shard_rows(shard: int, rows_per_shard: int) -> range:
    """Contiguous slot rows owned by data shard ``shard`` (ownership is
    ``slot // rows_per_shard`` everywhere: staging placement, per-shard
    counters and the failover drain all agree on this map)."""
    return range(shard * rows_per_shard, (shard + 1) * rows_per_shard)


def ensure_host_devices(n: int) -> None:
    """Make sure jax will see >= ``n`` devices, or fail actionably.

    The host-platform device count is fixed the moment jax initialises
    its backend, so this must run before the first ``jax.devices()`` /
    array op of the process.  If ``XLA_FLAGS`` does not already force a
    count we set it here (idempotent for a fresh process); if the backend
    initialised earlier with fewer devices, the count cannot change and
    we raise with the fix instead of silently serving a 1-device mesh.
    """
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + " " if flags else "") + f"{_FORCE_FLAG}={n}"
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"requested a {n}-device mesh but jax initialised with "
            f"{have} device(s) before the flag could take effect.  "
            f"Relaunch with XLA_FLAGS='{_FORCE_FLAG}={n}' in the "
            f"environment (or pass --mesh so the launcher sets it before "
            f"any jax use).")


# ---------------------------------------------------------------------------
# PartitionSpecs for the slot state and the serving param layout
# ---------------------------------------------------------------------------

def _tp_shards_hidden(cfg, plan: MeshPlan) -> bool:
    """True when the model axis actually shards ``d_hidden`` -- must
    match ``sharding.spec_for_param``'s divisibility fallback so the h
    cache layout agrees with the gate-kernel layout."""
    if plan.model <= 1 or cfg.block_kind != "minrnn":
        return False
    d_hidden = int(cfg.d_model * (cfg.minrnn.expansion if cfg.minrnn
                                  else 1.0))
    return d_hidden % plan.model == 0


def _cache_pspecs(cache: Dict[str, Any], shard_hidden: bool
                  ) -> Dict[str, Any]:
    """Decode-cache leaves: (L, B, ...) with batch at axis 1 (``pos`` at
    axis 0).  Only the minRNN ``h`` leaf carries a model dim (it IS the
    col-parallel gate output); conv windows / KV / SSM rows stay
    replicated per model shard."""
    specs: Dict[str, Any] = {}
    for k, leaf in cache.items():
        if k == "pos":
            specs[k] = P("data")
        elif k == "h" and shard_hidden:
            specs[k] = P(None, "data", "model")
        else:
            specs[k] = P(None, "data", *([None] * (leaf.ndim - 2)))
    return specs


def slot_state_pspecs(cfg, state: Dict[str, Any], plan: MeshPlan
                      ) -> Dict[str, Any]:
    """PartitionSpecs for every leaf of ``lm.init_slot_state``: the slot
    pool (request fields, sampling keys, staging buffers, prompt matrix)
    shards over ``data`` on its leading B dim; cache leaves shard B at
    axis 1, with ``h`` additionally on ``model`` under TP.  A draft
    model's cache shards over ``data`` only (draft weights are
    replicated -- its per-shard compute is identical everywhere)."""
    shard_hidden = _tp_shards_hidden(cfg, plan)
    specs: Dict[str, Any] = {}
    for k, v in state.items():
        if k == "cache":
            specs[k] = _cache_pspecs(v, shard_hidden)
        elif k == "draft_cache":
            specs[k] = _cache_pspecs(v, False)
        else:
            specs[k] = jax.tree.map(
                lambda leaf: P("data", *([None] * (leaf.ndim - 1))), v)
    return specs


def slot_state_shardings(cfg, state, plan: MeshPlan, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        slot_state_pspecs(cfg, state, plan),
                        is_leaf=lambda x: isinstance(x, P))


# Serving-TP whitelist: ONLY the projections whose d_hidden / d_ff dim
# the decode path actually blocks over (col-parallel gates + mlp_in,
# row-parallel down + mlp_out).  Everything else -- norms, depthwise conv
# (its channels feed the FULL-d_model gate contraction), the tiny
# embedding/unembedding (vocab 256: sampling wants full logits with no
# collective) -- is replicated per model shard even where the training
# PARAM_RULES would shard it.
_SERVE_TP_PARAMS = re.compile(
    r"(rnn/w[zhfi]/(kernel|bias)|down/kernel"
    r"|mlp_in/(kernel|bias)|mlp_out/kernel)$")


def serve_params_pspecs(params, cfg, plan: MeshPlan, mesh: Mesh):
    """Param PartitionSpecs for the sharded superstep: replicated under
    pure DP; under TP the ``sharding.PARAM_RULES`` entries for the gate /
    down / MLP projections apply with ``tp -> ("model",)`` and every
    other logical axis disabled (``fsdp`` etc. are training-time
    layouts -- serving wants whole weights per data shard)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    if plan.model <= 1:
        return jax.tree_util.tree_unflatten(treedef, [P()] * len(flat))
    mapping = {"dp": (), "fsdp": (), "tp": ("model",), "expert": (),
               "sp": ()}
    specs = []
    for path, leaf in flat:
        path_s = sharding._path_str(path)
        if _SERVE_TP_PARAMS.search(path_s):
            specs.append(sharding.spec_for_param(path_s, leaf.shape, mesh,
                                                 mapping))
        else:
            specs.append(P())
    return jax.tree_util.tree_unflatten(treedef, specs)


def serve_params_shardings(params, cfg, plan: MeshPlan, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        serve_params_pspecs(params, cfg, plan, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# The shard_map'd superstep
# ---------------------------------------------------------------------------

_PLAIN_COUNTERS = ("prefill_steps", "prefill_rounds", "wasted_slot_steps",
                   "nonfinite_decode_rounds")
_SPEC_COUNTERS = _PLAIN_COUNTERS + ("draft_proposed", "draft_accepted",
                                    "emit_rounds")


def make_superstep(cfg, plan: MeshPlan, mesh: Mesh, state: Dict[str, Any],
                   params, n: int, *, prompt_chunk: int = 1, draft=None):
    """Build the jitted ``shard_map``'d superstep.

    Returns ``fn(params, draft_params, state) -> (toks, rids, state,
    counters)`` with the same contract as ``lm.superstep`` except that
    the scalar counters come back as (data,) arrays -- one value per
    data shard -- so the host can hold the slot-step identity per shard
    as well as globally.  ``toks``/``rids`` are the global (B, n[, S+1])
    planes (B-sharded on device; ``np.asarray`` gathers them at drain).
    """
    from repro.models import lm      # deferred: keep import cycles away

    state_specs = slot_state_pspecs(cfg, state, plan)
    param_specs = serve_params_pspecs(params, cfg, plan, mesh)
    tp_axis = "model" if plan.model > 1 else None

    ckeys = _SPEC_COUNTERS if draft is not None else _PLAIN_COUNTERS
    counter_specs = {k: P("data") for k in ckeys}
    counter_specs["nonfinite"] = P("data", None)
    emit_spec = P("data", None, None) if draft is not None \
        else P("data", None)

    def body(p, dp, s):
        # the serving_tp context is consulted at TRACE time -- tracing
        # happens inside this body, so row-parallel projections know to
        # psum their d_hidden-block partials over the model axis
        with mesh_ctx.serving_tp(tp_axis):
            toks, rids, st, counters = lm.superstep(
                p, cfg, s, n, prompt_chunk=prompt_chunk, draft=draft,
                draft_params=dp)
        # scalar counters -> (1,) so the P("data") out-spec concatenates
        # one value per data shard
        counters = {k: (v[None] if v.ndim == 0 else v)
                    for k, v in counters.items()}
        return toks, rids, st, counters

    fn = mesh_ctx.shard_map(
        body, mesh=mesh,
        in_specs=(param_specs, P(), state_specs),
        out_specs=(emit_spec, emit_spec, state_specs, counter_specs),
        check_vma=False)
    return jax.jit(fn)
