"""Padded public wrappers for the fused whole-block decode kernel.

``fused_block_step`` / ``fused_block_chunk`` take a minRNN residual
block's own param dict (``blocks.init`` layout) plus its carried decode
state and run the ENTIRE block -- norm, conv step, cell, down-proj,
MLP -- in one ``pallas_call``.  Dispatch: ``blocks.step`` /
``blocks.step_chunk`` route here when ``scan_strategy`` resolves to
``"fused"`` and the block's ``fuse_block`` knob allows it (rmsnorm
blocks, layer not sliced by tensor-parallel serving -- the TP psum must
stay outside the kernel, so sharded layers fall back to the cell-fused
tier).

Dtype contract: the compute-dtype cast points inside the kernel body
replicate the unfused composition exactly -- norm scales are passed
UNCAST (``rmsnorm_apply`` reads them in fp32 from the param dtype),
conv weights are passed uncast (``causal_conv_step`` casts to the
activation dtype in place), gate / down / MLP weights are pre-cast here
exactly where ``_fused_step_args`` / ``dense_apply`` cast them.

Padding: batch pads to the fp32 sublane multiple (padded rows carry
zeros; chunk rows get valid=0 and freeze).  Under interpret mode the
feature dims are NOT padded and the grid is forced to a single tile --
every op in the kernel body is then the identical jnp op on identical
values, which is the bit-exactness contract the tier-1 parity tests
pin (same single-tile policy as ``kernels/decode_step``).  On a real
TPU backend the feature dims pad to the lane/tile grid (zero pad
columns are inert through the whole residual chain: zero norm-scale,
conv, gate and projection pads keep them zero) and ``block_dh`` tiles
the Dh axis -- exact per feature tile, autotuned via
``benchmarks/autotune.py``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.block_step import kernel as _kernel
from repro.kernels.scan.ops import pad_to

DEFAULT_INTERPRET = jax.default_backend() != "tpu"

_SUBLANES = 8     # fp32 sublane multiple; bf16 inputs are upcast in-kernel
_LANES = 128
_MAX_BLOCK_DH = 512   # default Dh tile ceiling on real backends


_GATES = {"mingru": ("wz", "wh"), "minlstm": ("wf", "wi", "wh")}


def _cast(a, cd):
    return a if cd is None else a.astype(cd)


def _gate_operands(params, cd, x_dtype, cell):
    """(w, b) per gate with ``_fused_step_args``'s compute-dtype cast;
    missing biases become zeros (cell wrappers do the same)."""
    out = []
    for name in _GATES[cell]:
        p = params["rnn"][name]
        w = _cast(p["kernel"], cd)
        b = p.get("bias")
        b = jnp.zeros((w.shape[1],), cd or x_dtype) if b is None \
            else _cast(b, cd)
        out.append((w, b))
    return out


def _tile_plan(dx, dh, dm, block_dh, interpret):
    """(dx_pad, dh_pad, dm_pad, block_dh).  Interpret mode: unpadded
    features, single tile (bit-exactness).  Real backend: lane-aligned
    pads, Dh tiled."""
    if interpret:
        return dx, dh, dm, dh
    rnd = lambda v: -(-v // _LANES) * _LANES if v else 0
    dxp, dmp = rnd(dx), rnd(dm)
    bdh = rnd(block_dh) if block_dh else min(rnd(dh), _MAX_BLOCK_DH)
    dhp = -(-dh // bdh) * bdh
    return dxp, dhp, dmp, bdh


def _pack(params, x, h, win, valid, *, cell, use_conv, use_mlp, cd,
          block_dh, interpret):
    """Pad everything to the kernel grid and build the flat operand
    tuple in ``kernel._specs`` order.  Returns (operands, dims)."""
    dx = x.shape[-1]
    dh = h.shape[-1]
    dm = params["mlp_in"]["kernel"].shape[1] if use_mlp else 0
    dxp, dhp, dmp, bdh = _tile_plan(dx, dh, dm, block_dh, interpret)

    xp, _ = pad_to(x, _SUBLANES, 0)
    bsz = x.shape[0]
    xp, _ = pad_to(xp, dxp, -1)
    ops = [xp, pad_to(params["norm_rnn"]["scale"], dxp, 0)[0]]
    if use_conv:
        ops += [pad_to(params["conv"]["kernel"], dxp, 1)[0],
                pad_to(params["conv"]["bias"], dxp, 0)[0],
                pad_to(pad_to(win, _SUBLANES, 0)[0], dxp, -1)[0]]
    for w, b in _gate_operands(params, cd, x.dtype, cell):
        ops += [pad_to(pad_to(w, dxp, 0)[0], dhp, 1)[0],
                pad_to(b, dhp, 0)[0]]
    ops.append(pad_to(pad_to(h, _SUBLANES, 0)[0], dhp, -1)[0])
    ops.append(pad_to(pad_to(_cast(params["down"]["kernel"], cd),
                             dhp, 0)[0], dxp, 1)[0])
    if use_mlp:
        ops += [pad_to(params["norm_mlp"]["scale"], dxp, 0)[0],
                pad_to(pad_to(_cast(params["mlp_in"]["kernel"], cd),
                              dxp, 0)[0], dmp, 1)[0],
                pad_to(_cast(params["mlp_in"]["bias"], cd), dmp, 0)[0],
                pad_to(pad_to(_cast(params["mlp_out"]["kernel"], cd),
                              dmp, 0)[0], dxp, 1)[0],
                pad_to(_cast(params["mlp_out"]["bias"], cd), dxp, 0)[0]]
    if valid is not None:
        ops.append(pad_to(valid.astype(jnp.int32)[:, None],
                          _SUBLANES, 0)[0])
    return tuple(ops), (bsz, dx, dh, bdh)


def _flat_lead(arrs, n_trail):
    """Collapse leading dims to one batch dim; returns (flats, lead)."""
    lead = arrs[0].shape[:-n_trail[0]]
    if len(lead) == 1:
        return list(arrs), None
    n = math.prod(lead)
    return [a.reshape((n,) + a.shape[len(lead):])
            for a in arrs], lead


def fused_block_step(params, x_t: jax.Array, state: dict, *,
                     cell: str = "mingru", mode: str = "log",
                     use_conv: bool = False, use_mlp: bool = False,
                     compute_dtype=None, block_dh: int = 0,
                     interpret: bool = DEFAULT_INTERPRET):
    """One whole-block decode step in one Pallas call.  x_t: (..., D),
    state: {"h": (..., Dh)[, "conv": (..., K-1, D)]} -> (y, new_state),
    bit-identical to ``blocks.step`` on the cell-fused path (single
    feature tile)."""
    win = state.get("conv") if use_conv else None
    arrs = [x_t, state["h"]] + ([win] if use_conv else [])
    trails = [1, 1] + ([2] if use_conv else [])
    (x_f, h_f, *rest), lead = _flat_lead(arrs, trails)
    win_f = rest[0] if use_conv else None

    operands, (bsz, dx, dh, bdh) = _pack(
        params, x_f, h_f, win_f, None, cell=cell, use_conv=use_conv,
        use_mlp=use_mlp, cd=compute_dtype, block_dh=block_dh,
        interpret=interpret)
    outs = _kernel.block_step_kernel(
        operands, cell=cell, mode=mode, use_conv=use_conv,
        use_mlp=use_mlp, block_dh=bdh, dx_true=dx, interpret=interpret)
    y, h = outs[0][:bsz, :dx], outs[1][:bsz, :dh]
    new_state = dict(state)
    new_state["h"] = h
    if use_conv:
        new_state["conv"] = outs[2][:bsz, :, :dx]
    if lead is not None:
        y = y.reshape(lead + y.shape[1:])
        new_state = {k: v.reshape(lead + v.shape[1:])
                     for k, v in new_state.items()}
    return y, new_state


def fused_block_chunk(params, x: jax.Array, state: dict,
                      valid: jax.Array, *, cell: str = "mingru",
                      mode: str = "log", use_conv: bool = False,
                      use_mlp: bool = False, compute_dtype=None,
                      block_dh: int = 0, return_positions: bool = False,
                      interpret: bool = DEFAULT_INTERPRET):
    """Varlen C-token whole-block chunk in one Pallas call (the packed
    prefill / speculative-verify form).  x: (B, C, D), valid: (B,) int32
    in [1, C] -> (ys, new_state[, per-position states]), matching
    ``blocks.step_chunk`` with ``return_positions``."""
    chunk = x.shape[1]
    win = state.get("conv") if use_conv else None

    # weight/state operands from a (B, D) probe, then swap in the padded
    # time-major chunk (the kernel's fori_loop wants (C, B, D))
    operands, (bsz, dx, dh, bdh) = _pack(
        params, x[:, 0], state["h"], win, valid, cell=cell,
        use_conv=use_conv, use_mlp=use_mlp, cd=compute_dtype,
        block_dh=block_dh, interpret=interpret)
    xp, _ = pad_to(x, _SUBLANES, 0)
    xp, _ = pad_to(xp, operands[0].shape[-1], -1)
    operands = (jnp.swapaxes(xp, 0, 1),) + operands[1:]

    outs = _kernel.block_chunk_kernel(
        operands, cell=cell, mode=mode, use_conv=use_conv,
        use_mlp=use_mlp, block_dh=bdh, dx_true=dx, interpret=interpret)
    ys = jnp.swapaxes(outs[0], 0, 1)[:bsz, :chunk, :dx]
    hs = jnp.swapaxes(outs[1], 0, 1)[:bsz, :chunk, :dh]
    new_state = dict(state)
    new_state["h"] = hs[:, -1]          # frozen rows: == hs[:, valid-1]
    pos_states = {"h": hs}
    if use_conv:
        wins = jnp.swapaxes(outs[2], 0, 1)[:bsz, :chunk, :, :dx]
        new_state["conv"] = wins[:, -1]
        pos_states["conv"] = wins
    if return_positions:
        return ys, new_state, pos_states
    return ys, new_state
