"""Pure-jnp oracles for the fused whole-block decode kernel.

Same math as kernel.py with no Pallas machinery: pre-norm RMSNorm (fp32
internal), causal-conv step, fp32 cell update (minGRU / minLSTM with
stable f/(f+i)), compute-dtype down / MLP dots.  This is deliberately
the op sequence of ``core.blocks.step`` / ``step_chunk`` on the
pure-jnp cell path, so the parity chain is

    kernel.py  ==  ref.py  ==  blocks.step(scan_strategy="sequential")

and the parity tests diff all three.  Params are the block's own param
dict (``blocks.init`` layout: norm_rnn / rnn / conv / down / norm_mlp /
mlp_in / mlp_out).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import min_lstm, nn


def _cell_step(cell: str, mode: str, rnn, y, h_prev, compute_dtype):
    """fp32 cell update matching the decode_step kernels: compute-dtype
    projections upcast to fp32, output cast back to the input dtype."""
    if compute_dtype is not None:
        y = y.astype(compute_dtype)
    out_dtype = y.dtype
    y32 = y.astype(jnp.float32)

    def proj(name):
        w = rnn[name]["kernel"].astype(jnp.float32)
        p = y32 @ w
        if "bias" in rnn[name]:
            p = p + rnn[name]["bias"].astype(jnp.float32)
        return p

    h32 = h_prev.astype(jnp.float32)
    if cell == "mingru":
        z = jax.nn.sigmoid(proj("wz"))
        v = proj("wh")
        h_tilde = nn.g(v) if mode == "log" else v
        h = (1.0 - z) * h32 + z * h_tilde
    else:
        f, i = min_lstm.normalized_gates(proj("wf"), proj("wi"))
        v = proj("wh")
        h_tilde = nn.g(v) if mode == "log" else v
        h = f * h32 + i * h_tilde
    return h.astype(out_dtype)


def block_step_ref(params, x_t, state, *, cell: str = "mingru",
                   mode: str = "log", use_conv: bool = True,
                   use_mlp: bool = True, compute_dtype=None):
    """One residual block decode step.  x_t: (B, d_model), state:
    {"h": (B, d_hidden)[, "conv": (B, K-1, d_model)]} -> (y, new_state)."""
    y = nn.rmsnorm_apply(params["norm_rnn"], x_t)
    new_state = dict(state)
    if use_conv:
        y, new_state["conv"] = nn.causal_conv_step(params["conv"], y,
                                                   state["conv"])
    h = _cell_step(cell, mode, params["rnn"], y, state["h"], compute_dtype)
    new_state["h"] = h
    x_t = x_t + nn.dense_apply(params["down"], h, compute_dtype)
    if use_mlp:
        y = nn.rmsnorm_apply(params["norm_mlp"], x_t)
        y = nn.gelu(nn.dense_apply(params["mlp_in"], y, compute_dtype))
        x_t = x_t + nn.dense_apply(params["mlp_out"], y, compute_dtype)
    return x_t, new_state


def block_chunk_ref(params, x, state, valid, *, cell: str = "mingru",
                    mode: str = "log", use_conv: bool = True,
                    use_mlp: bool = True, compute_dtype=None):
    """Varlen chunk oracle: ``valid[b]`` masked sequential block steps.
    x: (B, C, d_model), valid: (B,) int32 in [1, C] -> (ys (B, C,
    d_model), new_state, per-position states {"h": (B, C, d_hidden)[,
    "conv": (B, C, K-1, d_model)]}).  Frozen rows re-emit their final
    state; matching ``blocks.step_chunk``, the residual / down / MLP at
    a frozen position read the FROZEN h (garbage positions the caller
    masks are nonetheless deterministic, so the parity tests can diff
    every element)."""
    chunk = x.shape[1]

    def body(st, inp):
        x_t, t = inp
        keep = t < valid
        y = nn.rmsnorm_apply(params["norm_rnn"], x_t)
        st_new = dict(st)
        if use_conv:
            y, win_new = nn.causal_conv_step(params["conv"], y,
                                             st["conv"])
            st_new["conv"] = jnp.where(keep[:, None, None], win_new,
                                       st["conv"])
        h_new = _cell_step(cell, mode, params["rnn"], y, st["h"],
                           compute_dtype)
        st_new["h"] = jnp.where(keep[:, None], h_new,
                                st["h"]).astype(st["h"].dtype)
        x_t = x_t + nn.dense_apply(params["down"], st_new["h"],
                                   compute_dtype)
        if use_mlp:
            y = nn.rmsnorm_apply(params["norm_mlp"], x_t)
            y = nn.gelu(nn.dense_apply(params["mlp_in"], y,
                                       compute_dtype))
            x_t = x_t + nn.dense_apply(params["mlp_out"], y,
                                       compute_dtype)
        return st_new, (x_t, st_new)

    final, (ys, pos) = jax.lax.scan(
        body, dict(state), (jnp.moveaxis(x, 1, 0), jnp.arange(chunk)))
    ys = jnp.moveaxis(ys, 0, 1)
    pos = {k: jnp.moveaxis(v, 0, 1) for k, v in pos.items()}
    return ys, final, pos
