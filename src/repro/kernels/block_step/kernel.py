"""Pallas TPU kernel: fused whole-block minRNN decode step.

``kernels/decode_step`` fuses the *cell* (gate GEMVs + state update);
every other op of the residual block -- RMSNorm, the causal-conv step,
the down-projection and the MLP -- still runs as separate XLA fusions,
re-streaming (B, D) activations through HBM and paying a kernel launch
per op, per layer, per decode round.  At serving batch sizes the round
is weight-bound, so that overhead is pure latency on the hot path.

This kernel runs the ENTIRE block step in ONE pallas_call per layer:

    y  = RMSNorm(x) ; y = ConvStep(y)                 [optional conv]
    h  = cell(y, h_prev)          minGRU / minLSTM (stable f/(f+i))
    x  = x + Down(h)
    x  = x + MLPout(gelu(MLPin(RMSNorm(x))))          [optional MLP]

carrying (h, conv window) through VMEM and emitting the residual output
plus the updated state.  The arithmetic mirrors ``core.blocks.step``
op-for-op -- fp32 inside the norm and the cell (matching
``nn.rmsnorm_apply`` and the decode-step cell kernels), compute-dtype
dots for down/MLP (matching ``nn.dense_apply``) -- so with a single
feature tile the fused block is bit-identical to the cell-fused
composition.

Grid = (Dh tiles,), sequential: each tile computes its slice of the
gate projections and the new h, and accumulates its partial
down-projection product into a VMEM scratch; the final tile adds the
residual and runs the MLP.  With ``n_tiles == 1`` (every interpret-mode
config -- ops.py forces it, see the decode_step single-tile policy) the
body collapses to plain unsplit dots and the scratch disappears, which
is the bit-exactness contract.  Multi-tile grids (real-TPU VMEM
streaming for layers that do not fit) split the down contraction per
tile, exact per feature tile only.  The MLP weights ride VMEM-resident
(untiled) -- layers whose MLP exceeds VMEM should stay on the cell
kernel tier.

The ``*_chunk`` variants replay up to C per-token block steps per call
with per-row ``valid`` freezing -- the packed-prefill and
speculative-verify form.  They emit the per-position residual stream,
per-position h and per-position conv windows, so ``lm.decode_chunk``
(reads position ``valid-1``) and ``lm.decode_verify`` (needs the whole
rollback table) ride the same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import min_lstm, nn


def _rmsnorm(x, scale, dx_true: int):
    """``nn.rmsnorm_apply`` arithmetic; when the feature axis is padded
    (real-TPU lane alignment) the mean divides by the TRUE d_model --
    zero pad columns add nothing to the sum."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if x.shape[-1] == dx_true:
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    else:
        var = jnp.sum(jnp.square(x32), axis=-1, keepdims=True) / dx_true
    y = x32 * jax.lax.rsqrt(var + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def _cell_update(cell: str, mode: str, y32, gates32, h32):
    """One cell state update in fp32 -- the exact op sequence of the
    ``decode_step`` kernels (same dots, same gate transforms)."""
    if cell == "mingru":
        (wz, bz), (wh, bh) = gates32
        k = jnp.dot(y32, wz, preferred_element_type=jnp.float32) + bz
        v = jnp.dot(y32, wh, preferred_element_type=jnp.float32) + bh
        z = jax.nn.sigmoid(k)
        h_tilde = nn.g(v) if mode == "log" else v
        return (1.0 - z) * h32 + z * h_tilde
    (wf, bf), (wi, bi), (wh, bh) = gates32
    kf = jnp.dot(y32, wf, preferred_element_type=jnp.float32) + bf
    ki = jnp.dot(y32, wi, preferred_element_type=jnp.float32) + bi
    v = jnp.dot(y32, wh, preferred_element_type=jnp.float32) + bh
    f, i = min_lstm.normalized_gates(kf, ki)   # stable f/(f+i)
    h_tilde = nn.g(v) if mode == "log" else v
    return f * h32 + i * h_tilde


def _unpack(refs, *, cell: str, use_conv: bool, use_mlp: bool):
    """Split the flat pallas ref list into named groups (input order of
    ``_in_specs``)."""
    it = iter(refs)
    x = next(it)
    gamma = next(it)
    conv = (next(it), next(it), next(it)) if use_conv else None
    n_gates = 2 if cell == "mingru" else 3
    gates = [(next(it), next(it)) for _ in range(n_gates)]
    h = next(it)
    down = next(it)
    mlp = (next(it), next(it), next(it), next(it), next(it)) \
        if use_mlp else None
    return x, gamma, conv, gates, h, down, mlp, list(it)


def _conv_step(conv, y):
    """``nn.causal_conv_step``: returns (conv output, full window)."""
    ck_ref, cb_ref, win_ref = conv
    ck = ck_ref[...].astype(y.dtype)
    window = jnp.concatenate([win_ref[...], y[:, None, :]], axis=1)
    out = jnp.einsum("bkd,kd->bd", window, ck) \
        + cb_ref[...].astype(y.dtype)
    return out, window


def _mlp(mlp, x, dx_true: int):
    """Pre-norm gelu MLP sub-block on the residual stream.  The casts
    into the weight dtype replicate ``nn.dense_apply``'s compute-dtype
    cast (ops.py pre-casts the weights)."""
    gamma2_ref, wi_ref, bi_ref, wo_ref, bo_ref = mlp
    y = _rmsnorm(x, gamma2_ref[...], dx_true)
    m = jnp.dot(y.astype(wi_ref.dtype), wi_ref[...]) + bi_ref[...]
    m = jax.nn.gelu(m, approximate=True)
    return jnp.dot(m.astype(wo_ref.dtype), wo_ref[...]) + bo_ref[...]


def _block_step_body(*refs, cell: str, mode: str, use_conv: bool,
                     use_mlp: bool, n_tiles: int, dx_true: int):
    x_ref, gamma_ref, conv, gates, h_ref, down_ref, mlp, rest = _unpack(
        refs, cell=cell, use_conv=use_conv, use_mlp=use_mlp)
    y_out_ref, h_out_ref = rest[0], rest[1]
    win_out_ref = rest[2] if use_conv else None
    acc_ref = rest[-1] if n_tiles > 1 else None

    x = x_ref[...]                                        # (B, Dx)
    y = _rmsnorm(x, gamma_ref[...], dx_true)
    if use_conv:
        y, window = _conv_step(conv, y)
    # y -> gate-weight dtype -> fp32 replicates ``_fused_step_args``'s
    # compute-dtype cast followed by the cell kernel's fp32 upcast
    y32 = y.astype(gates[0][0].dtype).astype(jnp.float32)
    g32 = [(w[...].astype(jnp.float32), b[...].astype(jnp.float32))
           for (w, b) in gates]
    h32 = _cell_update(cell, mode, y32, g32,
                       h_ref[...].astype(jnp.float32))
    h = h32.astype(h_out_ref.dtype)
    h_out_ref[...] = h

    if n_tiles == 1:
        # the bit-exact tier: plain compute-dtype down dot, exactly
        # ``nn.dense_apply`` on the full feature dim
        if use_conv:
            win_out_ref[...] = window[:, 1:, :].astype(win_out_ref.dtype)
        xr = x + jnp.dot(h.astype(down_ref.dtype), down_ref[...])
        if use_mlp:
            xr = xr + _mlp(mlp, xr, dx_true)
        y_out_ref[...] = xr
        return

    # multi-tile (real-TPU streaming) tier: sequential grid over Dh
    # tiles, partial down products accumulated in fp32 scratch; the
    # last tile finishes the residual + MLP.  Exact per feature tile.
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        if use_conv:
            win_out_ref[...] = window[:, 1:, :].astype(win_out_ref.dtype)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(h.astype(down_ref.dtype), down_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_tiles - 1)
    def _():
        xr = x + acc_ref[...].astype(x.dtype)
        if use_mlp:
            xr = xr + _mlp(mlp, xr, dx_true)
        y_out_ref[...] = xr


def _block_chunk_body(*refs, cell: str, mode: str, use_conv: bool,
                      use_mlp: bool, n_tiles: int, dx_true: int,
                      chunk: int):
    """Varlen C-token chunk: weights VMEM-resident, one ``fori_loop``
    replaying the exact per-token arithmetic of ``_block_step_body``
    with per-row ``valid`` freezing of (h, conv window) -- bit-identical
    to ``chunk`` sequential block-step calls (single-tile tier)."""
    x_ref, gamma_ref, conv, gates, h_ref, down_ref, mlp, rest = _unpack(
        refs, cell=cell, use_conv=use_conv, use_mlp=use_mlp)
    valid_ref = rest[0]                                   # (B, 1) int32
    y_out_ref, hs_ref = rest[1], rest[2]
    win_pos_ref = rest[3] if use_conv else None
    acc_ref = rest[-1] if n_tiles > 1 else None

    valid = valid_ref[...]
    g32 = [(w[...].astype(jnp.float32), b[...].astype(jnp.float32))
           for (w, b) in gates]
    j = pl.program_id(0) if n_tiles > 1 else 0

    def body(t, carry):
        h32, win = carry
        x_t = x_ref[t]                                    # (B, Dx)
        y = _rmsnorm(x_t, gamma_ref[...], dx_true)
        if use_conv:
            ck_ref, cb_ref, _ = conv
            ck = ck_ref[...].astype(y.dtype)
            window = jnp.concatenate([win, y[:, None, :]], axis=1)
            y = jnp.einsum("bkd,kd->bd", window, ck) \
                + cb_ref[...].astype(y.dtype)
            win = jnp.where((t < valid)[..., None], window[:, 1:, :], win)
        y32 = y.astype(gates[0][0].dtype).astype(jnp.float32)
        h_new32 = _cell_update(cell, mode, y32, g32, h32)
        # per-token round-trip through the cache dtype -- sequential
        # steps re-read h from a cdtype cache, so the packed carry must
        # quantize identically (same contract as the decode_step chunks)
        h_new32 = h_new32.astype(hs_ref.dtype).astype(jnp.float32)
        h32 = jnp.where(t < valid, h_new32, h32)
        h = h32.astype(hs_ref.dtype)
        hs_ref[t] = h
        if use_conv:
            win_pos_ref[t] = win.astype(win_pos_ref.dtype)
        if n_tiles == 1:
            xr = x_t + jnp.dot(h.astype(down_ref.dtype), down_ref[...])
            if use_mlp:
                xr = xr + _mlp(mlp, xr, dx_true)
            y_out_ref[t] = xr
        else:
            prev = jnp.where(j == 0, jnp.zeros_like(acc_ref[t]),
                             acc_ref[t])
            part = prev + jnp.dot(h.astype(down_ref.dtype), down_ref[...],
                                  preferred_element_type=jnp.float32)
            acc_ref[t] = part
            # complete only on the last tile; earlier tiles' writes are
            # overwritten (sequential grid, pinned output block)
            xr = x_t + part.astype(x_t.dtype)
            if use_mlp:
                xr = xr + _mlp(mlp, xr, dx_true)
            y_out_ref[t] = xr
        return h32, win

    win0 = conv[2][...] if use_conv else jnp.zeros((), x_ref.dtype)
    jax.lax.fori_loop(0, chunk, body,
                      (h_ref[...].astype(jnp.float32), win0))


def _specs(bsz, dxp, dhp, dmp, conv_k, block_dh, *, cell, use_conv,
           use_mlp, chunk=0):
    """(in_specs, out_specs) for the step (chunk=0) / chunk forms.  The
    x / norm / conv / MLP operands are pinned (index_map constant, so
    Mosaic keeps them resident across feature tiles); gate weights,
    biases, h and the down rows stream per Dh tile."""
    pin2 = pl.BlockSpec((bsz, dxp), lambda j: (0, 0))
    vec = pl.BlockSpec((dxp,), lambda j: (0,))
    gate_w = pl.BlockSpec((dxp, block_dh), lambda j: (0, j))
    gate_b = pl.BlockSpec((block_dh,), lambda j: (j,))
    n_gates = 2 if cell == "mingru" else 3

    in_specs = [pl.BlockSpec((chunk, bsz, dxp), lambda j: (0, 0, 0))
                if chunk else pin2,
                vec]
    if use_conv:
        in_specs += [pl.BlockSpec((conv_k, dxp), lambda j: (0, 0)),
                     vec,
                     pl.BlockSpec((bsz, conv_k - 1, dxp),
                                  lambda j: (0, 0, 0))]
    in_specs += [gate_w, gate_b] * n_gates
    in_specs += [pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
                 pl.BlockSpec((block_dh, dxp), lambda j: (j, 0))]
    if use_mlp:
        in_specs += [vec,
                     pl.BlockSpec((dxp, dmp), lambda j: (0, 0)),
                     pl.BlockSpec((dmp,), lambda j: (0,)),
                     pl.BlockSpec((dmp, dxp), lambda j: (0, 0)),
                     vec]
    if chunk:
        in_specs.append(pl.BlockSpec((bsz, 1), lambda j: (0, 0)))

    if chunk:
        out_specs = [pl.BlockSpec((chunk, bsz, dxp), lambda j: (0, 0, 0)),
                     pl.BlockSpec((chunk, bsz, block_dh),
                                  lambda j: (0, 0, j))]
        if use_conv:
            out_specs.append(pl.BlockSpec((chunk, bsz, conv_k - 1, dxp),
                                          lambda j: (0, 0, 0, 0)))
    else:
        out_specs = [pin2,
                     pl.BlockSpec((bsz, block_dh), lambda j: (0, j))]
        if use_conv:
            out_specs.append(pl.BlockSpec((bsz, conv_k - 1, dxp),
                                          lambda j: (0, 0, 0)))
    return in_specs, out_specs


@functools.partial(jax.jit, static_argnames=(
    "cell", "mode", "use_conv", "use_mlp", "block_dh", "dx_true",
    "interpret"))
def block_step_kernel(operands, *, cell: str, mode: str, use_conv: bool,
                      use_mlp: bool, block_dh: int, dx_true: int,
                      interpret: bool = True):
    """operands: flat tuple in ``_specs`` input order -- x (B, Dxp),
    norm scale, [conv kernel/bias/window], gate (w, b) pairs, h_prev
    (B, Dhp), down kernel, [mlp norm scale / in w / in b / out w /
    out b].  Returns (y (B, Dxp), h (B, Dhp)[, window (B, K-1, Dxp)]).
    Dhp % block_dh == 0 (ops.py pads; forces a single tile under
    interpret for bit-exactness)."""
    x = operands[0]
    bsz, dxp = x.shape
    n_gates = 2 if cell == "mingru" else 3
    i_gate = 2 + (3 if use_conv else 0)
    dhp = operands[i_gate].shape[1]
    h_prev = operands[i_gate + 2 * n_gates]
    conv_k = operands[2].shape[0] if use_conv else 0
    dmp = operands[i_gate + 2 * n_gates + 3].shape[1] if use_mlp else 0
    assert dhp % block_dh == 0, (dhp, block_dh)
    n_tiles = dhp // block_dh

    in_specs, out_specs = _specs(bsz, dxp, dhp, dmp, conv_k, block_dh,
                                 cell=cell, use_conv=use_conv,
                                 use_mlp=use_mlp)
    out_shape = [jax.ShapeDtypeStruct((bsz, dxp), x.dtype),
                 jax.ShapeDtypeStruct((bsz, dhp), h_prev.dtype)]
    if use_conv:
        out_shape.append(jax.ShapeDtypeStruct((bsz, conv_k - 1, dxp),
                                              x.dtype))
    kwargs = {}
    if n_tiles > 1:
        kwargs["scratch_shapes"] = [pltpu.VMEM((bsz, dxp), jnp.float32)]
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",))   # sequential: down acc

    return pl.pallas_call(
        functools.partial(_block_step_body, cell=cell, mode=mode,
                          use_conv=use_conv, use_mlp=use_mlp,
                          n_tiles=n_tiles, dx_true=dx_true),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        interpret=interpret,
        **kwargs,
    )(*operands)


@functools.partial(jax.jit, static_argnames=(
    "cell", "mode", "use_conv", "use_mlp", "block_dh", "dx_true",
    "interpret"))
def block_chunk_kernel(operands, *, cell: str, mode: str, use_conv: bool,
                       use_mlp: bool, block_dh: int, dx_true: int,
                       interpret: bool = True):
    """Chunk form: operands as :func:`block_step_kernel` with x time-major
    (C, B, Dxp) and a trailing valid (B, 1) int32.  Returns per-position
    (ys (C, B, Dxp), hs (C, B, Dhp)[, windows (C, B, K-1, Dxp)]); frozen
    rows re-emit their final state from position ``valid-1`` on."""
    x = operands[0]
    chunk, bsz, dxp = x.shape
    n_gates = 2 if cell == "mingru" else 3
    i_gate = 2 + (3 if use_conv else 0)
    dhp = operands[i_gate].shape[1]
    h_prev = operands[i_gate + 2 * n_gates]
    conv_k = operands[2].shape[0] if use_conv else 0
    dmp = operands[i_gate + 2 * n_gates + 3].shape[1] if use_mlp else 0
    assert dhp % block_dh == 0, (dhp, block_dh)
    n_tiles = dhp // block_dh

    in_specs, out_specs = _specs(bsz, dxp, dhp, dmp, conv_k, block_dh,
                                 cell=cell, use_conv=use_conv,
                                 use_mlp=use_mlp, chunk=chunk)
    out_shape = [jax.ShapeDtypeStruct((chunk, bsz, dxp), x.dtype),
                 jax.ShapeDtypeStruct((chunk, bsz, dhp), h_prev.dtype)]
    if use_conv:
        out_shape.append(jax.ShapeDtypeStruct(
            (chunk, bsz, conv_k - 1, dxp), x.dtype))
    kwargs = {}
    if n_tiles > 1:
        kwargs["scratch_shapes"] = [
            pltpu.VMEM((chunk, bsz, dxp), jnp.float32)]
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",))

    return pl.pallas_call(
        functools.partial(_block_chunk_body, cell=cell, mode=mode,
                          use_conv=use_conv, use_mlp=use_mlp,
                          n_tiles=n_tiles, dx_true=dx_true, chunk=chunk),
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        interpret=interpret,
        **kwargs,
    )(*operands)
