"""Pallas TPU kernel: chunked first-order linear scan.

TPU-native adaptation of the paper's parallel scan (DESIGN.md §3):

  * grid = (batch, feature_tiles, time_chunks); the time dimension is the
    LAST grid axis so it executes sequentially on a core ("arbitrary"
    dimension semantics), giving us a legal cross-chunk carry;
  * each (chunk, feature_tile) block of a/b lives in VMEM -- (bt, bd) with
    bt a multiple of 8 (sublanes) and bd a multiple of 128 (lanes);
  * the in-chunk inclusive prefix is a Kogge-Stone doubling ladder of
    elementwise VPU ops (log2(bt) steps), never touching the MXU;
  * the carry h between chunks is a (1, bd) fp32 VMEM scratch accumulator.

HBM traffic: reads a,b once, writes h once -- the roofline optimum for an
elementwise scan (arithmetic intensity ~ log2(bt)/6 flops/byte).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kogge_stone(a: jax.Array, b: jax.Array):
    """Inclusive scan of (a, b) segments along axis 0 of a (bt, bd) tile.

    combine((A_l,B_l),(A_r,B_r)) = (A_l*A_r, A_r*B_l + B_r); log2(bt) steps,
    each a full-tile shift + multiply-add (vectorizes on 8x128 VPU lanes).
    """
    bt = a.shape[0]
    A, B = a, b
    shift = 1
    while shift < bt:
        A_prev = jnp.concatenate(
            [jnp.ones((shift,) + A.shape[1:], A.dtype), A[:-shift]], axis=0)
        B_prev = jnp.concatenate(
            [jnp.zeros((shift,) + B.shape[1:], B.dtype), B[:-shift]], axis=0)
        B = A * B_prev + B
        A = A * A_prev
        shift *= 2
    return A, B


def _log_kogge_stone(la: jax.Array, lb: jax.Array):
    """Inclusive scan of log-space (log_a, log_b) segments along axis 0.

    Same doubling ladder as :func:`_kogge_stone` but with the combine done
    entirely in log space,

        combine((La_l, Lb_l), (La_r, Lb_r))
            = (La_l + La_r, logaddexp(La_r + Lb_l, Lb_r)),

    so no cumulative product/sum is ever materialised in linear space --
    this is the in-kernel equivalent of the Heinsen (2023) scan.  Identity
    element: (log_a, log_b) = (0, -inf).
    """
    bt = la.shape[0]
    A, B = la, lb
    shift = 1
    while shift < bt:
        A_prev = jnp.concatenate(
            [jnp.zeros((shift,) + A.shape[1:], A.dtype), A[:-shift]], axis=0)
        B_prev = jnp.concatenate(
            [jnp.full((shift,) + B.shape[1:], -jnp.inf, B.dtype),
             B[:-shift]], axis=0)
        B = jnp.logaddexp(A + B_prev, B)
        A = A + A_prev
        shift *= 2
    return A, B


def _scan_kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref):
    """One (batch row, feature tile, time chunk) block."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(carry_ref.dtype)

    a = a_ref[0].astype(jnp.float32)          # (bt, bd)
    b = b_ref[0].astype(jnp.float32)
    A, B = _kogge_stone(a, b)
    h = B + A * carry_ref[...]                # carry broadcasts (1, bd)
    o_ref[0, ...] = h.astype(o_ref.dtype)
    carry_ref[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("block_t", "block_d",
                                             "interpret"))
def linear_scan_kernel(a: jax.Array, b: jax.Array, h0: jax.Array,
                       *, block_t: int = 256, block_d: int = 128,
                       interpret: bool = True) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t via the Pallas chunked-scan kernel.

    a, b: (B, T, D); h0: (B, D).  T % block_t == 0 and D % block_d == 0
    (ops.py pads).  interpret=True executes the kernel body on CPU; on a
    real TPU pass interpret=False.
    """
    bsz, t, d = a.shape
    assert t % block_t == 0 and d % block_d == 0, (t, d, block_t, block_d)
    grid = (bsz, d // block_d, t // block_t)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_t, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_d), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d),
                               lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(a, b, h0)


def _log_scan_kernel(la_ref, lb_ref, lh0_ref, o_ref, carry_ref):
    """One (batch row, feature tile, time chunk) block of the log-space scan.

    Inputs are log coefficients / log values; the cross-chunk carry stays in
    LOG space (the per-chunk logaddexp ladder is the rescaling: nothing is
    exponentiated until the final write), so arbitrarily long products of
    a_t in (0, 1) never underflow.  Output is h = exp(log_h), linear space.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        carry_ref[...] = lh0_ref[...].astype(carry_ref.dtype)

    la = la_ref[0].astype(jnp.float32)        # (bt, bd) cumulative log a
    lb = lb_ref[0].astype(jnp.float32)
    A, B = _log_kogge_stone(la, lb)
    log_h = jnp.logaddexp(B, A + carry_ref[...])   # carry: (1, bd) log h
    o_ref[0, ...] = jnp.exp(log_h).astype(o_ref.dtype)
    carry_ref[...] = log_h[-1:]


@functools.partial(jax.jit, static_argnames=("block_t", "block_d",
                                             "interpret"))
def log_scan_kernel(log_a: jax.Array, log_b: jax.Array, log_h0: jax.Array,
                    *, block_t: int = 256, block_d: int = 128,
                    interpret: bool = True) -> jax.Array:
    """h_t = exp(log_a_t) * h_{t-1} + exp(log_b_t) via the log-space kernel.

    log_a, log_b: (B, T, D); log_h0: (B, D), -inf encodes h0 = 0.  Output is
    h in linear space; all intermediate state (cumulative coefficients and
    the cross-chunk carry) stays in log space.  T % block_t == 0 and
    D % block_d == 0 (ops.py pads with the identity (0, -inf)).
    """
    bsz, t, d = log_a.shape
    assert t % block_t == 0 and d % block_d == 0, (t, d, block_t, block_d)
    grid = (bsz, d // block_d, t // block_t)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        _log_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_t, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_d), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d),
                               lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(log_a, log_b, log_h0)
