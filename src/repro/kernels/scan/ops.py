"""Jitted public wrapper for the chunked-scan kernel, with custom VJP.

The backward pass of h_t = a_t h_{t-1} + b_t is itself a (reversed) linear
scan:

    g_t  = dL/dh_t + a_{t+1} g_{t+1}        (reverse-scan with coeff a_{t+1})
    dL/db_t = g_t
    dL/da_t = g_t * h_{t-1}
    dL/dh0  = a_1 * g_1  ... = g_0' (the reverse carry past t=1)

so the same kernel serves both directions -- the training hot path never
leaves Pallas.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.scan import kernel as _kernel

DEFAULT_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x, multiple, axis, value):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, size
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def _run(a, b, h0, block_t, block_d, interpret):
    """Pad to tile multiples, run kernel, slice back."""
    t, d = a.shape[-2], a.shape[-1]
    bt = min(block_t, max(8, 1 << (t - 1).bit_length()))
    a_p, _ = _pad_to(a, bt, -2, 1.0)       # identity coefficient
    b_p, _ = _pad_to(b, bt, -2, 0.0)
    a_p, _ = _pad_to(a_p, block_d, -1, 1.0)
    b_p, _ = _pad_to(b_p, block_d, -1, 0.0)
    h0_p, _ = _pad_to(h0, block_d, -1, 0.0)
    out = _kernel.linear_scan_kernel(a_p, b_p, h0_p, block_t=bt,
                                     block_d=block_d, interpret=interpret)
    return out[..., :t, :d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                block_t: int = 256, block_d: int = 128,
                interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """Differentiable h_t = a_t h_{t-1} + b_t, Pallas-accelerated.

    a, b: (B, T, D); h0: (B, D).  Arbitrary T/D (padded to tiles).
    """
    return _run(a, b, h0, block_t, block_d, interpret)


def _fwd(a, b, h0, block_t, block_d, interpret):
    h = _run(a, b, h0, block_t, block_d, interpret)
    return h, (a, h, h0)


def _bwd(block_t, block_d, interpret, res, dh):
    a, h, h0 = res
    # reverse scan: g_t = dh_t + a_{t+1} g_{t+1}
    a_next = jnp.concatenate(
        [a[..., 1:, :], jnp.zeros_like(a[..., :1, :])], axis=-2)
    g = _run(jnp.flip(a_next, axis=-2), jnp.flip(dh, axis=-2),
             jnp.zeros_like(h0), block_t, block_d, interpret)
    g = jnp.flip(g, axis=-2)
    h_prev = jnp.concatenate([h0[..., None, :], h[..., :-1, :]], axis=-2)
    da = g * h_prev
    db = g
    dh0 = a[..., 0, :] * g[..., 0, :]
    return da, db, dh0


linear_scan.defvjp(_fwd, _bwd)


def linear_scan_auto(a: jax.Array, b: jax.Array,
                     h0: Optional[jax.Array] = None, **kw) -> jax.Array:
    """Convenience: default h0 = 0, flattens extra leading dims."""
    if h0 is None:
        h0 = jnp.zeros(a.shape[:-2] + a.shape[-1:], b.dtype)
    lead = a.shape[:-2]
    if len(lead) != 1:
        n = 1
        for s in lead:
            n *= s
        out = linear_scan(a.reshape((n,) + a.shape[-2:]),
                          b.reshape((n,) + b.shape[-2:]),
                          h0.reshape((n,) + h0.shape[-1:]), **kw)
        return out.reshape(lead + out.shape[-2:])
    return linear_scan(a, b, h0, **kw)
