"""Jitted public wrappers for the chunked-scan kernels, with custom VJPs.

Two differentiable entry points share one backward structure:

  * ``linear_scan``     -- h_t = a_t h_{t-1} + b_t on linear-space inputs
    (the ``scan_strategy="pallas"``/``mode="linear"`` path);
  * ``log_space_scan``  -- same recurrence parameterised by (log a, log b)
    with the per-chunk logaddexp ladder and a log-space cross-chunk carry
    (the default ``mode="log"`` training/prefill path, numerically
    matching ``repro.core.scan.scan_log_space``).

The backward pass of h_t = a_t h_{t-1} + b_t is itself a (reversed) linear
scan:

    g_t  = dL/dh_t + a_{t+1} g_{t+1}        (reverse-scan with coeff a_{t+1})
    dL/db_t = g_t
    dL/da_t = g_t * h_{t-1}
    dL/dh0  = a_1 * g_1  ... = g_0' (the reverse carry past t=1)

and for the log parameterisation the chain rule just multiplies each grad
by the exponentiated input (d/dlog_a = a * d/da).  The reverse scan's
coefficients a_{t+1} live in (0, 1) and its values dL/dh_t are finite and
signed, so it is numerically safe in linear space: the *forward* kernel
needs log space (long products of gates underflow), the backward reuses
the linear kernel reversed.  Both directions of both entry points run the
Pallas chunked-scan kernels (interpret mode off-TPU).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.scan import kernel as _kernel

DEFAULT_INTERPRET = jax.default_backend() != "tpu"


def call_with_flat_lead(fn, *specs):
    """Collapse arbitrary leading dims to one batch dim around ``fn``.

    ``specs`` are (array, n_trailing) pairs; the leading dims are taken
    from the first pair and must agree across all of them.  Used by every
    kernel wrapper (and the fused cell paths) whose Pallas grid wants a
    single (B, ...) batch axis.
    """
    x0, t0 = specs[0]
    lead = x0.shape[:-t0] if t0 else x0.shape
    if len(lead) == 1:
        return fn(*(x for x, _ in specs))
    n = math.prod(lead)
    flat = [x.reshape((n,) + x.shape[len(lead):]) for x, _ in specs]
    out = fn(*flat)
    return out.reshape(lead + out.shape[1:])


def pad_to(x, multiple, axis, value=0.0):
    """Pad ``axis`` up to a multiple with ``value``; returns (padded, size).

    Shared by every kernel wrapper (this module and the fused cell ops)
    that must round inputs up to the Pallas tile grid.
    """
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, size
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def round_block_t(block_t: int, t: int) -> int:
    """Clamp the time tile for a length-t sequence: next power of two
    covering t, at least 8 (TPU sublanes), at most ``block_t``."""
    return min(block_t, max(8, 1 << (t - 1).bit_length()))


_pad_to = pad_to   # internal alias


def _run(a, b, h0, block_t, block_d, interpret):
    """Pad to tile multiples, run kernel, slice back."""
    t, d = a.shape[-2], a.shape[-1]
    bt = round_block_t(block_t, t)
    a_p, _ = _pad_to(a, bt, -2, 1.0)       # identity coefficient
    b_p, _ = _pad_to(b, bt, -2, 0.0)
    a_p, _ = _pad_to(a_p, block_d, -1, 1.0)
    b_p, _ = _pad_to(b_p, block_d, -1, 0.0)
    h0_p, _ = _pad_to(h0, block_d, -1, 0.0)
    out = _kernel.linear_scan_kernel(a_p, b_p, h0_p, block_t=bt,
                                     block_d=block_d, interpret=interpret)
    return out[..., :t, :d]


def reverse_scan_grads(a, dh, h, h0, block_t, block_d, interpret):
    """Shared backward core for h_t = a_t h_{t-1} + b_t.

    Runs the reverse scan g_t = dh_t + a_{t+1} g_{t+1} through the Pallas
    kernel and returns ``(g, h_prev, dh0)`` with ``dh0 = a_1 * g_1``; every
    custom VJP in this module and in the fused cell kernels derives its
    input gradients from these (dL/da = g * h_prev, dL/db = g, plus any
    chain rule for the parameterisation).  All arrays are linear-space and
    share one dtype chosen by the caller; the coefficients a live in
    (0, 1) and g is finite and signed, so linear space is safe even when
    the forward ran in log space.
    """
    # reverse scan: g_t = dh_t + a_{t+1} g_{t+1}
    a_next = jnp.concatenate(
        [a[..., 1:, :], jnp.zeros_like(a[..., :1, :])], axis=-2)
    g = _run(jnp.flip(a_next, axis=-2), jnp.flip(dh, axis=-2),
             jnp.zeros_like(h0), block_t, block_d, interpret)
    g = jnp.flip(g, axis=-2)
    h_prev = jnp.concatenate([h0[..., None, :], h[..., :-1, :]], axis=-2)
    dh0 = a[..., 0, :] * g[..., 0, :]
    return g, h_prev, dh0


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                block_t: int = 256, block_d: int = 128,
                interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """Differentiable h_t = a_t h_{t-1} + b_t, Pallas-accelerated.

    a, b: (B, T, D); h0: (B, D).  Arbitrary T/D (padded to tiles).
    """
    return _run(a, b, h0, block_t, block_d, interpret)


def _fwd(a, b, h0, block_t, block_d, interpret):
    h = _run(a, b, h0, block_t, block_d, interpret)
    return h, (a, h, h0)


def _bwd(block_t, block_d, interpret, res, dh):
    a, h, h0 = res
    g, h_prev, dh0 = reverse_scan_grads(a, dh, h, h0, block_t, block_d,
                                        interpret)
    return g * h_prev, g, dh0


linear_scan.defvjp(_fwd, _bwd)


def linear_scan_auto(a: jax.Array, b: jax.Array,
                     h0: Optional[jax.Array] = None, **kw) -> jax.Array:
    """Convenience: default h0 = 0, flattens extra leading dims."""
    if h0 is None:
        h0 = jnp.zeros(a.shape[:-2] + a.shape[-1:], b.dtype)
    return call_with_flat_lead(
        lambda a_, b_, h_: linear_scan(a_, b_, h_, **kw),
        (a, 2), (b, 2), (h0, 1))


# ---------------------------------------------------------------------------
# Log-space scan (the default mode="log" training/prefill path)
# ---------------------------------------------------------------------------

def _run_log(log_a, log_b, log_h0, block_t, block_d, interpret):
    """Pad to tile multiples with the log identity (0, -inf), run, slice."""
    t, d = log_a.shape[-2], log_a.shape[-1]
    bt = round_block_t(block_t, t)
    la_p, _ = _pad_to(log_a, bt, -2, 0.0)         # log a = 0  <=>  a = 1
    lb_p, _ = _pad_to(log_b, bt, -2, -jnp.inf)    # log b = -inf  <=>  b = 0
    la_p, _ = _pad_to(la_p, block_d, -1, 0.0)
    lb_p, _ = _pad_to(lb_p, block_d, -1, -jnp.inf)
    lh0_p, _ = _pad_to(log_h0, block_d, -1, -jnp.inf)
    out = _kernel.log_scan_kernel(la_p, lb_p, lh0_p, block_t=bt,
                                  block_d=block_d, interpret=interpret)
    return out[..., :t, :d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def log_space_scan(log_a: jax.Array, log_b: jax.Array, log_h0: jax.Array,
                   block_t: int = 256, block_d: int = 128,
                   interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """Differentiable Heinsen-style scan, Pallas-accelerated.

    h_t = exp(log_a_t) h_{t-1} + exp(log_b_t);  log_a, log_b: (B, T, D);
    log_h0: (B, D) with -inf encoding h0 = 0.  Output h is linear-space
    fp32; all in-kernel state stays in log space (see kernel.py).
    """
    return _run_log(log_a, log_b, log_h0, block_t, block_d, interpret)


def _log_fwd(log_a, log_b, log_h0, block_t, block_d, interpret):
    h = _run_log(log_a, log_b, log_h0, block_t, block_d, interpret)
    return h, (log_a, log_b, log_h0, h)


def _log_bwd(block_t, block_d, interpret, res, dh):
    log_a, log_b, log_h0, h = res
    a = jnp.exp(log_a.astype(jnp.float32))
    h0 = jnp.exp(log_h0.astype(jnp.float32))
    g, h_prev, dh0 = reverse_scan_grads(a, dh.astype(jnp.float32), h, h0,
                                        block_t, block_d, interpret)
    # chain rule through the exp parameterisation: d/dlog_x = x * d/dx
    dlog_a = (g * h_prev * a).astype(log_a.dtype)
    dlog_b = (g * jnp.exp(log_b.astype(jnp.float32))).astype(log_b.dtype)
    dlog_h0 = (dh0 * h0).astype(log_h0.dtype)
    return dlog_a, dlog_b, dlog_h0


log_space_scan.defvjp(_log_fwd, _log_bwd)


def log_space_scan_auto(log_a: jax.Array, log_b: jax.Array,
                        log_h0: Optional[jax.Array] = None, **kw
                        ) -> jax.Array:
    """Convenience: default log_h0 = -inf (h0 = 0), flattens leading dims."""
    if log_h0 is None:
        log_h0 = jnp.full(log_a.shape[:-2] + log_a.shape[-1:], -jnp.inf,
                          jnp.float32)
    return call_with_flat_lead(
        lambda a_, b_, h_: log_space_scan(a_, b_, h_, **kw),
        (log_a, 2), (log_b, 2), (log_h0, 1))
