"""Pure-jnp oracle for the chunked linear-scan Pallas kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def linear_scan_ref(a: jax.Array, b: jax.Array,
                    h0: Optional[jax.Array] = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis -2.  Sequential ground truth.

    a, b: (B, T, D);  h0: (B, D) or None (zeros).
    """
    if h0 is None:
        h0 = jnp.zeros(a.shape[:-2] + a.shape[-1:], b.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    a_t = jnp.moveaxis(a, -2, 0)
    b_t = jnp.moveaxis(b, -2, 0)
    _, hs = lax.scan(step, h0.astype(b.dtype), (a_t, b_t))
    return jnp.moveaxis(hs, 0, -2)
