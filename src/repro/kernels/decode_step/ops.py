"""Padded public wrappers for the fused decode-step kernels.

``fused_mingru_step`` / ``fused_minlstm_step`` accept arbitrary batch
leading dims, any Dx/Dh (padded up to the kernel tile grid with zeros --
zero-padded contraction columns contribute nothing to the GEMVs, and
padded feature columns are sliced off the output), and optional biases.
No custom VJP: decode is inference-only; training/prefill differentiate
through the fused *parallel* kernels instead.

Dispatch: ``core.min_gru.step`` / ``core.min_lstm.step`` route here when
their ``scan_strategy`` resolves to ``"fused"`` (the config default
``"auto"``), which is how ``blocks.step`` -> ``lm.decode_step`` ->
``lm.superstep`` put the whole serving hot path on Pallas: the engine's
unified device loop drives prefilling (teacher-forced prompt tokens) and
decoding rows through this same kernel in the same round -- real kernels
on TPU, interpret-mode parity elsewhere.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_step import kernel as _kernel
from repro.kernels.scan.ops import call_with_flat_lead, pad_to

DEFAULT_INTERPRET = jax.default_backend() != "tpu"

_SUBLANES = 8     # fp32 sublane multiple; bf16 inputs are upcast in-kernel
_LANES = 128


def _pad_batch(x, h_prev):
    x, b = pad_to(x, _SUBLANES, 0)
    h_prev, _ = pad_to(h_prev, _SUBLANES, 0)
    return x, h_prev, b


def fused_mingru_step(x: jax.Array, wz: jax.Array, bz: Optional[jax.Array],
                      wh: jax.Array, bh: Optional[jax.Array],
                      h_prev: jax.Array, *, mode: str = "log",
                      block_dh: int = 128,
                      interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minGRU cell step (projections + gates + state update), one Pallas
    call.  x: (..., Dx), h_prev: (..., Dh) -> h_t: (..., Dh)."""
    dh = wz.shape[1]
    if bz is None:
        bz = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)

    def run(xf, hf):
        xp, hp, b = _pad_batch(xf, hf)
        xp, _ = pad_to(xp, _LANES, 1)
        wzp, _ = pad_to(pad_to(wz, _LANES, 0)[0], block_dh, 1)
        whp, _ = pad_to(pad_to(wh, _LANES, 0)[0], block_dh, 1)
        bzp, _ = pad_to(bz, block_dh, 0)
        bhp, _ = pad_to(bh, block_dh, 0)
        hp, _ = pad_to(hp, block_dh, 1)
        out = _kernel.mingru_step_kernel(xp, wzp, bzp, whp, bhp, hp,
                                         block_dh=block_dh, mode=mode,
                                         interpret=interpret)
        return out[:b, :dh]

    return call_with_flat_lead(run, (x, 1), (h_prev, 1))


def fused_minlstm_step(x: jax.Array, wf: jax.Array, bf: Optional[jax.Array],
                       wi: jax.Array, bi: Optional[jax.Array],
                       wh: jax.Array, bh: Optional[jax.Array],
                       h_prev: jax.Array, *, mode: str = "log",
                       normalize: bool = True, block_dh: int = 128,
                       interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minLSTM cell step (three projections + stable f/(f+i) normalisation
    + state update), one Pallas call.  Shapes as fused_mingru_step."""
    dh = wf.shape[1]
    if bf is None:
        bf = jnp.zeros((dh,), x.dtype)
    if bi is None:
        bi = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)

    def run(xf, hf):
        xp, hp, b = _pad_batch(xf, hf)
        xp, _ = pad_to(xp, _LANES, 1)
        ws = [pad_to(pad_to(w, _LANES, 0)[0], block_dh, 1)[0]
              for w in (wf, wi, wh)]
        bs = [pad_to(b_, block_dh, 0)[0] for b_ in (bf, bi, bh)]
        hp, _ = pad_to(hp, block_dh, 1)
        out = _kernel.minlstm_step_kernel(
            xp, ws[0], bs[0], ws[1], bs[1], ws[2], bs[2], hp,
            block_dh=block_dh, mode=mode, normalize=normalize,
            interpret=interpret)
        return out[:b, :dh]

    return call_with_flat_lead(run, (x, 1), (h_prev, 1))
