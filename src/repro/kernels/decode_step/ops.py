"""Padded public wrappers for the fused decode-step kernels.

``fused_mingru_step`` / ``fused_minlstm_step`` accept arbitrary batch
leading dims, any Dx/Dh (padded up to the kernel tile grid with zeros --
zero-padded contraction columns contribute nothing to the GEMVs, and
padded feature columns are sliced off the output), and optional biases.
No custom VJP: decode is inference-only; training/prefill differentiate
through the fused *parallel* kernels instead.

Dispatch: ``core.min_gru.step`` / ``core.min_lstm.step`` route here when
their ``scan_strategy`` resolves to ``"fused"`` (the config default
``"auto"``), which is how ``blocks.step`` -> ``lm.decode_step`` ->
``lm.superstep`` put the whole serving hot path on Pallas: the engine's
unified device loop drives prefilling (teacher-forced prompt tokens) and
decoding rows through this same kernel in the same round -- real kernels
on TPU, interpret-mode parity elsewhere.

The ``*_chunk`` wrappers serve double duty: packed prefill
(``lm.decode_chunk``) and speculative-decode verification
(``lm.decode_verify``) are the same masked varlen replay -- the chunk's
per-position states ARE the rollback table, so both callers share one
kernel and one parity contract.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_step import kernel as _kernel
from repro.kernels.scan.ops import call_with_flat_lead, pad_to

DEFAULT_INTERPRET = jax.default_backend() != "tpu"

_SUBLANES = 8     # fp32 sublane multiple; bf16 inputs are upcast in-kernel
_LANES = 128


def _pad_batch(x, h_prev):
    x, b = pad_to(x, _SUBLANES, 0)
    h_prev, _ = pad_to(h_prev, _SUBLANES, 0)
    return x, h_prev, b


def _tile(dh: int, block_dh: int, interpret: bool) -> int:
    """Force a SINGLE-tile grid under interpret mode: there the grid is
    a traced loop, so a multi-tile step kernel unrolls into straight-line
    per-tile dots that XLA merges into one fused dot -- an accumulation
    order the chunk kernels' ``fori_loop`` body cannot reproduce (the
    historical "~1 ulp on multi-tile interpret grids" caveat).  One tile
    makes step and chunk execute the identical dot on every config, so
    the step==chunk bit-exactness contract holds unconditionally.  Real
    TPU backends keep the requested ``block_dh`` streaming tile (both
    kernels run the grid tile-sequentially there, already exact)."""
    if interpret:
        return -(-dh // _LANES) * _LANES
    return block_dh


def fused_mingru_step(x: jax.Array, wz: jax.Array, bz: Optional[jax.Array],
                      wh: jax.Array, bh: Optional[jax.Array],
                      h_prev: jax.Array, *, mode: str = "log",
                      block_dh: int = 128,
                      interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minGRU cell step (projections + gates + state update), one Pallas
    call.  x: (..., Dx), h_prev: (..., Dh) -> h_t: (..., Dh)."""
    dh = wz.shape[1]
    block_dh = _tile(dh, block_dh, interpret)
    if bz is None:
        bz = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)

    def run(xf, hf):
        xp, hp, b = _pad_batch(xf, hf)
        xp, _ = pad_to(xp, _LANES, 1)
        wzp, _ = pad_to(pad_to(wz, _LANES, 0)[0], block_dh, 1)
        whp, _ = pad_to(pad_to(wh, _LANES, 0)[0], block_dh, 1)
        bzp, _ = pad_to(bz, block_dh, 0)
        bhp, _ = pad_to(bh, block_dh, 0)
        hp, _ = pad_to(hp, block_dh, 1)
        out = _kernel.mingru_step_kernel(xp, wzp, bzp, whp, bhp, hp,
                                         block_dh=block_dh, mode=mode,
                                         interpret=interpret)
        return out[:b, :dh]

    return call_with_flat_lead(run, (x, 1), (h_prev, 1))


def fused_minlstm_step(x: jax.Array, wf: jax.Array, bf: Optional[jax.Array],
                       wi: jax.Array, bi: Optional[jax.Array],
                       wh: jax.Array, bh: Optional[jax.Array],
                       h_prev: jax.Array, *, mode: str = "log",
                       normalize: bool = True, block_dh: int = 128,
                       interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minLSTM cell step (three projections + stable f/(f+i) normalisation
    + state update), one Pallas call.  Shapes as fused_mingru_step."""
    dh = wf.shape[1]
    block_dh = _tile(dh, block_dh, interpret)
    if bf is None:
        bf = jnp.zeros((dh,), x.dtype)
    if bi is None:
        bi = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)

    def run(xf, hf):
        xp, hp, b = _pad_batch(xf, hf)
        xp, _ = pad_to(xp, _LANES, 1)
        ws = [pad_to(pad_to(w, _LANES, 0)[0], block_dh, 1)[0]
              for w in (wf, wi, wh)]
        bs = [pad_to(b_, block_dh, 0)[0] for b_ in (bf, bi, bh)]
        hp, _ = pad_to(hp, block_dh, 1)
        out = _kernel.minlstm_step_kernel(
            xp, ws[0], bs[0], ws[1], bs[1], ws[2], bs[2], hp,
            block_dh=block_dh, mode=mode, normalize=normalize,
            interpret=interpret)
        return out[:b, :dh]

    return call_with_flat_lead(run, (x, 1), (h_prev, 1))


# ---------------------------------------------------------------------------
# Variable-length packed-prefill chunks (the superstep prompt-packing path)
# ---------------------------------------------------------------------------

def _chunk_pad(xf, hf, valid):
    """Shared chunk-wrapper padding: (B, C, Dx) -> time-major (C, B8,
    Dx128) plus padded h/valid (padded rows get valid=0, freezing them at
    their zero h0 -- sliced off on the way out)."""
    xp, b = pad_to(xf, _SUBLANES, 0)
    xp, _ = pad_to(xp, _LANES, 2)
    hp, _ = pad_to(hf, _SUBLANES, 0)
    vp, _ = pad_to(valid.astype(jnp.int32)[:, None], _SUBLANES, 0)
    return jnp.swapaxes(xp, 0, 1), hp, vp, b


def fused_mingru_chunk(x: jax.Array, wz: jax.Array, bz: Optional[jax.Array],
                       wh: jax.Array, bh: Optional[jax.Array],
                       h_prev: jax.Array, valid: jax.Array, *,
                       mode: str = "log", block_dh: int = 128,
                       interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """Packed varlen minGRU chunk in one Pallas call: weights stream from
    HBM once for up to C prompt tokens.  x: (..., C, Dx), h_prev:
    (..., Dh), valid: (...,) int32 in [1, C] -> hs: (..., C, Dh); row b
    freezes at ``valid[b]`` so ``hs[..., valid-1, :]`` onward is its final
    state.  Bit-identical to ``valid[b]`` sequential ``fused_mingru_step``
    calls (the packed superstep's C=1 parity contract rides on this)."""
    dh = wz.shape[1]
    block_dh = _tile(dh, block_dh, interpret)
    if bz is None:
        bz = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)

    def run(xf, hf, vf):
        chunk = xf.shape[1]
        xp, hp, vp, b = _chunk_pad(xf, hf, vf)
        wzp, _ = pad_to(pad_to(wz, _LANES, 0)[0], block_dh, 1)
        whp, _ = pad_to(pad_to(wh, _LANES, 0)[0], block_dh, 1)
        bzp, _ = pad_to(bz, block_dh, 0)
        bhp, _ = pad_to(bh, block_dh, 0)
        hp, _ = pad_to(hp, block_dh, 1)
        out = _kernel.mingru_chunk_kernel(xp, wzp, bzp, whp, bhp, hp, vp,
                                          block_dh=block_dh, mode=mode,
                                          interpret=interpret)
        return jnp.swapaxes(out, 0, 1)[:b, :chunk, :dh]

    return call_with_flat_lead(run, (x, 2), (h_prev, 1), (valid, 0))


def fused_minlstm_chunk(x: jax.Array, wf: jax.Array, bf: Optional[jax.Array],
                        wi: jax.Array, bi: Optional[jax.Array],
                        wh: jax.Array, bh: Optional[jax.Array],
                        h_prev: jax.Array, valid: jax.Array, *,
                        mode: str = "log", normalize: bool = True,
                        block_dh: int = 128,
                        interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """Packed varlen minLSTM chunk; contract as :func:`fused_mingru_chunk`
    (bit-identical to sequential ``fused_minlstm_step`` calls)."""
    dh = wf.shape[1]
    block_dh = _tile(dh, block_dh, interpret)
    if bf is None:
        bf = jnp.zeros((dh,), x.dtype)
    if bi is None:
        bi = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)

    def run(xf, hf, vf):
        chunk = xf.shape[1]
        xp, hp, vp, b = _chunk_pad(xf, hf, vf)
        ws = [pad_to(pad_to(w, _LANES, 0)[0], block_dh, 1)[0]
              for w in (wf, wi, wh)]
        bs = [pad_to(b_, block_dh, 0)[0] for b_ in (bf, bi, bh)]
        hp, _ = pad_to(hp, block_dh, 1)
        out = _kernel.minlstm_chunk_kernel(
            xp, ws[0], bs[0], ws[1], bs[1], ws[2], bs[2], hp, vp,
            block_dh=block_dh, mode=mode, normalize=normalize,
            interpret=interpret)
        return jnp.swapaxes(out, 0, 1)[:b, :chunk, :dh]

    return call_with_flat_lead(run, (x, 2), (h_prev, 1), (valid, 0))
