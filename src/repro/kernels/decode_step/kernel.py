"""Pallas TPU kernel: fused minGRU/minLSTM single-token decode step.

Decode rolls the O(1) recurrence one token at a time, so the per-step
compute is a *batched GEMV*: x_t (B, Dx) against the gate projections
(Dx, Dh) followed by a handful of elementwise VPU ops.  Unfused, XLA
materialises the gate pre-activations k/v (B, Dh) in HBM between the
matmul and the state update and launches one fusion per projection; at
decode batch sizes the step is weight-bound, so every extra HBM
round-trip and launch is pure latency on the serving hot path.

This kernel runs the whole cell step in ONE pallas_call per layer:

  * both (minGRU) / all three (minLSTM) projections on the MXU from a
    single resident (B, Dx) input tile;
  * the sigmoid / g() gate transforms, the numerically stable minLSTM
    f/(f+i) normalisation (Algorithm 8 exponentiated -- naive division
    NaNs at saturated gates), and the convex state update
    h = a * h_prev + b on the VPU;
  * only the new h (B, Dh) is written back.

Grid = (Dh tiles,): the x tile is pinned by its index_map so Mosaic
keeps it resident across feature tiles, and the weight tiles stream
through VMEM once per step.  The layer stack is dispatched as ONE
lax.scan over stacked weights by ``models/lm.decode_step`` (the weights
stay device-resident across the whole multi-token decode loop -- the
weight-stationary serving regime), and ``lm.superstep`` wraps that step
in a second on-device scan so K rounds -- prefilling and decoding slots
alike -- cost one host round-trip.

All arithmetic is fp32 in-kernel regardless of input dtype (matching
the fused parallel kernels, so prefill -> decode handoff is consistent);
bf16 inputs are upcast on load and the output is cast back.

The ``*_chunk_kernel`` variants amortise the weight stream over a packed
prompt chunk: one pallas_call keeps the gate weight tiles VMEM-resident
while a ``fori_loop`` replays up to C per-token step updates with
per-row ``valid``-length freezing -- the serving superstep's prompt
*packing* path (C prompt tokens per weight stream instead of 1 in the
weight-bound regime), bit-identical to C sequential step-kernel calls.
The SAME chunk variants are the speculative-decoding *verify* primitive
(``lm.decode_verify``): they emit the recurrent state after every
position, so accepting a leading run of drafts and rolling back to the
first rejection is one O(d_hidden) gather per slot -- no extra kernel,
no recompute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import min_lstm, nn


def _mingru_step_kernel(x_ref, wz_ref, bz_ref, wh_ref, bh_ref, h_ref,
                        o_ref, *, mode: str):
    x = x_ref[...].astype(jnp.float32)                    # (B, Dx)
    wz = wz_ref[...].astype(jnp.float32)                  # (Dx, bdh)
    wh = wh_ref[...].astype(jnp.float32)
    bz = bz_ref[...].astype(jnp.float32)
    bh = bh_ref[...].astype(jnp.float32)
    k = jnp.dot(x, wz, preferred_element_type=jnp.float32) + bz
    v = jnp.dot(x, wh, preferred_element_type=jnp.float32) + bh
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    h_prev = h_ref[...].astype(jnp.float32)               # (B, bdh)
    o_ref[...] = ((1.0 - z) * h_prev + z * h_tilde).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_dh", "mode", "interpret"))
def mingru_step_kernel(x: jax.Array, wz: jax.Array, bz: jax.Array,
                       wh: jax.Array, bh: jax.Array, h_prev: jax.Array,
                       *, block_dh: int = 128, mode: str = "log",
                       interpret: bool = True) -> jax.Array:
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh).  Dh % block_dh == 0
    and Dx % 128 == 0 (ops.py pads); B padded to a sublane multiple."""
    bsz, dx = x.shape
    dh = wz.shape[1]
    assert dh % block_dh == 0, (dh, block_dh)
    grid = (dh // block_dh,)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))

    return pl.pallas_call(
        functools.partial(_mingru_step_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, dx), lambda j: (0, 0)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dh), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, wz, bz, wh, bh, h_prev)


def _mingru_chunk_kernel(x_ref, wz_ref, bz_ref, wh_ref, bh_ref, h_ref,
                         valid_ref, o_ref, *, mode: str, chunk: int):
    """Variable-length C-token chunk: the weight tiles stay VMEM-resident
    while a ``fori_loop`` replays the *exact* per-token arithmetic of
    ``_mingru_step_kernel`` (same (B, Dx) @ (Dx, bdh) dot per token, same
    gate ops, same per-token cast to the output dtype), so a packed chunk
    is bit-identical to ``chunk`` sequential step-kernel calls -- while
    streaming the gate weights from HBM once instead of ``chunk`` times.
    Bit-exactness holds per feature tile on every backend: real TPU runs
    both kernels' grids tile-sequentially, and under interpret mode
    ops.py forces a single-tile grid (``_tile``), so step and chunk
    always execute the identical dot -- multi-tile configs included.
    Rows freeze once ``t >= valid[b]``: the update is masked and the
    frozen h is re-written, so ``o[valid[b]-1:]`` all hold the row's
    final state (the caller reads position ``valid[b]-1``)."""
    wz = wz_ref[...].astype(jnp.float32)                  # (Dx, bdh)
    wh = wh_ref[...].astype(jnp.float32)
    bz = bz_ref[...].astype(jnp.float32)
    bh = bh_ref[...].astype(jnp.float32)
    valid = valid_ref[...]                                # (B, 1) int32

    def body(t, h):
        x = x_ref[t].astype(jnp.float32)                  # (B, Dx)
        k = jnp.dot(x, wz, preferred_element_type=jnp.float32) + bz
        v = jnp.dot(x, wh, preferred_element_type=jnp.float32) + bh
        z = jax.nn.sigmoid(k)
        h_tilde = nn.g(v) if mode == "log" else v
        h_new = (1.0 - z) * h + z * h_tilde
        # per-token round-trip through the output dtype: sequential steps
        # re-read h from a cdtype cache, so the packed carry must quantize
        # identically for bf16 bit-exactness
        h_new = h_new.astype(o_ref.dtype).astype(jnp.float32)
        h = jnp.where(t < valid, h_new, h)
        o_ref[t] = h.astype(o_ref.dtype)
        return h

    jax.lax.fori_loop(0, chunk, body,
                      h_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_dh", "mode", "interpret"))
def mingru_chunk_kernel(x: jax.Array, wz: jax.Array, bz: jax.Array,
                        wh: jax.Array, bh: jax.Array, h_prev: jax.Array,
                        valid: jax.Array, *, block_dh: int = 128,
                        mode: str = "log", interpret: bool = True
                        ) -> jax.Array:
    """x: (C, B, Dx) time-major, h_prev: (B, Dh), valid: (B, 1) int32 ->
    hs: (C, B, Dh).  Same tiling contract as :func:`mingru_step_kernel`;
    C rides the untiled leading axis so the in-kernel time index is a
    cheap leading-dim dynamic slice."""
    chunk, bsz, dx = x.shape
    dh = wz.shape[1]
    assert dh % block_dh == 0, (dh, block_dh)
    grid = (dh // block_dh,)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))

    return pl.pallas_call(
        functools.partial(_mingru_chunk_kernel, mode=mode, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, bsz, dx), lambda j: (0, 0, 0)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
            pl.BlockSpec((bsz, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, bsz, block_dh), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((chunk, bsz, dh), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, wz, bz, wh, bh, h_prev, valid)


def _minlstm_step_kernel(x_ref, wf_ref, bf_ref, wi_ref, bi_ref, wh_ref,
                         bh_ref, h_ref, o_ref, *, mode: str,
                         normalize: bool):
    x = x_ref[...].astype(jnp.float32)                    # (B, Dx)
    wf = wf_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    kf = jnp.dot(x, wf, preferred_element_type=jnp.float32) \
        + bf_ref[...].astype(jnp.float32)
    ki = jnp.dot(x, wi, preferred_element_type=jnp.float32) \
        + bi_ref[...].astype(jnp.float32)
    v = jnp.dot(x, wh, preferred_element_type=jnp.float32) \
        + bh_ref[...].astype(jnp.float32)
    if normalize:
        # stable f/(f+i) -- the naive quotient is 0/0 = NaN at saturated
        # gates; same in-kernel call as kernels/fused_minlstm
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    h_tilde = nn.g(v) if mode == "log" else v
    h_prev = h_ref[...].astype(jnp.float32)
    o_ref[...] = (f * h_prev + i * h_tilde).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_dh", "mode", "normalize",
                                             "interpret"))
def minlstm_step_kernel(x: jax.Array, wf: jax.Array, bf: jax.Array,
                        wi: jax.Array, bi: jax.Array, wh: jax.Array,
                        bh: jax.Array, h_prev: jax.Array,
                        *, block_dh: int = 128, mode: str = "log",
                        normalize: bool = True,
                        interpret: bool = True) -> jax.Array:
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh).  Same tiling contract
    as :func:`mingru_step_kernel`."""
    bsz, dx = x.shape
    dh = wf.shape[1]
    assert dh % block_dh == 0, (dh, block_dh)
    grid = (dh // block_dh,)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))

    return pl.pallas_call(
        functools.partial(_minlstm_step_kernel, mode=mode,
                          normalize=normalize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, dx), lambda j: (0, 0)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dh), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, wf, bf, wi, bi, wh, bh, h_prev)


def _minlstm_chunk_kernel(x_ref, wf_ref, bf_ref, wi_ref, bi_ref, wh_ref,
                          bh_ref, h_ref, valid_ref, o_ref, *, mode: str,
                          normalize: bool, chunk: int):
    """minLSTM sibling of ``_mingru_chunk_kernel``: weights resident, one
    ``fori_loop`` of bit-exact ``_minlstm_step_kernel`` token updates with
    per-row ``valid`` freezing."""
    wf = wf_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    bf = bf_ref[...].astype(jnp.float32)
    bi = bi_ref[...].astype(jnp.float32)
    bh = bh_ref[...].astype(jnp.float32)
    valid = valid_ref[...]                                # (B, 1) int32

    def body(t, h):
        x = x_ref[t].astype(jnp.float32)                  # (B, Dx)
        kf = jnp.dot(x, wf, preferred_element_type=jnp.float32) + bf
        ki = jnp.dot(x, wi, preferred_element_type=jnp.float32) + bi
        v = jnp.dot(x, wh, preferred_element_type=jnp.float32) + bh
        if normalize:
            f, i = min_lstm.normalized_gates(kf, ki)
        else:
            f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
        h_tilde = nn.g(v) if mode == "log" else v
        h_new = (f * h + i * h_tilde).astype(o_ref.dtype).astype(jnp.float32)
        h = jnp.where(t < valid, h_new, h)
        o_ref[t] = h.astype(o_ref.dtype)
        return h

    jax.lax.fori_loop(0, chunk, body,
                      h_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_dh", "mode", "normalize",
                                             "interpret"))
def minlstm_chunk_kernel(x: jax.Array, wf: jax.Array, bf: jax.Array,
                         wi: jax.Array, bi: jax.Array, wh: jax.Array,
                         bh: jax.Array, h_prev: jax.Array, valid: jax.Array,
                         *, block_dh: int = 128, mode: str = "log",
                         normalize: bool = True,
                         interpret: bool = True) -> jax.Array:
    """x: (C, B, Dx) time-major, h_prev: (B, Dh), valid: (B, 1) int32 ->
    hs: (C, B, Dh).  Same contract as :func:`mingru_chunk_kernel`."""
    chunk, bsz, dx = x.shape
    dh = wf.shape[1]
    assert dh % block_dh == 0, (dh, block_dh)
    grid = (dh // block_dh,)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))

    return pl.pallas_call(
        functools.partial(_minlstm_chunk_kernel, mode=mode,
                          normalize=normalize, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, bsz, dx), lambda j: (0, 0, 0)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
            pl.BlockSpec((bsz, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, bsz, block_dh), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((chunk, bsz, dh), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, wf, bf, wi, bi, wh, bh, h_prev, valid)
