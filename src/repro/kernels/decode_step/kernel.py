"""Pallas TPU kernel: fused minGRU/minLSTM single-token decode step.

Decode rolls the O(1) recurrence one token at a time, so the per-step
compute is a *batched GEMV*: x_t (B, Dx) against the gate projections
(Dx, Dh) followed by a handful of elementwise VPU ops.  Unfused, XLA
materialises the gate pre-activations k/v (B, Dh) in HBM between the
matmul and the state update and launches one fusion per projection; at
decode batch sizes the step is weight-bound, so every extra HBM
round-trip and launch is pure latency on the serving hot path.

This kernel runs the whole cell step in ONE pallas_call per layer:

  * both (minGRU) / all three (minLSTM) projections on the MXU from a
    single resident (B, Dx) input tile;
  * the sigmoid / g() gate transforms, the numerically stable minLSTM
    f/(f+i) normalisation (Algorithm 8 exponentiated -- naive division
    NaNs at saturated gates), and the convex state update
    h = a * h_prev + b on the VPU;
  * only the new h (B, Dh) is written back.

Grid = (Dh tiles,): the x tile is pinned by its index_map so Mosaic
keeps it resident across feature tiles, and the weight tiles stream
through VMEM once per step.  The layer stack is dispatched as ONE
lax.scan over stacked weights by ``models/lm.decode_step`` (the weights
stay device-resident across the whole multi-token decode loop -- the
weight-stationary serving regime), and ``lm.superstep`` wraps that step
in a second on-device scan so K rounds -- prefilling and decoding slots
alike -- cost one host round-trip.

All arithmetic is fp32 in-kernel regardless of input dtype (matching
the fused parallel kernels, so prefill -> decode handoff is consistent);
bf16 inputs are upcast on load and the output is cast back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import min_lstm, nn


def _mingru_step_kernel(x_ref, wz_ref, bz_ref, wh_ref, bh_ref, h_ref,
                        o_ref, *, mode: str):
    x = x_ref[...].astype(jnp.float32)                    # (B, Dx)
    wz = wz_ref[...].astype(jnp.float32)                  # (Dx, bdh)
    wh = wh_ref[...].astype(jnp.float32)
    bz = bz_ref[...].astype(jnp.float32)
    bh = bh_ref[...].astype(jnp.float32)
    k = jnp.dot(x, wz, preferred_element_type=jnp.float32) + bz
    v = jnp.dot(x, wh, preferred_element_type=jnp.float32) + bh
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    h_prev = h_ref[...].astype(jnp.float32)               # (B, bdh)
    o_ref[...] = ((1.0 - z) * h_prev + z * h_tilde).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_dh", "mode", "interpret"))
def mingru_step_kernel(x: jax.Array, wz: jax.Array, bz: jax.Array,
                       wh: jax.Array, bh: jax.Array, h_prev: jax.Array,
                       *, block_dh: int = 128, mode: str = "log",
                       interpret: bool = True) -> jax.Array:
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh).  Dh % block_dh == 0
    and Dx % 128 == 0 (ops.py pads); B padded to a sublane multiple."""
    bsz, dx = x.shape
    dh = wz.shape[1]
    assert dh % block_dh == 0, (dh, block_dh)
    grid = (dh // block_dh,)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))

    return pl.pallas_call(
        functools.partial(_mingru_step_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, dx), lambda j: (0, 0)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dh), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, wz, bz, wh, bh, h_prev)


def _minlstm_step_kernel(x_ref, wf_ref, bf_ref, wi_ref, bi_ref, wh_ref,
                         bh_ref, h_ref, o_ref, *, mode: str,
                         normalize: bool):
    x = x_ref[...].astype(jnp.float32)                    # (B, Dx)
    wf = wf_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    kf = jnp.dot(x, wf, preferred_element_type=jnp.float32) \
        + bf_ref[...].astype(jnp.float32)
    ki = jnp.dot(x, wi, preferred_element_type=jnp.float32) \
        + bi_ref[...].astype(jnp.float32)
    v = jnp.dot(x, wh, preferred_element_type=jnp.float32) \
        + bh_ref[...].astype(jnp.float32)
    if normalize:
        # stable f/(f+i) -- the naive quotient is 0/0 = NaN at saturated
        # gates; same in-kernel call as kernels/fused_minlstm
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    h_tilde = nn.g(v) if mode == "log" else v
    h_prev = h_ref[...].astype(jnp.float32)
    o_ref[...] = (f * h_prev + i * h_tilde).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_dh", "mode", "normalize",
                                             "interpret"))
def minlstm_step_kernel(x: jax.Array, wf: jax.Array, bf: jax.Array,
                        wi: jax.Array, bi: jax.Array, wh: jax.Array,
                        bh: jax.Array, h_prev: jax.Array,
                        *, block_dh: int = 128, mode: str = "log",
                        normalize: bool = True,
                        interpret: bool = True) -> jax.Array:
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh).  Same tiling contract
    as :func:`mingru_step_kernel`."""
    bsz, dx = x.shape
    dh = wf.shape[1]
    assert dh % block_dh == 0, (dh, block_dh)
    grid = (dh // block_dh,)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",))

    return pl.pallas_call(
        functools.partial(_minlstm_step_kernel, mode=mode,
                          normalize=normalize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, dx), lambda j: (0, 0)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((dx, block_dh), lambda j: (0, j)),
            pl.BlockSpec((block_dh,), lambda j: (j,)),
            pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bsz, block_dh), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dh), x.dtype),
        interpret=interpret,
        **kwargs,
    )(x, wf, bf, wi, bi, wh, bh, h_prev)
