"""Pure-jnp oracles for the fused decode-step kernels.

Same math as kernel.py (fp32 internal compute, output cast to the input
dtype) with no Pallas machinery -- the parity tests diff the kernel
against these, and they double as readable documentation of exactly what
the kernel computes.

The chunk oracles re-emit each row's carried state at every position
past ``valid`` (frozen rows repeat their final state), which is the
invariant the speculative verify path leans on: gathering the state at
any committed position is exact whether or not the row advanced there.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import min_lstm, nn


def mingru_step_ref(x, wz, bz, wh, bh, h_prev, *, mode: str = "log"):
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh)."""
    x32 = x.astype(jnp.float32)
    k = x32 @ wz.astype(jnp.float32) + bz.astype(jnp.float32)
    v = x32 @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    h = (1.0 - z) * h_prev.astype(jnp.float32) + z * h_tilde
    return h.astype(x.dtype)


def minlstm_step_ref(x, wf, bf, wi, bi, wh, bh, h_prev, *,
                     mode: str = "log", normalize: bool = True):
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh)."""
    x32 = x.astype(jnp.float32)
    kf = x32 @ wf.astype(jnp.float32) + bf.astype(jnp.float32)
    ki = x32 @ wi.astype(jnp.float32) + bi.astype(jnp.float32)
    v = x32 @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    if normalize:
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    h_tilde = nn.g(v) if mode == "log" else v
    h = f * h_prev.astype(jnp.float32) + i * h_tilde
    return h.astype(x.dtype)


def _chunk_scan(step_one, x, h_prev, valid):
    """Shared varlen chunk recurrence: apply ``step_one`` per token and
    freeze row b once ``t >= valid[b]`` (the frozen h is re-emitted, so
    every position >= valid-1 holds the row's final state)."""
    chunk = x.shape[1]

    def body(h, inp):
        x_t, t = inp
        h_new = step_one(x_t, h)
        h = jnp.where((t < valid)[:, None], h_new, h).astype(h.dtype)
        return h, h

    _, hs = jax.lax.scan(
        body, h_prev, (jnp.moveaxis(x, 1, 0), jnp.arange(chunk)))
    return jnp.moveaxis(hs, 0, 1)


def mingru_chunk_ref(x, wz, bz, wh, bh, h_prev, valid, *,
                     mode: str = "log"):
    """Varlen chunk oracle.  x: (B, C, Dx), h_prev: (B, Dh), valid: (B,)
    int32 in [1, C] -> hs: (B, C, Dh): ``valid[b]`` masked sequential
    ``mingru_step_ref`` updates, rows frozen beyond their valid length."""
    return _chunk_scan(
        lambda x_t, h: mingru_step_ref(x_t, wz, bz, wh, bh, h, mode=mode),
        x, h_prev, valid)


def minlstm_chunk_ref(x, wf, bf, wi, bi, wh, bh, h_prev, valid, *,
                      mode: str = "log", normalize: bool = True):
    """Varlen chunk oracle, minLSTM.  Shapes as :func:`mingru_chunk_ref`."""
    return _chunk_scan(
        lambda x_t, h: minlstm_step_ref(x_t, wf, bf, wi, bi, wh, bh, h,
                                        mode=mode, normalize=normalize),
        x, h_prev, valid)
