"""Pure-jnp oracles for the fused decode-step kernels.

Same math as kernel.py (fp32 internal compute, output cast to the input
dtype) with no Pallas machinery -- the parity tests diff the kernel
against these, and they double as readable documentation of exactly what
the kernel computes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import min_lstm, nn


def mingru_step_ref(x, wz, bz, wh, bh, h_prev, *, mode: str = "log"):
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh)."""
    x32 = x.astype(jnp.float32)
    k = x32 @ wz.astype(jnp.float32) + bz.astype(jnp.float32)
    v = x32 @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    h = (1.0 - z) * h_prev.astype(jnp.float32) + z * h_tilde
    return h.astype(x.dtype)


def minlstm_step_ref(x, wf, bf, wi, bi, wh, bh, h_prev, *,
                     mode: str = "log", normalize: bool = True):
    """x: (B, Dx), h_prev: (B, Dh) -> h_t: (B, Dh)."""
    x32 = x.astype(jnp.float32)
    kf = x32 @ wf.astype(jnp.float32) + bf.astype(jnp.float32)
    ki = x32 @ wi.astype(jnp.float32) + bi.astype(jnp.float32)
    v = x32 @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    if normalize:
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    h_tilde = nn.g(v) if mode == "log" else v
    h = f * h_prev.astype(jnp.float32) + i * h_tilde
    return h.astype(x.dtype)
