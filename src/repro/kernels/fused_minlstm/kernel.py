"""Pallas TPU kernel: fused minLSTM (three gate projections + scan).

Sibling of ``kernels/fused_mingru``: unfused, XLA materialises the gate
activations kf, ki, v: (B, T, 3*Dh) in HBM between the matmuls and the
scan.  This kernel keeps the (bt, Dx) input tile and the three (Dx, bdh)
weight tiles in VMEM, runs the projections on the MXU, applies the
sigmoid / normalisation / g() gates and the Kogge-Stone scan on the VPU,
and writes only h.

The paper's length-independence normalisation (Section 3.2) is computed
in-kernel: f' = f/(f+i), i' = i/(f+i), then h_t = f' h_{t-1} + i' h~_t.
VMEM budget per block (fp32): bt*Dx + 3*Dx*bdh + 4*bt*bdh floats -- one
more weight tile than the minGRU kernel, still comfortably inside 16 MB
for the paper's LM shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import min_lstm, nn
from repro.kernels.scan.kernel import _kogge_stone


def _fused_kernel(x_ref, wf_ref, bf_ref, wi_ref, bi_ref, wh_ref, bh_ref,
                  h0_ref, o_ref, carry_ref, *, mode: str, normalize: bool):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(carry_ref.dtype)

    x = x_ref[0].astype(jnp.float32)                      # (bt, Dx)
    wf = wf_ref[...].astype(jnp.float32)                  # (Dx, bdh)
    wi = wi_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    kf = (jnp.dot(x, wf, preferred_element_type=jnp.float32)
          + bf_ref[...].astype(jnp.float32))
    ki = (jnp.dot(x, wi, preferred_element_type=jnp.float32)
          + bi_ref[...].astype(jnp.float32))
    v = (jnp.dot(x, wh, preferred_element_type=jnp.float32)
         + bh_ref[...].astype(jnp.float32))
    if normalize:
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    if mode == "log":
        h_tilde = nn.g(v)
    else:
        h_tilde = v
    A, B = _kogge_stone(f, i * h_tilde)
    h = B + A * carry_ref[...]
    o_ref[0, ...] = h.astype(o_ref.dtype)
    carry_ref[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("block_t", "block_dh", "mode",
                                             "normalize", "interpret"))
def fused_minlstm_kernel(x: jax.Array, wf: jax.Array, bf: jax.Array,
                         wi: jax.Array, bi: jax.Array,
                         wh: jax.Array, bh: jax.Array, h0: jax.Array,
                         *, block_t: int = 256, block_dh: int = 128,
                         mode: str = "log", normalize: bool = True,
                         interpret: bool = True):
    """x: (B, T, Dx) -> h: (B, T, Dh).  T % block_t == 0, Dh % block_dh == 0."""
    bsz, t, dx = x.shape
    dh = wf.shape[1]
    assert t % block_t == 0 and dh % block_dh == 0, (t, dh)
    grid = (bsz, dh // block_dh, t // block_t)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_fused_kernel, mode=mode, normalize=normalize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, dx), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((dx, block_dh), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_dh,), lambda i, j, k: (j,)),
            pl.BlockSpec((dx, block_dh), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_dh,), lambda i, j, k: (j,)),
            pl.BlockSpec((dx, block_dh), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_dh,), lambda i, j, k: (j,)),
            pl.BlockSpec((1, block_dh), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_dh),
                               lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_dh), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, wf, bf, wi, bi, wh, bh, h0)
