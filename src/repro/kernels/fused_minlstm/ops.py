"""Jitted wrapper for the fused minLSTM kernel, with a custom VJP.

Mirrors ``kernels/fused_mingru/ops.py``: the forward is one Pallas call
(three MXU projections + VPU gates + chunked scan, only h leaves VMEM);
the backward's sequential piece is the reversed Pallas linear-scan kernel

    g_t = dL/dh_t + f'_{t+1} g_{t+1}

and the gate/projection gradients (dWf/dWi/dWh/dx/db*, including the
f' = f/(f+i) normalisation jacobian) come from XLA's vjp of the
rematerialised fp32 gate computation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import min_lstm, nn
from repro.kernels.fused_minlstm import kernel as _kernel
from repro.kernels.scan import ops as scan_ops

DEFAULT_INTERPRET = jax.default_backend() != "tpu"


def _run(x, wf, bf, wi, bi, wh, bh, h0, mode, normalize, block_t, block_dh,
         interpret):
    """Pad T to the time tile and Dh to the feature tile, run, slice."""
    t, dh = x.shape[1], wf.shape[1]
    bt = scan_ops.round_block_t(block_t, t)
    x, _ = scan_ops.pad_to(x, bt, 1)
    wf, _ = scan_ops.pad_to(wf, block_dh, 1)
    wi, _ = scan_ops.pad_to(wi, block_dh, 1)
    wh, _ = scan_ops.pad_to(wh, block_dh, 1)
    bf, _ = scan_ops.pad_to(bf, block_dh, 0)
    bi, _ = scan_ops.pad_to(bi, block_dh, 0)
    bh, _ = scan_ops.pad_to(bh, block_dh, 0)
    h0, _ = scan_ops.pad_to(h0, block_dh, 1)
    out = _kernel.fused_minlstm_kernel(x, wf, bf, wi, bi, wh, bh, h0,
                                       block_t=bt, block_dh=block_dh,
                                       mode=mode, normalize=normalize,
                                       interpret=interpret)
    return out[:, :t, :dh]


def _gates_fp32(x, wf, bf, wi, bi, wh, bh, mode, normalize):
    """Rematerialised (a, b) scan inputs, fp32 (kernel-internal dtype)."""
    x32 = x.astype(jnp.float32)
    kf = x32 @ wf.astype(jnp.float32) + bf.astype(jnp.float32)
    ki = x32 @ wi.astype(jnp.float32) + bi.astype(jnp.float32)
    v = x32 @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    if normalize:
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    if mode == "log":
        h_tilde = nn.g(v)
    else:
        h_tilde = v
    return f, i * h_tilde


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11, 12))
def _fused_minlstm(x, wf, bf, wi, bi, wh, bh, h0, mode, normalize, block_t,
                   block_dh, interpret):
    return _run(x, wf, bf, wi, bi, wh, bh, h0, mode, normalize, block_t,
                block_dh, interpret)


def _fwd(x, wf, bf, wi, bi, wh, bh, h0, mode, normalize, block_t, block_dh,
         interpret):
    h = _run(x, wf, bf, wi, bi, wh, bh, h0, mode, normalize, block_t,
             block_dh, interpret)
    return h, (x, wf, bf, wi, bi, wh, bh, h0, h)


def _bwd(mode, normalize, block_t, block_dh, interpret, res, dh):
    x, wf, bf, wi, bi, wh, bh, h0, h = res
    gates = functools.partial(_gates_fp32, mode=mode, normalize=normalize)
    (a, _), pull = jax.vjp(gates, x, wf, bf, wi, bi, wh, bh)
    g, h_prev, dh0 = scan_ops.reverse_scan_grads(
        a, dh.astype(jnp.float32), h.astype(jnp.float32),
        h0.astype(jnp.float32), block_t, block_dh, interpret)
    dx, dwf, dbf, dwi, dbi, dwh, dbh = pull((g * h_prev, g))
    return dx, dwf, dbf, dwi, dbi, dwh, dbh, dh0.astype(h0.dtype)


_fused_minlstm.defvjp(_fwd, _bwd)


def fused_minlstm(x: jax.Array, wf: jax.Array, bf: Optional[jax.Array],
                  wi: jax.Array, bi: Optional[jax.Array],
                  wh: jax.Array, bh: Optional[jax.Array],
                  h0: Optional[jax.Array] = None, *, mode: str = "log",
                  normalize: bool = True, block_t: int = 256,
                  block_dh: int = 128,
                  interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minLSTM layer forward (projections + recurrence) in one Pallas call.

    Differentiable in x, the three weight/bias pairs and h0.
    """
    bsz = x.shape[0]
    dh = wf.shape[1]
    if bf is None:
        bf = jnp.zeros((dh,), x.dtype)
    if bi is None:
        bi = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)
    if h0 is None:
        h0 = jnp.zeros((bsz, dh), x.dtype)
    return _fused_minlstm(x, wf, bf, wi, bi, wh, bh, h0, mode, normalize,
                          block_t, block_dh, interpret)
