"""Pure-jnp oracle for the fused minLSTM gate-projection + scan kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import min_lstm, nn


def fused_minlstm_ref(x: jax.Array, wf: jax.Array, bf: jax.Array,
                      wi: jax.Array, bi: jax.Array,
                      wh: jax.Array, bh: jax.Array,
                      h0: Optional[jax.Array] = None,
                      mode: str = "log", normalize: bool = True) -> jax.Array:
    """minLSTM layer forward: projections + recurrence, unfused reference.

    x: (B, T, Dx); wf, wi, wh: (Dx, Dh); bf, bi, bh: (Dh,); h0: (B, Dh).
    """
    kf = x @ wf + bf
    ki = x @ wi + bi
    v = x @ wh + bh
    if normalize:
        f, i = min_lstm.normalized_gates(kf, ki)
    else:
        f, i = jax.nn.sigmoid(kf), jax.nn.sigmoid(ki)
    h_tilde = nn.g(v) if mode == "log" else v
    a = f
    b = i * h_tilde
    if h0 is None:
        h0 = jnp.zeros(x.shape[:-2] + (wf.shape[1],), b.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(a, -2, 0), jnp.moveaxis(b, -2, 0)))
    return jnp.moveaxis(hs, 0, -2)
