"""Jitted wrapper for the fused minGRU kernel, with a custom VJP.

Forward (training, prefill, serving): one Pallas call runs both gate
projections on the MXU and the chunked scan on the VPU, writing only h --
the k, v: (B, T, Dh) gate activations never round-trip through HBM.

Backward: ``custom_vjp`` whose heavy sequential piece is the *same* Pallas
chunked-scan kernel reversed,

    g_t = dL/dh_t + (1 - z_{t+1}) g_{t+1}       (reverse linear scan)
    dL/da_t = g_t * h_{t-1},  dL/db_t = g_t      with (a, b) = (1-z, z*h~)

followed by the transposed projection matmuls (dWz/dWh/dx/db*), which XLA
derives from the rematerialised gate computation -- so forward AND backward
of the default training hot path run through Pallas (interpret mode
off-TPU).  The gate pre-activations are recomputed from x in the backward
(two matmuls, standard rematerialisation) rather than saved, keeping the
forward's HBM win.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nn
from repro.kernels.fused_mingru import kernel as _kernel
from repro.kernels.scan import ops as scan_ops

DEFAULT_INTERPRET = jax.default_backend() != "tpu"


def _run(x, wz, bz, wh, bh, h0, mode, block_t, block_dh, interpret):
    """Pad T to the time tile and Dh to the feature tile, run, slice."""
    t, dh = x.shape[1], wz.shape[1]
    bt = scan_ops.round_block_t(block_t, t)
    x, _ = scan_ops.pad_to(x, bt, 1)
    wz, _ = scan_ops.pad_to(wz, block_dh, 1)
    wh, _ = scan_ops.pad_to(wh, block_dh, 1)
    bz, _ = scan_ops.pad_to(bz, block_dh, 0)
    bh, _ = scan_ops.pad_to(bh, block_dh, 0)
    h0, _ = scan_ops.pad_to(h0, block_dh, 1)
    out = _kernel.fused_mingru_kernel(x, wz, bz, wh, bh, h0, block_t=bt,
                                      block_dh=block_dh, mode=mode,
                                      interpret=interpret)
    return out[:, :t, :dh]


def _gates_fp32(x, wz, bz, wh, bh, mode):
    """Rematerialised (a, b) scan inputs, fp32 (matches the kernel's
    internal compute dtype so backward residuals agree with forward)."""
    x32 = x.astype(jnp.float32)
    k = x32 @ wz.astype(jnp.float32) + bz.astype(jnp.float32)
    v = x32 @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    z = jax.nn.sigmoid(k)
    if mode == "log":
        h_tilde = nn.g(v)
    else:
        h_tilde = v
    return 1.0 - z, z * h_tilde


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _fused_mingru(x, wz, bz, wh, bh, h0, mode, block_t, block_dh, interpret):
    return _run(x, wz, bz, wh, bh, h0, mode, block_t, block_dh, interpret)


def _fwd(x, wz, bz, wh, bh, h0, mode, block_t, block_dh, interpret):
    h = _run(x, wz, bz, wh, bh, h0, mode, block_t, block_dh, interpret)
    return h, (x, wz, bz, wh, bh, h0, h)


def _bwd(mode, block_t, block_dh, interpret, res, dh):
    x, wz, bz, wh, bh, h0, h = res
    gates = functools.partial(_gates_fp32, mode=mode)
    (a, _), pull = jax.vjp(gates, x, wz, bz, wh, bh)
    g, h_prev, dh0 = scan_ops.reverse_scan_grads(
        a, dh.astype(jnp.float32), h.astype(jnp.float32),
        h0.astype(jnp.float32), block_t, block_dh, interpret)
    dx, dwz, dbz, dwh, dbh = pull((g * h_prev, g))
    return dx, dwz, dbz, dwh, dbh, dh0.astype(h0.dtype)


_fused_mingru.defvjp(_fwd, _bwd)


def fused_mingru(x: jax.Array, wz: jax.Array, bz: Optional[jax.Array],
                 wh: jax.Array, bh: Optional[jax.Array],
                 h0: Optional[jax.Array] = None, *, mode: str = "log",
                 block_t: int = 256, block_dh: int = 128,
                 interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minGRU layer forward (projections + recurrence) in one Pallas call.

    Differentiable in x, wz, bz, wh, bh and h0 (carried state, so chunked
    prefill / TBPTT can backprop into the incoming carry).
    """
    bsz, _, _ = x.shape
    dh = wz.shape[1]
    if bz is None:
        bz = jnp.zeros((dh,), x.dtype)
    if bh is None:
        bh = jnp.zeros((dh,), x.dtype)
    if h0 is None:
        h0 = jnp.zeros((bsz, dh), x.dtype)
    return _fused_mingru(x, wz, bz, wh, bh, h0, mode, block_t, block_dh,
                         interpret)
