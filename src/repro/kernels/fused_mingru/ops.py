"""Jitted wrapper for the fused minGRU kernel.

Forward/prefill-serving hot path.  For training we use the (differentiable)
``repro.kernels.scan.ops.linear_scan`` with XLA matmuls for the projections:
the fused kernel's weight gradients would need a second (transposed) matmul
pass that XLA already schedules optimally, so fusing buys nothing on the
backward -- see EXPERIMENTS.md §Perf for the measured forward win.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_mingru import kernel as _kernel

DEFAULT_INTERPRET = jax.default_backend() != "tpu"


def fused_mingru(x: jax.Array, wz: jax.Array, bz: Optional[jax.Array],
                 wh: jax.Array, bh: Optional[jax.Array],
                 h0: Optional[jax.Array] = None, *, mode: str = "log",
                 block_t: int = 256, block_dh: int = 128,
                 interpret: bool = DEFAULT_INTERPRET) -> jax.Array:
    """minGRU layer forward (projections + recurrence) in one Pallas call."""
    bsz, t, dx = x.shape
    dh = wz.shape[1]
    if bz is None:
        bz = jnp.zeros((dh,), jnp.float32)
    if bh is None:
        bh = jnp.zeros((dh,), jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, dh), x.dtype)

    # pad T to the time tile and Dh to the feature tile
    bt = min(block_t, max(8, 1 << (t - 1).bit_length()))
    pt = (-t) % bt
    if pt:
        x = jnp.pad(x, ((0, 0), (0, pt), (0, 0)))
    pd = (-dh) % block_dh
    if pd:
        wz = jnp.pad(wz, ((0, 0), (0, pd)))
        wh = jnp.pad(wh, ((0, 0), (0, pd)))
        bz = jnp.pad(bz, (0, pd))
        bh = jnp.pad(bh, (0, pd))
        h0 = jnp.pad(h0, ((0, 0), (0, pd)))

    out = _kernel.fused_mingru_kernel(x, wz, bz, wh, bh, h0, block_t=bt,
                                      block_dh=block_dh, mode=mode,
                                      interpret=interpret)
    return out[:, :t, :dh]
