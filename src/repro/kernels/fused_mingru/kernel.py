"""Pallas TPU kernel: fused minGRU (gate projections + scan).

Why fuse (DESIGN.md §3): unfused, XLA materializes the gate activations
k, v: (B, T, 2*Dh) in HBM between the matmul and the scan -- for the paper's
LM block that is 2x the layer's activation traffic.  This kernel keeps a
(bt, Dx) input tile and the (Dx, bdh) weight tiles in VMEM, runs both
projections on the MXU, applies the sigmoid/g gates and the Kogge-Stone
scan on the VPU, and writes only h.  Per-block HBM traffic drops from
reading x + writing k,v + reading k,v + writing h  to  reading x + weights
+ writing h.

VMEM budget per block (fp32): bt*Dx + 2*Dx*bdh + 3*bt*bdh floats.
With bt=256, Dx<=2048, bdh=128: 2048*256*4 + 2*2048*128*4 + ... ~ 4.5 MB --
fits v5e's 16 MB higher-level VMEM comfortably.  The weight blocks are
re-fetched per time chunk; index_map pins them so Mosaic hoists the copy
out of the sequential grid dimension (revisiting the same block is free).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import nn
from repro.kernels.scan.kernel import _kogge_stone


def _fused_kernel(x_ref, wz_ref, bz_ref, wh_ref, bh_ref, h0_ref,
                  o_ref, carry_ref, *, mode: str):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(carry_ref.dtype)

    x = x_ref[0].astype(jnp.float32)                      # (bt, Dx)
    wz = wz_ref[...].astype(jnp.float32)                  # (Dx, bdh)
    wh = wh_ref[...].astype(jnp.float32)
    bz = bz_ref[...].astype(jnp.float32)
    bh = bh_ref[...].astype(jnp.float32)
    k = jnp.dot(x, wz, preferred_element_type=jnp.float32) + bz
    v = jnp.dot(x, wh, preferred_element_type=jnp.float32) + bh
    z = jax.nn.sigmoid(k)
    if mode == "log":
        h_tilde = nn.g(v)
    else:
        h_tilde = v
    A, B = _kogge_stone(1.0 - z, z * h_tilde)
    h = B + A * carry_ref[...]
    o_ref[0, ...] = h.astype(o_ref.dtype)
    carry_ref[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("block_t", "block_dh", "mode",
                                             "interpret"))
def fused_mingru_kernel(x: jax.Array, wz: jax.Array, bz: jax.Array,
                        wh: jax.Array, bh: jax.Array, h0: jax.Array,
                        *, block_t: int = 256, block_dh: int = 128,
                        mode: str = "log", interpret: bool = True):
    """x: (B, T, Dx) -> h: (B, T, Dh).  T % block_t == 0, Dh % block_dh == 0."""
    bsz, t, dx = x.shape
    dh = wz.shape[1]
    assert t % block_t == 0 and dh % block_dh == 0, (t, dh)
    grid = (bsz, dh // block_dh, t // block_t)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_fused_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, dx), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((dx, block_dh), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_dh,), lambda i, j, k: (j,)),
            pl.BlockSpec((dx, block_dh), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_dh,), lambda i, j, k: (j,)),
            pl.BlockSpec((1, block_dh), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_dh),
                               lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_dh), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, wz, bz, wh, bh, h0)
