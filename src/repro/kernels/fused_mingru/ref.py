"""Pure-jnp oracle for the fused minGRU gate-projection + scan kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import nn


def fused_mingru_ref(x: jax.Array, wz: jax.Array, bz: jax.Array,
                     wh: jax.Array, bh: jax.Array,
                     h0: Optional[jax.Array] = None,
                     mode: str = "log") -> jax.Array:
    """minGRU layer forward: projections + recurrence, unfused reference.

    x: (B, T, Dx); wz, wh: (Dx, Dh); bz, bh: (Dh,); h0: (B, Dh).
    """
    k = x @ wz + bz
    v = x @ wh + bh
    z = jax.nn.sigmoid(k)
    h_tilde = nn.g(v) if mode == "log" else v
    a = 1.0 - z
    b = z * h_tilde
    if h0 is None:
        h0 = jnp.zeros(x.shape[:-2] + (wz.shape[1],), b.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(a, -2, 0), jnp.moveaxis(b, -2, 0)))
    return jnp.moveaxis(hs, 0, -2)
