"""repro: multi-pod JAX framework for parallel-scan minimal RNNs.

Implements "Were RNNs All We Needed?" (Feng et al., 2024) as a
production-grade training/inference framework: the minGRU/minLSTM
parallel-scan core, a 10-architecture model zoo, SPMD distribution
(DP/FSDP/TP/EP/SP over a multi-pod mesh), Pallas TPU kernels, fault-tolerant
training, and a batched serving engine.
"""

__version__ = "1.0.0"
