"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch mingru-lm --smoke \
        --ckpt-dir /tmp/repro_ckpt --prompts "To be" "Friends,"

Loads the latest checkpoint (or random init) and runs the continuous-
batching superstep engine over the given prompts: admission, prefill,
decode and sampling all happen inside one jitted device loop per
``--decode-block K`` rounds (``lm.superstep``), with finished slots
re-armed from their staging buffers in-loop.  ``--speculative ngram``
turns on speculative decoding (n-gram self-drafting, verified in one
chunk pass per round, streams bit-identical).  ``--max-queue``,
``--deadline-rounds``, ``--priority`` and ``--max-retries`` expose the
fault-tolerance layer (bounded admission, EDF deadlines, NaN-quarantine
retry -- see README "Failure model").  ``--fuse-block`` picks the decode
kernel tier (whole-block megakernel vs cell kernels) and ``--tune-file``
loads an autotuned (block_dh, C, K) plan -- see README "Autotuning".
``--snapshot-dir`` arms crash recovery (write-ahead journal + periodic
full-state snapshots) and ``--restore DIR`` resumes a crashed run
bit-identically -- see README "Crash recovery".
Prints the kernel tier + plan source, then completions (tagged with
their terminal status when not COMPLETED) + the engine stats snapshot
(prefill/decode token counters, wasted slot steps, per-request TTFT and
inter-token latency, tokens/s, host round-trips per decoded token, draft
accept rate, lifecycle/failure counters).
"""

from __future__ import annotations

import argparse
import time

# --mesh needs the virtual-device flag exported BEFORE the model stack
# imports below touch jax (kernel modules initialise the backend);
# jax-free by construction, safe as the very first repro import
from repro.distributed import devcount

devcount.force_host_devices_from_argv()

import jax

from repro.configs import archs
from repro.data.lm_corpus import decode_bytes
from repro.distributed import serve_mesh
from repro.models import lm
from repro.serving.engine import ServingEngine
from repro.training import checkpoint as ckpt_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mingru-lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--prompts", nargs="*",
                    default=["To be, or not to be", "Friends, Romans"])
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--decode-block", type=int, default=None,
                    help="device rounds per host round-trip (K): one "
                         "superstep runs K token-select/step/sample/"
                         "re-admit rounds on device per engine.step() "
                         "(default: the --tune-file plan's K, else 1)")
    ap.add_argument("--prompt-chunk", type=int, default=None,
                    help="prompt tokens a prefilling slot consumes per "
                         "device round (C): packed prefill amortises one "
                         "weight stream over C prompt tokens (minGRU/"
                         "minLSTM archs only; default: the --tune-file "
                         "plan's C, else 1 = unpacked)")
    ap.add_argument("--fuse-block", default="auto",
                    choices=["auto", "on", "off"],
                    help="whole-block decode megakernel tier "
                         "(kernels/block_step): one pallas_call per "
                         "layer per decode round; 'off' keeps the "
                         "cell-only kernel tier, 'auto' falls back per "
                         "layer when a TP slice or non-rmsnorm block "
                         "rules the fused path out")
    ap.add_argument("--tune-file", default=None, metavar="PATH|auto|none",
                    help="autotune plan (benchmarks/autotune.py): an "
                         "explicit TUNE_<config>.json path (shape-"
                         "checked, mismatch raises), 'auto' for the "
                         "discovery order ($REPRO_TUNE_DIR, cwd, repo "
                         "root), or 'none'; fills block_dh and the K/C "
                         "defaults -- explicit flags win")
    ap.add_argument("--speculative", default=None, choices=["ngram"],
                    help="speculative decoding draft source: decoding "
                         "rows propose up to --draft-len tokens per "
                         "round, verified in one chunk pass -- streams "
                         "stay bit-identical, inter-token latency drops "
                         "below one round on accepted drafts")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="max draft tokens proposed per round (S)")
    ap.add_argument("--priority", type=int, default=1,
                    help="scheduling class for all submitted prompts "
                         "(lower = more urgent; EDF-with-aging order)")
    ap.add_argument("--deadline-rounds", type=int, default=None,
                    help="per-request deadline in device rounds from "
                         "submission; overdue requests are TIMED_OUT "
                         "(partial output kept), and requests whose "
                         "deadline the capacity estimate cannot meet "
                         "are SHED at admission")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue (0 = unbounded): at "
                         "the high watermark new requests are REJECTED "
                         "until the queue drains below the low "
                         "watermark")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="quarantine retry budget: how many times a "
                         "request killed by the non-finite health guard "
                         "is re-enqueued before it is FAILED")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serving mesh shape, e.g. 4x1 (data-parallel "
                         "slot shards) or 2x2 (+ tensor-parallel gate "
                         "projections).  On CPU the launcher forces DxM "
                         "virtual devices -- this must happen before jax "
                         "initialises, so pass --mesh rather than "
                         "constructing the engine yourself, or set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N in the environment")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="arm crash recovery: journal every submit/"
                         "cancel/step to DIR/journal.jsonl and snapshot "
                         "the full serving state every --snapshot-every "
                         "rounds (starts a NEW journal epoch; resume a "
                         "crashed one with --restore DIR instead)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="snapshot cadence in device rounds for "
                         "--snapshot-dir (default 8)")
    ap.add_argument("--restore", default=None, metavar="DIR",
                    help="resume a crashed serving run: rebuild the "
                         "engine from DIR's newest good snapshot + "
                         "journal-tail replay (engine shape flags are "
                         "taken from the journal header, not the CLI), "
                         "finish its in-flight requests, then serve "
                         "--prompts on top.  Keeps journaling into DIR")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.tune_file == "none":
        args.tune_file = None

    # device count is fixed at backend init: force it before ANY jax
    # device use (init_params below is the first), or fail actionably
    mesh_plan = serve_mesh.MeshPlan.parse(args.mesh)
    if mesh_plan is not None:
        serve_mesh.ensure_host_devices(mesh_plan.size)

    cfg = archs.smoke(args.arch) if args.smoke else archs.get(args.arch)
    if cfg.vocab_size != 256:
        cfg = cfg.replace(vocab_size=256)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        restored = ckpt_lib.CheckpointManager(args.ckpt_dir).restore_latest()
        if restored is not None:
            step, params, _ = restored
            print(f"loaded checkpoint step {step}")

    if args.restore:
        engine = ServingEngine.restore(args.restore, cfg, params)
        rep = engine.recovery_report
        print(f"restored from {args.restore}: snapshot "
              f"@{rep['snapshot_round']}, replayed "
              f"{rep['replayed_records']} journal records "
              f"({rep['replayed_rounds']} rounds) in "
              f"{rep['recovery_s']:.2f}s"
              + (f"; fell past corrupt snapshot(s) "
                 f"{rep['corrupt_snapshots_skipped']}"
                 if rep["corrupt_snapshots_skipped"] else ""))
    else:
        engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                               max_len=args.max_len, seed=args.seed,
                               decode_block=args.decode_block,
                               prompt_chunk=args.prompt_chunk,
                               speculative=args.speculative,
                               draft_len=args.draft_len,
                               max_queue=args.max_queue,
                               max_retries=args.max_retries,
                               mesh=mesh_plan,
                               fuse_block=args.fuse_block,
                               tune=args.tune_file,
                               recover_dir=args.snapshot_dir,
                               snapshot_every=args.snapshot_every)
    rids = {}
    for p in args.prompts:
        rid = engine.submit(list(p.encode()), max_new=args.max_new,
                            temperature=args.temperature,
                            top_k=args.top_k, top_p=args.top_p,
                            priority=args.priority,
                            deadline=args.deadline_rounds)
        rids[rid] = p

    t0 = time.time()
    outs = engine.run_to_completion()
    dt = time.time() - t0
    n_tokens = sum(len(o) for o in outs.values())
    for rid, toks in sorted(outs.items()):
        req = engine.finished[rid]
        tag = "" if req.status == "COMPLETED" else f" [{req.status}]"
        # a restored engine also finishes requests journaled by the
        # crashed process, whose prompts arrived via the journal
        label = rids.get(rid, decode_bytes(req.prompt))
        print(f"--- [{label!r}]{tag} -> {decode_bytes(toks)!r}")
    print(f"{n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / max(dt, 1e-9):.1f} tok/s, batched)")
    snap = engine.stats.snapshot()
    plan = engine.tune_plan
    print(f"kernel tier: {engine.kernel_tier} "
          f"(fuse_block={args.fuse_block}, "
          f"block_dh={engine.cfg.block_dh or 'default'}"
          + (f", plan {plan.get('source', '<dict>')}" if plan else
             ", no tune plan") + ")")
    print(f"superstep K={engine.decode_block} C={engine.prompt_chunk}: "
          f"{snap['decode_calls']} host round-trips for "
          f"{snap['decode_tokens']} decoded tokens "
          f"({snap['host_roundtrips_per_decode_token']:.3f} "
          f"round-trips/token); "
          f"{snap['prefill_tokens']} prompt tokens prefilled in-loop "
          f"over {snap['prefill_rounds']} packed rounds; "
          f"wasted slot steps: {snap['wasted_slot_steps']} "
          f"({snap['wasted_slot_fraction']:.1%} of slot steps)")
    print(f"latency: ttft mean {snap['ttft_s_mean'] * 1e3:.1f}ms "
          f"(p95 {snap['ttft_s_p95'] * 1e3:.1f}ms, "
          f"{snap['ttft_rounds_mean']:.1f} device rounds), "
          f"inter-token {snap['itl_s_mean'] * 1e3:.1f}ms "
          f"({snap['itl_rounds_mean']:.2f} rounds/token)")
    if args.speculative:
        print(f"speculative ({args.speculative}, S={args.draft_len}): "
              f"{snap['draft_accepted']}/{snap['draft_proposed']} drafts "
              f"accepted ({snap['accept_rate']:.1%}); "
              f"{snap['non_spec_tokens']} of {snap['decode_tokens']} "
              f"tokens from the non-speculative path")
    if mesh_plan is not None:
        per = ", ".join(
            f"shard {i}: {s['decode_tokens']} tok "
            f"({s['wasted_slot_steps']} wasted)"
            for i, s in enumerate(snap["shards"]))
        print(f"mesh {mesh_plan} ({mesh_plan.size} devices): {per}; "
              f"slot-step identity per shard + global: "
              f"{snap['shard_identities_ok']}")
    print(f"lifecycle: {snap['completed']}/{snap['submitted']} completed "
          f"({snap['completion_rate']:.0%}), "
          f"cancelled {snap['cancelled']}, timed_out {snap['timed_out']}, "
          f"failed {snap['failed']}, shed {snap['shed']}, "
          f"rejected {snap['rejected']}; "
          f"quarantined {snap['quarantined']} "
          f"(retried {snap['retried']}, "
          f"nonfinite rounds {snap['nonfinite_decode_rounds']})")
    print("engine stats: " + ", ".join(
        f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
        for k, v in sorted(snap.items())))


if __name__ == "__main__":
    main()
