"""ShapeDtypeStruct stand-ins for every (architecture x shape) cell.

Nothing here allocates: params come from jax.eval_shape(init), inputs are
ShapeDtypeStructs, caches are eval_shape'd init_cache.  The dry-run lowers
against these (assignment: MULTI-POD DRY-RUN step 2).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models import encdec, lm

S = jax.ShapeDtypeStruct


def params_specs(cfg: ModelConfig):
    model = encdec if cfg.family == "encdec" else lm
    return jax.eval_shape(
        functools.partial(model.init_params, cfg=cfg), jax.random.PRNGKey(0))


def train_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": S((b, s), jnp.int32), "labels": S((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = S((b, cfg.n_frontend_tokens, cfg.frontend_dim),
                            cfg.cdtype)
    elif cfg.frontend == "patches":
        batch["patch_embeds"] = S((b, cfg.n_frontend_tokens,
                                   cfg.frontend_dim), cfg.cdtype)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {"tokens": S((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = S((b, cfg.n_frontend_tokens, cfg.frontend_dim),
                            cfg.cdtype)
    elif cfg.frontend == "patches":
        batch["patch_embeds"] = S((b, cfg.n_frontend_tokens,
                                   cfg.frontend_dim), cfg.cdtype)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    model = encdec if cfg.family == "encdec" else lm
    return jax.eval_shape(
        functools.partial(model.init_cache, cfg, b, s))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b = shape.global_batch
    return {"token": S((b,), jnp.int32), "cache": cache_specs(cfg, shape)}


def n_params(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts from the eval-shape tree."""
    tree = params_specs(cfg)
    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        names = [str(getattr(p, "key", p)) for p in path]
        size = 1
        for d in leaf.shape:
            size *= d
        total += size
        if cfg.moe and any(n in ("gate_w", "up_w", "down_w") for n in names):
            active += size * cfg.moe.top_k // cfg.moe.n_experts
        else:
            active += size
    return total, active
