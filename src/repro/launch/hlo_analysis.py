"""Post-SPMD HLO analysis: collective bytes + roofline terms.

collective_bytes is not in cost_analysis(): we parse the optimized HLO
(compiled.as_text()) and sum the RESULT-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.  The HLO is
the per-device partitioned module, so these are per-device bytes moved --
divided by the per-link bandwidth they give the collective roofline term
(methodology note in EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import re
from typing import Dict

# TPU v5e constants (assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} from the optimized HLO."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, _, rhs = ls.partition("=")
        head = rhs.lstrip()
        for kind in _COLLECTIVES:
            # match the op use, e.g. "f32[...] all-reduce(" / "all-reduce-start("
            if head.startswith(("(", "f", "b", "s", "u", "p", "c", "t")) and \
                    re.search(rf"\b{kind}(-start)?\(", head):
                # result type is between '=' and the op name
                seg = head.split(kind)[0]
                stats[kind]["count"] += 1
                stats[kind]["bytes"] += _shape_bytes(seg)
                break
    return stats


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   collective_bytes_per_dev: float) -> Dict[str, float]:
    """The three per-device roofline times (seconds)."""
    t_compute = flops_per_dev / PEAK_FLOPS
    t_memory = bytes_per_dev / HBM_BW
    t_collective = collective_bytes_per_dev / LINK_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)], key=lambda kv: kv[1])[0]
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_collective, "dominant": dominant}


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for inference (fwd only)."""
    mult = 6 if kind == "train" else 2
    return float(mult) * n_params_active * tokens
