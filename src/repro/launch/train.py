"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch mingru-lm --task lm --steps 200 --batch 8 --seq 256

Wires together: config registry -> model -> AdamW -> deterministic data
pipeline -> fault-tolerant supervisor (checkpoint/restart, straggler
watchdog).  ``--smoke`` swaps in the reduced config for CPU runs;
``--simulate-failure N`` kills step N once to demonstrate recovery.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.data import lm_corpus, synthetic
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib
from repro.training.fault_tolerance import TrainSupervisor


def build_batch_fn(task: str, cfg, batch: int, seq: int, seed: int):
    if task == "lm":
        train_data, _ = lm_corpus.build_corpus()
        if cfg.vocab_size < 256:
            raise ValueError("char LM needs vocab >= 256")
        return lambda step: lm_corpus.lm_batch(train_data, seed, step,
                                               batch, seq)
    if task == "selective_copy":
        return lambda step: synthetic.selective_copy_batch(
            seed, step, batch, seq_len=seq, vocab=cfg.vocab_size)
    raise ValueError(task)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mingru-lm")
    ap.add_argument("--task", default="lm",
                    choices=["lm", "selective_copy"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = archs.smoke(args.arch) if args.smoke else archs.get(args.arch)
    if args.task == "lm" and cfg.vocab_size != 256:
        cfg = cfg.replace(vocab_size=256)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params dtype={cfg.param_dtype}")

    ocfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                               total_steps=args.steps)
    from repro.models import lm as model
    params = model.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{n_params / 1e6:.1f}M parameters")
    opt_state = opt_lib.init(ocfg, params)

    step_fn = jax.jit(ts_lib.make_train_step(
        cfg, ocfg, microbatches=args.microbatches))
    batch_fn = build_batch_fn(args.task, cfg, args.batch, args.seq,
                              args.seed)

    manager = ckpt_lib.CheckpointManager(args.ckpt_dir, keep=2,
                                         save_interval=args.ckpt_every)
    sup = TrainSupervisor(_logged(step_fn, args.log_every), batch_fn,
                          manager)
    if args.simulate_failure >= 0:
        fired = []

        def hook(step):
            if step == args.simulate_failure and not fired:
                fired.append(step)
                raise RuntimeError("simulated node failure")

        sup.failure_hook = hook

    restored = manager.restore_latest()
    start = 0
    if restored is not None:
        start, params, opt_state = restored
        print(f"resumed from step {start}")

    t0 = time.time()
    params, opt_state, report = sup.run(params, opt_state, args.steps,
                                        start_step=start)
    dt = time.time() - t0
    print(f"ran {report.steps_run} steps in {dt:.1f}s "
          f"({dt / max(report.steps_run, 1):.2f} s/step); "
          f"recovered failures={report.failures_recovered} "
          f"stragglers={report.straggler_events}")
    if report.final_metrics:
        print("final:", {k: float(v) for k, v in
                         report.final_metrics.items()})
    manager.maybe_save(args.steps, params, opt_state, force=True)
    return report


def _logged(step_fn, every):
    count = [0]

    def run(params, opt_state, batch):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        count[0] += 1
        if count[0] % every == 0:
            print(f"  step {count[0]}: " +
                  " ".join(f"{k}={float(v):.4f}"
                           for k, v in metrics.items()
                           if jnp.ndim(v) == 0))
        return params, opt_state, metrics

    return run


if __name__ == "__main__":
    main()
