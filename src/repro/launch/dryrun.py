import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment: MULTI-POD DRY-RUN step 3).

Lowers + compiles every (architecture x input-shape x mesh) cell against
the production mesh with ShapeDtypeStruct inputs (no allocation), prints
memory_analysis / cost_analysis, and records collective stats + roofline
terms to JSONL.

  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl

--all orchestrates one subprocess per cell (isolation + resumability).
"""

import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.base import SHAPES, long_context_ok
from repro.distributed import context as mesh_ctx
from repro.distributed import sharding
from repro.launch import hlo_analysis, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, lm
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib


def _opt_cfg(cfg):
    return opt_lib.AdamWConfig(
        moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16"
        else "float32")


def build_lowerable(cfg, shape, mesh, *, microbatches: int = 1):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    p_specs = input_specs.params_specs(cfg)
    pure = bool(getattr(cfg, "pure_dp", 0))
    p_sh = sharding.params_shardings(p_specs, mesh, pure)
    model = encdec if cfg.family == "encdec" else lm

    if shape.kind == "train":
        ocfg = _opt_cfg(cfg)
        o_specs = jax.eval_shape(
            functools.partial(opt_lib.init, ocfg), p_specs)
        o_sh = opt_lib.AdamWState(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            mu=sharding.params_shardings(o_specs.mu, mesh),
            nu=sharding.params_shardings(o_specs.nu, mesh))
        batch = input_specs.train_specs(cfg, shape)
        b_specs = sharding.batch_pspec(mesh, batch, pure)
        b_sh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), b_specs)
        step_fn = ts_lib.make_train_step(cfg, ocfg,
                                         microbatches=microbatches)
        return (step_fn, (p_specs, o_specs, batch),
                (p_sh, o_sh, b_sh), (p_sh, o_sh, None), (0, 1))

    if shape.kind == "prefill":
        batch = input_specs.prefill_specs(cfg, shape)
        b_specs = sharding.batch_pspec(mesh, batch, pure)
        b_sh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), b_specs)
        if cfg.family == "encdec":
            def fn(params, batch):
                return encdec.forward(params, cfg, batch["frames"],
                                      batch["tokens"])
        else:
            # frontend prefix tokens (vlm patches) extend the cached length
            max_len = shape.seq_len + (cfg.n_frontend_tokens
                                       if cfg.frontend == "patches" else 0)

            def fn(params, batch):
                return lm.prefill(params, cfg, batch["tokens"], max_len,
                                  patch_embeds=batch.get("patch_embeds"))
        return fn, (p_specs, batch), (p_sh, b_sh), None, ()

    # decode
    specs = input_specs.decode_specs(cfg, shape)
    c_pspecs = sharding.cache_pspecs(cfg, mesh, specs["cache"],
                                     shape.global_batch)
    c_sh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), c_pspecs)
    t_sh = jax.sharding.NamedSharding(
        mesh, sharding.token_pspec(mesh, shape.global_batch))

    def fn(params, token, cache):
        return model.decode_step(params, cfg, token, cache)

    return (fn, (p_specs, specs["token"], specs["cache"]),
            (p_sh, t_sh, c_sh), (None, c_sh), (2,))


# ---------------------------------------------------------------------------
# Depth extrapolation: XLA's cost_analysis counts a while-loop body ONCE, so
# the scanned full-depth compile undercounts FLOPs/bytes/collectives by
# ~n_layers x (verified: scan vs unrolled on a 10-layer matmul stack).
# Unrolling the full model is honest but slow (374 s for starcoder2-15b).
# Instead we compile small UNROLLED depth variants, fit
#     cost = base + sum_i  n_i * per_layer_i
# per metric, and evaluate at the full depth -- exact for homogeneous
# trunks, and handled per layer type for the heterogeneous ones (dense
# prefix + MoE; encoder + decoder; hybrid groups).
# ---------------------------------------------------------------------------

def depth_variants(cfg):
    """Returns (variants, full_counts): each variant is (cfg_v, counts)."""
    if cfg.family == "encdec":
        mk = lambda e, d: cfg.replace(n_encoder_layers=e, n_layers=d)
        return ([(mk(1, 1), (1, 1)), (mk(2, 1), (2, 1)),
                 (mk(1, 2), (1, 2))],
                (cfg.n_encoder_layers, cfg.n_layers))
    if cfg.block_kind == "hybrid":
        every = cfg.hybrid_attn_every
        mk = lambda g: cfg.replace(n_layers=g * every)
        return ([(mk(1), (1,)), (mk(2), (2,))],
                (cfg.n_layers // every,))
    if cfg.moe and cfg.moe.first_dense_layers:
        mk = lambda d, m: cfg.replace(
            n_layers=d + m, moe=dataclasses.replace(
                cfg.moe, first_dense_layers=d))
        return ([(mk(1, 1), (1, 1)), (mk(2, 1), (2, 1)),
                 (mk(1, 2), (1, 2))],
                (cfg.moe.first_dense_layers,
                 cfg.n_layers - cfg.moe.first_dense_layers))
    mk = lambda n: cfg.replace(n_layers=n)
    return [(mk(1), (1,)), (mk(2), (2,))], (cfg.n_layers,)


def _cell_costs(cfg, shape, mesh, microbatches: int = 1) -> dict:
    """Compile one variant and extract the extrapolatable metrics."""
    fn, args, in_sh, out_sh, donate = build_lowerable(
        cfg, shape, mesh, microbatches=microbatches)
    kw = dict(in_shardings=in_sh)
    if out_sh is not None:
        kw["out_shardings"] = out_sh
    with mesh_ctx.use_mesh(mesh, pure_dp=bool(getattr(cfg, "pure_dp", 0))):
        compiled = jax.jit(fn, **kw).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # older jax: one dict per program
        ca = ca[0] if ca else {}
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(v["bytes"] for v in coll.values())),
    }


def extrapolate_costs(cfg, shape, mesh, microbatches: int = 1) -> dict:
    """Fit base + per-layer-type costs from small unrolled variants."""
    import numpy as np
    variants, full = depth_variants(cfg)
    rows, metrics = [], []
    for cfg_v, counts in variants:
        rows.append([1.0] + list(counts))
        m = _cell_costs(cfg_v.replace(scan_layers=False), shape, mesh,
                        microbatches)
        metrics.append([m["flops"], m["bytes"], m["coll_bytes"]])
    a = np.array(rows)
    y = np.array(metrics)
    x, *_ = np.linalg.lstsq(a, y, rcond=None)
    full_row = np.array([1.0] + list(full))
    flops, byts, coll = full_row @ x
    return {"flops": max(flops, 0.0), "bytes": max(byts, 0.0),
            "coll_bytes": max(coll, 0.0),
            "fit": {"counts": [list(c) for _, c in variants],
                    "full": list(full)}}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             microbatches: int = 1, verbose: bool = True,
             cfg_override=None) -> dict:
    cfg = cfg_override or archs.get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "microbatches": microbatches}

    if shape_name == "long_500k" and not long_context_ok(cfg):
        rec.update(ok=True, skipped=True,
                   reason="pure full-attention arch at 524k ctx "
                          "(DESIGN.md §5)")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    fn, args, in_sh, out_sh, donate = build_lowerable(
        cfg, shape, mesh, microbatches=microbatches)
    jit_kw = dict(in_shardings=in_sh)
    if out_sh is not None:
        jit_kw["out_shardings"] = out_sh
    if donate:
        jit_kw["donate_argnums"] = donate

    with mesh_ctx.use_mesh(mesh, pure_dp=bool(getattr(cfg, "pure_dp", 0))):
        lowered = jax.jit(fn, **jit_kw).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_stats(hlo)

    # honest per-device costs via small-unrolled depth extrapolation
    # (the scanned compile above proves lowering/memory; its cost_analysis
    # counts loop bodies once -- see module comment)
    costs = extrapolate_costs(cfg, shape, mesh, microbatches)
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    coll_bytes = costs["coll_bytes"]
    terms = hlo_analysis.roofline_terms(flops_dev, bytes_dev, coll_bytes)

    n_total, n_active = input_specs.n_params(cfg)
    tokens = (shape.global_batch * shape.seq_len if shape.kind != "decode"
              else shape.global_batch)
    mf = hlo_analysis.model_flops(
        n_active, tokens, "train" if shape.kind == "train" else "infer")
    n_dev = mesh.size
    useful_ratio = mf / (flops_dev * n_dev) if flops_dev else 0.0

    rec.update(
        ok=True, skipped=False, cost_fit=costs["fit"],
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        n_devices=n_dev,
        mem=dict(argument_bytes=mem.argument_size_in_bytes,
                 output_bytes=mem.output_size_in_bytes,
                 temp_bytes=mem.temp_size_in_bytes,
                 alias_bytes=mem.alias_size_in_bytes),
        hbm_per_device=(mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes),
        flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
        collectives={k: v for k, v in coll.items() if v["count"]},
        collective_bytes_per_dev=coll_bytes,
        roofline=terms,
        n_params=n_total, n_params_active=n_active,
        model_flops=mf, useful_flops_ratio=round(useful_ratio, 4),
    )
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_kind}] compile "
              f"{t_compile:.1f}s")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops/dev=%.3e bytes/dev=%.3e"
              % (flops_dev, bytes_dev))
        print("  collectives:", rec["collectives"])
        print("  roofline:", {k: (f"{v:.2e}" if isinstance(v, float) else v)
                              for k, v in terms.items()})
    return rec


def all_cells(include_extras: bool = True):
    names = list(archs.ASSIGNED)
    if include_extras:
        names += archs.PAPER_OWN + archs.EXTRAS
    for arch in names:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


def orchestrate(out_path: str, include_extras: bool, timeout: int,
                only_missing: bool = True):
    done = set()
    if only_missing and os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    cells = [c for c in all_cells(include_extras) if c not in done]
    print(f"{len(cells)} cells to run ({len(done)} already done)")
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    for i, (arch, shape, mesh) in enumerate(cells):
        print(f"=== [{i + 1}/{len(cells)}] {arch} x {shape} x {mesh}",
              flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--json-out", out_path]
        try:
            proc = subprocess.run(cmd, timeout=timeout,
                                  capture_output=True, text=True)
            if proc.returncode != 0:
                rec = {"arch": arch, "shape": shape, "mesh": mesh,
                       "ok": False,
                       "error": proc.stderr[-2000:] if proc.stderr else
                       "nonzero exit"}
                with open(out_path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                print("  FAILED:", proc.stderr.splitlines()[-1]
                      if proc.stderr else "?")
        except subprocess.TimeoutExpired:
            rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": False,
                   "error": f"compile timeout > {timeout}s"}
            with open(out_path, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print("  TIMEOUT")


def apply_overrides(cfg, spec: str):
    """--override "ssm.chunk=64,remat=dots,moe.capacity_factor=1.0" """
    if not spec:
        return cfg
    for kv in spec.split(","):
        key, _, val = kv.partition("=")
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        if "." in key:
            sub, field = key.split(".", 1)
            subcfg = getattr(cfg, sub)
            cfg = cfg.replace(**{sub: dataclasses.replace(
                subcfg, **{field: val})})
        else:
            cfg = cfg.replace(**{key: val})
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--override", default="",
                    help="comma-separated cfg overrides, e.g. ssm.chunk=64")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-extras", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--json-out", default=None,
                    help="append the single-cell record to this JSONL")
    args = ap.parse_args()

    if args.all:
        orchestrate(args.out, not args.no_extras, args.timeout)
        return

    try:
        cfg_override = None
        if args.override:
            cfg_override = apply_overrides(archs.get(args.arch),
                                           args.override)
        rec = run_cell(args.arch, args.shape, args.mesh, args.microbatches,
                       cfg_override=cfg_override)
        if args.override:
            rec["override"] = args.override
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "ok": False, "error": traceback.format_exc()[-2000:]}
        print(rec["error"], file=sys.stderr)
        if args.json_out:
            with open(args.json_out, "a") as f:
                f.write(json.dumps(rec) + "\n")
        sys.exit(1)
    if args.json_out:
        with open(args.json_out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
