"""Config: zamba2-2.7b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("zamba2-2.7b")
SMOKE = archs.smoke("zamba2-2.7b")
