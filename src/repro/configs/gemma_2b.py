"""Config: gemma-2b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("gemma-2b")
SMOKE = archs.smoke("gemma-2b")
