"""Config: deepseek-v3-671b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("deepseek-v3-671b")
SMOKE = archs.smoke("deepseek-v3-671b")
