"""Config: deepseek-67b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("deepseek-67b")
SMOKE = archs.smoke("deepseek-67b")
