"""Architecture configs: the 10 assigned archs + the paper's own models."""

from repro.configs import archs  # noqa: F401
from repro.configs.base import (MinRNNConfig, ModelConfig, MoEConfig,  # noqa: F401
                                SHAPES, SSMConfig, ShapeConfig,
                                long_context_ok)
