"""Config: mamba2-370m (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("mamba2-370m")
SMOKE = archs.smoke("mamba2-370m")
