"""Config: whisper-base (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("whisper-base")
SMOKE = archs.smoke("whisper-base")
