"""Config: pixtral-12b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("pixtral-12b")
SMOKE = archs.smoke("pixtral-12b")
