"""Config: starcoder2-15b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("starcoder2-15b")
SMOKE = archs.smoke("starcoder2-15b")
