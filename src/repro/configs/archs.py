"""The 10 assigned architectures (exact public configs) + the paper's own
minGRU/minLSTM LMs.

Sources are cited per entry ([arXiv / hf] per the assignment).  ``d_ff`` in
the assignment's MoE entries is the per-expert hidden dim; dense-prefix
layers use the published dense d_ff.  Each arch has a ``smoke`` reduction
(same family, tiny dims) used by the per-arch CPU smoke tests; the full
configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation).
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs.base import (MinRNNConfig, ModelConfig, MoEConfig,
                                SSMConfig)

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig, smoke: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke


_BIG = dict(param_dtype="bfloat16", compute_dtype="bfloat16", remat="full")
_SMOKE_NUM = dict(param_dtype="float32", compute_dtype="float32",
                  remat="none")


# ---------------------------------------------------------------------------
# starcoder2-15b  [arXiv:2402.19173; hf]  GQA, RoPE, layernorm, plain GELU MLP
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="starcoder2-15b", block_kind="attention",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
        d_ff=24576, vocab_size=49152, norm="layernorm", gated_mlp=False,
        mlp_activation="gelu", attn_bias=True, mlp_bias=True,
        rope=True, rope_theta=1e5, tie_embeddings=False, **_BIG),
    ModelConfig(
        name="starcoder2-15b", block_kind="attention",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, norm="layernorm", gated_mlp=False,
        mlp_activation="gelu", attn_bias=True, mlp_bias=True,
        rope=True, rope_theta=1e5, **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# gemma-7b  [arXiv:2403.08295; hf]  GeGLU, head_dim 256, 256k vocab
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="gemma-7b", block_kind="attention",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab_size=256000, norm="rmsnorm",
        norm_zero_centered=True, gated_mlp=True, mlp_activation="gelu",
        rope=True, tie_embeddings=True, embedding_scale=True, **_BIG),
    ModelConfig(
        name="gemma-7b", block_kind="attention",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=1024, norm="rmsnorm", norm_zero_centered=True,
        gated_mlp=True, mlp_activation="gelu", rope=True,
        tie_embeddings=True, embedding_scale=True, **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# gemma-2b  [arXiv:2403.08295; hf]  MQA (kv=1), GeGLU, head_dim 256
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="gemma-2b", block_kind="attention",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=256000, norm="rmsnorm",
        norm_zero_centered=True, gated_mlp=True, mlp_activation="gelu",
        rope=True, tie_embeddings=True, embedding_scale=True, **_BIG),
    ModelConfig(
        name="gemma-2b", block_kind="attention",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=1024, norm="rmsnorm", norm_zero_centered=True,
        gated_mlp=True, mlp_activation="gelu", rope=True,
        tie_embeddings=True, embedding_scale=True, **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# deepseek-67b  [arXiv:2401.02954; hf]  llama-arch, GQA kv=8, SwiGLU
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="deepseek-67b", block_kind="attention",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=102400, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True, **_BIG),
    ModelConfig(
        name="deepseek-67b", block_kind="attention",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab_size=512, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True, **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# pixtral-12b  [hf:mistralai/Pixtral-12B-2409; unverified]
# pixtral-ViT frontend (stub patch embeddings) + mistral-nemo backbone
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="pixtral-12b", block_kind="attention",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True, rope_theta=1e6,
        frontend="patches", n_frontend_tokens=1024, frontend_dim=1024,
        **_BIG),
    ModelConfig(
        name="pixtral-12b", block_kind="attention",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True, rope_theta=1e6,
        frontend="patches", n_frontend_tokens=8, frontend_dim=32,
        **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# mamba2-370m  [arXiv:2405.21060; unverified]  SSD, attn-free
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="mamba2-370m", block_kind="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=50280, norm="rmsnorm", rope=False, tie_embeddings=True,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                      conv_kernel=4, chunk=256),
        param_dtype="bfloat16", compute_dtype="bfloat16", remat="full"),
    ModelConfig(
        name="mamba2-370m", block_kind="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=512, norm="rmsnorm", rope=False, tie_embeddings=True,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1,
                      conv_kernel=4, chunk=8), **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# deepseek-v3-671b  [arXiv:2412.19437; hf]  MLA, 1 shared + 256 routed top-8
# (MTP head omitted -- training objective orthogonal to the assignment)
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="deepseek-v3-671b", block_kind="attention", attn_kind="mla",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=18432, vocab_size=129280, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True,
        mla_q_lora=1536, mla_kv_lora=512, mla_rope_dim=64,
        mla_qk_nope_dim=128, mla_v_dim=128,
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      d_shared=2048, first_dense_layers=3,
                      capacity_factor=1.25), **_BIG),
    ModelConfig(
        name="deepseek-v3-671b", block_kind="attention", attn_kind="mla",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True,
        mla_q_lora=32, mla_kv_lora=16, mla_rope_dim=8,
        mla_qk_nope_dim=16, mla_v_dim=16,
        # capacity >= N*k so the smoke consistency tests see no dropping
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                      d_shared=32, first_dense_layers=1,
                      capacity_factor=16.0), **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# deepseek-moe-16b  [arXiv:2401.06066; hf]  2 shared + 64 routed top-6
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="deepseek-moe-16b", block_kind="attention",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=10944, vocab_size=102400, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      d_shared=2816, first_dense_layers=1,
                      capacity_factor=1.25), **_BIG),
    ModelConfig(
        name="deepseek-moe-16b", block_kind="attention",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, norm="rmsnorm", gated_mlp=True,
        mlp_activation="silu", rope=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2,
                      d_shared=64, first_dense_layers=1,
                      capacity_factor=16.0), **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# whisper-base  [arXiv:2212.04356; unverified]  enc-dec, conv frontend stub
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="whisper-base", family="encdec", block_kind="attention",
        n_layers=6, n_encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865, norm="layernorm",
        gated_mlp=False, mlp_activation="gelu", attn_bias=True,
        mlp_bias=True, rope=False, frontend="frames",
        n_frontend_tokens=1500, frontend_dim=512, max_seq_len=32768,
        param_dtype="bfloat16", compute_dtype="bfloat16", remat="full"),
    ModelConfig(
        name="whisper-base", family="encdec", block_kind="attention",
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, norm="layernorm",
        gated_mlp=False, mlp_activation="gelu", attn_bias=True,
        mlp_bias=True, rope=False, frontend="frames",
        n_frontend_tokens=16, frontend_dim=32, max_seq_len=128,
        **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# zamba2-2.7b  [arXiv:2411.15242; hf]  Mamba2 trunk + shared attn blocks
# (shared-block LoRA omitted -- DESIGN.md §5)
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="zamba2-2.7b", block_kind="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, vocab_size=32000, norm="rmsnorm", gated_mlp=True,
        mlp_activation="gelu", rope=True, hybrid_attn_every=6,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                      conv_kernel=4, chunk=256),
        param_dtype="bfloat16", compute_dtype="bfloat16", remat="full"),
    ModelConfig(
        name="zamba2-2.7b", block_kind="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, norm="rmsnorm", gated_mlp=True,
        mlp_activation="gelu", rope=True, hybrid_attn_every=2,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, n_groups=1,
                      conv_kernel=4, chunk=8), **_SMOKE_NUM))

# ---------------------------------------------------------------------------
# The paper's own architectures (Feng et al. 2024, App. C)
# ---------------------------------------------------------------------------
_register(
    ModelConfig(
        name="mingru-lm", block_kind="minrnn",
        n_layers=12, d_model=768, d_ff=3072, n_heads=0, n_kv_heads=0,
        vocab_size=256, norm="rmsnorm", rope=False, tie_embeddings=True,
        minrnn=MinRNNConfig(cell="mingru", expansion=2.0, mode="log",
                            use_conv=True, use_mlp=True),
        param_dtype="bfloat16", compute_dtype="bfloat16", remat="full"),
    ModelConfig(
        name="mingru-lm", block_kind="minrnn",
        n_layers=3, d_model=64, d_ff=256, n_heads=0, n_kv_heads=0,
        vocab_size=256, norm="rmsnorm", rope=False, tie_embeddings=True,
        minrnn=MinRNNConfig(cell="mingru", expansion=2.0, mode="log",
                            use_conv=True, use_mlp=True), **_SMOKE_NUM))

_register(
    ModelConfig(
        name="minlstm-lm", block_kind="minrnn",
        n_layers=12, d_model=768, d_ff=3072, n_heads=0, n_kv_heads=0,
        vocab_size=256, norm="rmsnorm", rope=False, tie_embeddings=True,
        minrnn=MinRNNConfig(cell="minlstm", expansion=2.0, mode="log",
                            use_conv=True, use_mlp=True),
        param_dtype="bfloat16", compute_dtype="bfloat16", remat="full"),
    ModelConfig(
        name="minlstm-lm", block_kind="minrnn",
        n_layers=3, d_model=64, d_ff=256, n_heads=0, n_kv_heads=0,
        vocab_size=256, norm="rmsnorm", rope=False, tie_embeddings=True,
        minrnn=MinRNNConfig(cell="minlstm", expansion=2.0, mode="log",
                            use_conv=True, use_mlp=True), **_SMOKE_NUM))

# beyond-paper: gemma-2b with the paper's minGRU mixer replacing attention
# (demonstrates the technique at an assigned-arch scale; sub-quadratic, so
# it also runs long_500k -- EXPERIMENTS.md §Perf)
_g2 = _REGISTRY["gemma-2b"]
_register(
    _g2.replace(name="gemma-2b-mingru", seq_mixer="mingru",
                minrnn=MinRNNConfig(cell="mingru", expansion=1.0,
                                    mode="log", use_conv=False,
                                    use_mlp=False)),
    _SMOKE["gemma-2b"].replace(name="gemma-2b-mingru", seq_mixer="mingru",
                               minrnn=MinRNNConfig(cell="mingru",
                                                   expansion=1.0,
                                                   mode="log",
                                                   use_conv=False,
                                                   use_mlp=False)))

ASSIGNED = [
    "starcoder2-15b", "gemma-7b", "gemma-2b", "deepseek-67b", "pixtral-12b",
    "mamba2-370m", "deepseek-v3-671b", "deepseek-moe-16b", "whisper-base",
    "zamba2-2.7b",
]

PAPER_OWN = ["mingru-lm", "minlstm-lm"]
EXTRAS = ["gemma-2b-mingru"]


def get(name: str) -> ModelConfig:
    return _REGISTRY[name]


def smoke(name: str) -> ModelConfig:
    return _SMOKE[name]


def all_names():
    return list(_REGISTRY)
