"""Config: minlstm-lm (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("minlstm-lm")
SMOKE = archs.smoke("minlstm-lm")
