"""Config: mingru-lm (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("mingru-lm")
SMOKE = archs.smoke("mingru-lm")
