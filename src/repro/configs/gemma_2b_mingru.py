"""Config: gemma-2b-mingru (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("gemma-2b-mingru")
SMOKE = archs.smoke("gemma-2b-mingru")
