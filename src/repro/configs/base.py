"""Config schema for every architecture in the zoo.

One frozen dataclass describes any of the 10 assigned architectures plus the
paper's own minRNN LMs.  Block composition is driven by ``block_kind`` and
the optional MoE / SSM / hybrid sub-configs; ``seq_mixer`` swaps the native
attention mixer for the paper's minGRU/minLSTM (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    n_shared: int = 0              # shared (always-on) experts
    d_shared: int = 0              # shared-expert hidden dim (total)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_dense_layers: int = 0    # deepseek: leading dense layers
    ep_2d: str = "auto"            # 2D (expert x d) weight sharding:
                                   # auto = on when activation all-to-all
                                   # traffic < weight gather (decode);
                                   # on | off force (EXPERIMENTS.md §Perf D)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256               # SSD chunk length
    dual_form: str = "masked"      # masked (paper-faithful) | factored
                                   # (beyond-paper, EXPERIMENTS.md §Perf)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MinRNNConfig:
    cell: str = "mingru"           # mingru | minlstm
    expansion: float = 2.0         # paper's alpha (LM uses 2)
    mode: str = "log"              # log-space parameterization
    use_conv: bool = True          # Conv4 prefix (paper App. C.2)
    conv_kernel: int = 4
    use_mlp: bool = True


@dataclass(frozen=True)
class ModelConfig:
    # identity ------------------------------------------------------------
    name: str = "unnamed"
    family: str = "lm"             # lm | encdec
    block_kind: str = "attention"  # attention | ssm | minrnn | hybrid
    seq_mixer: str = "native"      # native | mingru | minlstm (DESIGN §5)

    # trunk ---------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 256
    max_seq_len: int = 8192

    # flavor --------------------------------------------------------------
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_zero_centered: bool = False   # gemma (1+scale) RMSNorm
    mlp_activation: str = "silu"   # silu|gelu for the (gated) MLP
    gated_mlp: bool = True         # SwiGLU/GeGLU vs plain MLP
    attn_bias: bool = False        # starcoder2/whisper use biases
    mlp_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embedding_scale: bool = False  # gemma: x *= sqrt(d_model)
    attn_logit_soft_cap: float = 0.0

    # attention variant -----------------------------------------------------
    attn_kind: str = "gqa"         # gqa | mla
    mla_q_lora: int = 1536
    mla_kv_lora: int = 512
    mla_rope_dim: int = 64
    mla_v_dim: int = 128
    mla_qk_nope_dim: int = 128

    # sub-configs -----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    minrnn: Optional[MinRNNConfig] = None
    hybrid_attn_every: int = 0     # zamba2: shared attn block period

    # modality frontend stubs (assignment: frontends are stubs) -------------
    frontend: Optional[str] = None  # "patches" (vlm) | "frames" (audio)
    n_frontend_tokens: int = 0
    frontend_dim: int = 0           # raw embedding dim of the stub inputs

    # encoder-decoder --------------------------------------------------------
    n_encoder_layers: int = 0

    # numerics / performance -------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # minRNN scan execution (core.scan.STRATEGIES): "auto" resolves to the
    # fused Pallas projection+scan kernels -- real kernels on TPU,
    # interpret-mode parity elsewhere.  Set "associative" to force the
    # pure-jnp reference path.
    scan_strategy: str = "auto"
    # minRNN decode block fusion (kernels/block_step): "auto"/"on" run the
    # whole residual block (norm -> conv step -> cell -> down -> MLP) in
    # one pallas_call per layer per decode round when ``scan_strategy``
    # resolves to "fused" (falling back to the cell kernel under
    # tensor-parallel serving or non-rmsnorm blocks); "off" keeps the
    # cell-only fusion.  ``block_dh`` is the kernel's feature tile (0 =
    # kernel default; autotune plans set it via TUNE_<config>.json).
    fuse_block: str = "auto"       # auto | on | off
    block_dh: int = 0
    remat: str = "none"            # none | full | dots
    scan_layers: bool = True       # lax.scan over stacked layer params
    pure_dp: int = 0               # 1: replicate weights, all axes are DP
                                   # (small-model layout; §Perf)
    attn_q_chunk: int = 1024       # blocked-attention tile sizes
    attn_kv_chunk: int = 1024
    logits_softcap: float = 0.0
    # loss partitioning: keep vocab-sharded logits (see §Perf)
    z_loss: float = 0.0

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (TPU lane width) so the
        embedding/unembedding shard over the model axis; pad columns are
        masked to -1e30 in the logits (DESIGN.md §8)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs whose native mixer is sub-quadratic (long_500k runs for these)
SUBQUADRATIC_KINDS = ("ssm", "minrnn", "hybrid")


def long_context_ok(cfg: ModelConfig) -> bool:
    if cfg.block_kind in SUBQUADRATIC_KINDS:
        return True
    return cfg.seq_mixer in ("mingru", "minlstm")
