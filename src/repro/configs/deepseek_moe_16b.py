"""Config: deepseek-moe-16b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("deepseek-moe-16b")
SMOKE = archs.smoke("deepseek-moe-16b")
