"""Config: gemma-7b (see repro.configs.archs for the authoritative entry)."""

from repro.configs import archs

CONFIG = archs.get("gemma-7b")
SMOKE = archs.smoke("gemma-7b")
