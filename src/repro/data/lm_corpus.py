"""Character-level LM corpus (paper Fig. 2: Shakespeare / nanoGPT setting).

The real tinyshakespeare file (1,003,854 train tokens) is not available
offline, so the corpus here is a set of genuine public-domain Shakespeare
passages embedded below (~6 KB), deterministically tiled with passage-level
shuffling to the requested size.  Loss VALUES are therefore not comparable
to the paper's (the effective entropy is lower); loss TRENDS and
model-vs-model comparisons are (DESIGN.md §8).
"""

from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np

PASSAGES = [
    """To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause.""",
    """Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date:
Sometime too hot the eye of heaven shines,
And often is his gold complexion dimm'd;
And every fair from fair sometime declines,
By chance or nature's changing course untrimm'd;
But thy eternal summer shall not fade.""",
    """Tomorrow, and tomorrow, and tomorrow,
Creeps in this petty pace from day to day
To the last syllable of recorded time,
And all our yesterdays have lighted fools
The way to dusty death. Out, out, brief candle!
Life's but a walking shadow, a poor player
That struts and frets his hour upon the stage
And then is heard no more: it is a tale
Told by an idiot, full of sound and fury,
Signifying nothing.""",
    """But, soft! what light through yonder window breaks?
It is the east, and Juliet is the sun.
Arise, fair sun, and kill the envious moon,
Who is already sick and pale with grief,
That thou her maid art far more fair than she.""",
    """Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.""",
    """All the world's a stage,
And all the men and women merely players:
They have their exits and their entrances;
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms.""",
    """Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.
Now are our brows bound with victorious wreaths;
Our bruised arms hung up for monuments.""",
    """The quality of mercy is not strain'd,
It droppeth as the gentle rain from heaven
Upon the place beneath: it is twice blest;
It blesseth him that gives and him that takes:
'Tis mightiest in the mightiest: it becomes
The throned monarch better than his crown.""",
    """If music be the food of love, play on;
Give me excess of it, that, surfeiting,
The appetite may sicken, and so die.
That strain again! it had a dying fall:
O, it came o'er my ear like the sweet sound,
That breathes upon a bank of violets,
Stealing and giving odour!""",
    """Once more unto the breach, dear friends, once more;
Or close the wall up with our English dead.
In peace there's nothing so becomes a man
As modest stillness and humility:
But when the blast of war blows in our ears,
Then imitate the action of the tiger;
Stiffen the sinews, summon up the blood.""",
]

VOCAB_SIZE = 256          # byte-level


def build_corpus(target_bytes: int = 400_000, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (train_data, test_data) as uint8 arrays, ~9:1 split."""
    rng = np.random.default_rng(seed)
    chunks = []
    size = 0
    while size < target_bytes:
        order = rng.permutation(len(PASSAGES))
        for i in order:
            chunks.append(PASSAGES[i].encode() + b"\n\n")
            size += len(chunks[-1])
    data = np.frombuffer(b"".join(chunks), np.uint8)
    split = int(len(data) * 0.9)
    return data[:split].copy(), data[split:].copy()


def lm_batch(data: np.ndarray, seed: int, step: int, batch: int,
             seq_len: int) -> Dict[str, np.ndarray]:
    """Deterministic (seed, step) -> batch of next-char prediction."""
    rng = np.random.default_rng(np.random.PCG64(seed * 7_919 + step))
    starts = rng.integers(0, len(data) - seq_len - 1, size=batch)
    tokens = np.stack([data[s:s + seq_len] for s in starts]).astype(np.int32)
    labels = np.stack([data[s + 1:s + seq_len + 1]
                       for s in starts]).astype(np.int32)
    return {"tokens": tokens, "labels": labels}


def decode_bytes(ids) -> str:
    return bytes(int(i) for i in ids).decode(errors="replace")
