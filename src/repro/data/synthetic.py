"""Synthetic task generators for the paper's benchmarks.

All generators are pure functions of (seed, step) -- the training data
pipeline is stateless, which is what makes checkpoint-restart and
straggler takeover trivial (fault_tolerance.py).

Tasks:
  * selective_copy      -- Mamba paper (Gu & Dao 2024) / paper Tables 1-2
  * Chomsky-hierarchy   -- Deletang et al. 2023 + xLSTM extras / Table 5:
    even_pairs, majority, majority_count, cycle_nav, bucket_sort,
    missing_duplicate
  * listops             -- LRA-style nested prefix expressions / Table 6
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

IGNORE = -1


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed * 1_000_003 + step))


# ---------------------------------------------------------------------------
# Selective copy (paper §4.1/4.2): vocab 16, n_data tokens among noise;
# the model must reproduce the data tokens, in order, at the end.
# Token map: 0 noise, 1..13 data values, 14 sep. vocab_size = 16.
# ---------------------------------------------------------------------------

def selective_copy_batch(seed: int, step: int, batch: int,
                         seq_len: int = 4096, n_data: int = 16,
                         vocab: int = 16) -> Dict[str, np.ndarray]:
    """Returns tokens (B, T) and labels (B, T) where labels[p] is the
    next-token target for tokens[p]: IGNORE everywhere except the answer
    span (the model must emit the data tokens, in order, after the sep)."""
    rng = _rng(seed, step)
    n_values = vocab - 3
    sep = vocab - 2
    total = seq_len + 1 + n_data           # input + sep + answer slots
    tokens = np.zeros((batch, total), np.int32)
    targets = np.full((batch, total), IGNORE, np.int32)
    values = rng.integers(1, n_values + 1, size=(batch, n_data))
    for b in range(batch):
        pos = rng.choice(seq_len, size=n_data, replace=False)
        pos.sort()
        tokens[b, pos] = values[b]
    tokens[:, seq_len] = sep
    tokens[:, seq_len + 1:] = values       # teacher forcing
    # target for position p is tokens[p+1]: answer starts after the sep
    targets[:, seq_len:seq_len + n_data] = values
    return {"tokens": tokens[:, :-1], "labels": targets[:, :-1]}


def selective_copy_accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    pred = logits.argmax(-1)
    mask = labels >= 0
    return float((pred[mask] == labels[mask]).mean())


# ---------------------------------------------------------------------------
# Chomsky-hierarchy classification tasks.  Each returns
# {"tokens": (B, T), "label": (B,)} with n_classes in CLS_CLASSES.
# ---------------------------------------------------------------------------

CLS_VOCAB = 16            # shared token space for the suite
PAD = 0


def even_pairs(seed, step, batch, min_len=2, max_len=40):
    """Regular: is the number of 'ab'/'ba' transitions even (first==last)?"""
    rng = _rng(seed, step)
    tokens = np.zeros((batch, max_len), np.int32)
    label = np.zeros((batch,), np.int32)
    for b in range(batch):
        n = int(rng.integers(min_len, max_len + 1))
        s = rng.integers(1, 3, size=n)       # tokens {1, 2}
        tokens[b, :n] = s
        label[b] = int(s[0] == s[-1])
    return {"tokens": tokens, "label": label, "n_classes": 2}


def majority(seed, step, batch, min_len=2, max_len=40, n_sym=4):
    rng = _rng(seed, step)
    tokens = np.zeros((batch, max_len), np.int32)
    label = np.zeros((batch,), np.int32)
    for b in range(batch):
        n = int(rng.integers(min_len, max_len + 1))
        s = rng.integers(1, n_sym + 1, size=n)
        tokens[b, :n] = s
        counts = np.bincount(s, minlength=n_sym + 1)
        label[b] = int(counts[1:].argmax())   # 0..n_sym-1
    return {"tokens": tokens, "label": label, "n_classes": n_sym}


def majority_count(seed, step, batch, min_len=2, max_len=40, n_sym=2):
    """Count of the majority symbol (class = count, up to max_len)."""
    rng = _rng(seed, step)
    tokens = np.zeros((batch, max_len), np.int32)
    label = np.zeros((batch,), np.int32)
    for b in range(batch):
        n = int(rng.integers(min_len, max_len + 1))
        s = rng.integers(1, n_sym + 1, size=n)
        tokens[b, :n] = s
        counts = np.bincount(s, minlength=n_sym + 1)
        label[b] = int(counts[1:].max())
    return {"tokens": tokens, "label": label, "n_classes": max_len + 1}


def cycle_nav(seed, step, batch, min_len=2, max_len=40, n_states=5):
    """Moves {+1, -1, 0} on a cycle of 5; classify the final position."""
    rng = _rng(seed, step)
    tokens = np.zeros((batch, max_len), np.int32)
    label = np.zeros((batch,), np.int32)
    moves = np.array([1, -1, 0])
    for b in range(batch):
        n = int(rng.integers(min_len, max_len + 1))
        s = rng.integers(1, 4, size=n)        # tokens {1,2,3}
        tokens[b, :n] = s
        label[b] = int(moves[s - 1].sum() % n_states)
    return {"tokens": tokens, "label": label, "n_classes": n_states}


def missing_duplicate(seed, step, batch, min_len=2, max_len=20):
    """Sequence s + separator + s-with-a-hole; classify the missing token."""
    rng = _rng(seed, step)
    total = 2 * max_len + 1
    tokens = np.zeros((batch, total), np.int32)
    label = np.zeros((batch,), np.int32)
    hole, sep = 3, 4                          # symbols {1,2}, hole=3, sep=4
    for b in range(batch):
        n = int(rng.integers(min_len, max_len + 1))
        s = rng.integers(1, 3, size=n)
        miss = int(rng.integers(0, n))
        s2 = s.copy()
        s2[miss] = hole
        tokens[b, :n] = s
        tokens[b, n] = sep
        tokens[b, n + 1:2 * n + 1] = s2
        label[b] = int(s[miss] - 1)
    return {"tokens": tokens, "label": label, "n_classes": 2}


def bucket_sort(seed, step, batch, min_len=2, max_len=40, n_sym=5):
    """Sequence-to-sequence: emit the tokens in sorted order (LM format)."""
    rng = _rng(seed, step)
    sep = n_sym + 1
    total = 2 * max_len + 1
    tokens = np.zeros((batch, total), np.int32)
    targets = np.full((batch, total), IGNORE, np.int32)
    for b in range(batch):
        n = int(rng.integers(min_len, max_len + 1))
        s = rng.integers(1, n_sym + 1, size=n)
        srt = np.sort(s)
        tokens[b, :n] = s
        tokens[b, n] = sep
        tokens[b, n + 1:n + 1 + n] = srt
        targets[b, n:n + n] = srt
    return {"tokens": tokens[:, :-1], "labels": targets[:, 1:],
            "vocab": n_sym + 2}


def listops(seed, step, batch, max_len=128, max_depth=4):
    """Nested prefix expressions over digits: MAX MIN MED SUM_MOD.
    Tokens: 0 pad, 1-10 digits 0-9, 11 [MAX, 12 [MIN, 13 [MED, 14 [SM, 15 ]."""
    rng = _rng(seed, step)
    OPS = [11, 12, 13, 14]

    def gen(depth):
        if depth == 0 or rng.random() < 0.4:
            d = int(rng.integers(0, 10))
            return [d + 1], d
        op = int(rng.integers(0, 4))
        n_args = int(rng.integers(2, 4))
        toks, vals = [OPS[op]], []
        for _ in range(n_args):
            t, v = gen(depth - 1)
            toks.extend(t)
            vals.append(v)
        toks.append(15)
        if op == 0:
            out = max(vals)
        elif op == 1:
            out = min(vals)
        elif op == 2:
            out = sorted(vals)[len(vals) // 2]
        else:
            out = sum(vals) % 10
        return toks, out

    tokens = np.zeros((batch, max_len), np.int32)
    label = np.zeros((batch,), np.int32)
    for b in range(batch):
        while True:
            toks, val = gen(max_depth)
            if len(toks) <= max_len:
                break
        tokens[b, :len(toks)] = toks
        label[b] = val
    return {"tokens": tokens, "label": label, "n_classes": 10}


CHOMSKY_TASKS = {
    "even_pairs": even_pairs,
    "majority": majority,
    "majority_count": majority_count,
    "cycle_nav": cycle_nav,
    "missing_duplicate": missing_duplicate,
}
