"""Offline-RL proxy dataset (paper Table 3 system reproduction).

D4RL MuJoCo data is unavailable offline, so this builds an HONEST stand-in
that exercises the identical system: a 2-D point-mass reach task, behavior
datasets of three qualities (random / medium / expert -- mirroring M, M-R,
M-E), returns-to-go conditioning, and expert-normalized scoring.  Scores
are NOT comparable to D4RL numbers and are labelled as proxy everywhere
(DESIGN.md §1/§8).

Env: state (pos, vel) in R^2 each, action = accel in [-1, 1]^2,
reward = -||pos - goal||^2 per step, horizon H.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

H = 64                 # episode length
STATE_DIM = 4          # pos(2) + vel(2)
ACT_DIM = 2
DT = 0.1
GOAL = np.array([1.0, -0.5])


def _step(pos, vel, act):
    vel = 0.9 * vel + DT * np.clip(act, -1, 1)
    pos = pos + DT * vel
    reward = -float(((pos - GOAL) ** 2).sum())
    return pos, vel, reward


def _pd_policy(pos, vel, noise, rng):
    act = 2.5 * (GOAL - pos) - 1.2 * vel
    return np.clip(act + noise * rng.standard_normal(2), -1, 1)


def rollout(policy_noise: float, rng: np.random.Generator
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    pos = rng.uniform(-1, 1, 2)
    vel = np.zeros(2)
    states, acts, rews = [], [], []
    for _ in range(H):
        s = np.concatenate([pos, vel])
        if policy_noise >= 10:                       # random policy
            a = rng.uniform(-1, 1, 2)
        else:
            a = _pd_policy(pos, vel, policy_noise, rng)
        pos, vel, r = _step(pos, vel, a)
        states.append(s)
        acts.append(a)
        rews.append(r)
    return (np.array(states, np.float32), np.array(acts, np.float32),
            np.array(rews, np.float32))


DATASETS = {          # mirrors D4RL M / M-R / M-E quality tiers
    "medium": [0.6],
    "medium-replay": [10.0, 0.6],
    "medium-expert": [0.6, 0.05],
}


def build_dataset(name: str, n_episodes: int = 256, seed: int = 0):
    rng = np.random.default_rng(seed)
    noises = DATASETS[name]
    states = np.zeros((n_episodes, H, STATE_DIM), np.float32)
    acts = np.zeros((n_episodes, H, ACT_DIM), np.float32)
    rtg = np.zeros((n_episodes, H, 1), np.float32)
    for e in range(n_episodes):
        s, a, r = rollout(noises[e % len(noises)], rng)
        states[e], acts[e] = s, a
        rtg[e, :, 0] = np.cumsum(r[::-1])[::-1]      # returns-to-go
    return {"states": states, "actions": acts, "rtg": rtg}


def rl_batch(dataset, seed: int, step: int, batch: int) -> Dict:
    rng = np.random.default_rng(np.random.PCG64(seed * 31_337 + step))
    idx = rng.integers(0, len(dataset["states"]), size=batch)
    return {k: v[idx] for k, v in dataset.items()}


def expert_score(seed: int = 1, episodes: int = 16) -> float:
    rng = np.random.default_rng(seed)
    return float(np.mean([rollout(0.05, rng)[2].sum()
                          for _ in range(episodes)]))


def random_score(seed: int = 2, episodes: int = 16) -> float:
    rng = np.random.default_rng(seed)
    return float(np.mean([rollout(10.0, rng)[2].sum()
                          for _ in range(episodes)]))


def normalized(score: float, rand: float, expert: float) -> float:
    """D4RL-style: 100 * (score - random) / (expert - random)."""
    return 100.0 * (score - rand) / max(expert - rand, 1e-6)


def evaluate_policy(act_fn, episodes: int = 16, seed: int = 3,
                    target_rtg: float = 0.0) -> float:
    """Roll out a trained DT-style model: act_fn(states, actions, rtg, t)
    -> action for the current step."""
    rng = np.random.default_rng(seed)
    totals = []
    for _ in range(episodes):
        pos = rng.uniform(-1, 1, 2)
        vel = np.zeros(2)
        states = np.zeros((1, H, STATE_DIM), np.float32)
        acts = np.zeros((1, H, ACT_DIM), np.float32)
        rtg = np.zeros((1, H, 1), np.float32)
        rtg[0, 0, 0] = target_rtg
        total = 0.0
        for t in range(H):
            states[0, t] = np.concatenate([pos, vel])
            a = np.asarray(act_fn(states, acts, rtg, t))
            acts[0, t] = a
            pos, vel, r = _step(pos, vel, a)
            total += r
            if t + 1 < H:
                rtg[0, t + 1, 0] = rtg[0, t, 0] - r
        totals.append(total)
    return float(np.mean(totals))
