"""Paper Tables 1 & 2: Selective Copying -- layer ablation + solve check.

CPU-scaled (seq 32, 4 data tokens, ~350 steps vs paper's 4096/16/400k --
calibrated so learning happens inside the CPU budget): the qualitative
claims reproduce -- 1-layer minRNNs trail (time-independent gates),
stacking layers lifts accuracy; minGRU is more stable than minLSTM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_utils import header, row, time_call
from repro.configs.base import MinRNNConfig, ModelConfig
from repro.data import synthetic
from repro.models import lm
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib

SEQ = 32
N_DATA = 4
BATCH = 48


def train_eval(cell: str, n_layers: int, steps: int, seed: int = 0):
    cfg = ModelConfig(
        name=f"{cell}{n_layers}", block_kind="minrnn", n_layers=n_layers,
        d_model=64, d_ff=256, vocab_size=16, tie_embeddings=False,
        minrnn=MinRNNConfig(cell=cell, expansion=6.0, mode="log",
                            use_conv=False, use_mlp=False))
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=steps,
                               weight_decay=0.0)
    opt_state = opt_lib.init(ocfg, params)
    step = jax.jit(ts_lib.make_train_step(cfg, ocfg))
    us = None
    for i in range(steps):
        batch = synthetic.selective_copy_batch(seed, i, BATCH, seq_len=SEQ,
                                               n_data=N_DATA)
        if i == steps - 1:
            us = time_call(step, params, opt_state, batch, repeats=1,
                           warmup=0)
        params, opt_state, _ = step(params, opt_state, batch)
    accs = []
    fwd = jax.jit(lambda p, t: lm.forward(p, cfg, t)[0])
    for i in range(6):
        batch = synthetic.selective_copy_batch(seed + 777, i, BATCH,
                                               seq_len=SEQ, n_data=N_DATA)
        logits = fwd(params, jnp.asarray(batch["tokens"]))
        accs.append(synthetic.selective_copy_accuracy(
            np.asarray(logits), batch["labels"]))
    return float(np.mean(accs)), us or 0.0


def main(steps: int = 350) -> dict:
    header("table1+2_selective_copy (layer ablation)")
    out = {}
    for cell in ("minlstm", "mingru"):
        for n_layers in (1, 2, 3):
            acc, us = train_eval(cell, n_layers, steps)
            row(f"selective_copy/{cell}/{n_layers}layers", us,
                f"acc={acc:.3f}")
            out[(cell, n_layers)] = acc
    return out


if __name__ == "__main__":
    main()
