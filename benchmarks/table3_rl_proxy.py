"""Paper Table 3 (SYSTEM reproduction, proxy data -- DESIGN.md §1/§8).

Decision-Transformer frame with the paper's (minRNN -> MLP) block on a
point-mass control proxy: three behavior-quality datasets, returns-to-go
conditioning, expert-normalized scores.  Scores are NOT D4RL-comparable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_utils import header, row, time_call
from repro.core.blocks import MinRNNBlockConfig
from repro.data import rl_proxy
from repro.models import heads
from repro.training import optimizer as opt_lib


def train_eval(cell: str, dataset_name: str, steps: int, seed: int = 0):
    bc = MinRNNBlockConfig(d_model=64, cell=cell, expansion=2.0,
                           use_conv=False, use_mlp=True, mlp_factor=2.0)
    params = heads.dt_init(jax.random.PRNGKey(seed),
                           state_dim=rl_proxy.STATE_DIM,
                           act_dim=rl_proxy.ACT_DIM, d_model=64,
                           n_layers=3, block_cfg=bc)
    data = rl_proxy.build_dataset(dataset_name, n_episodes=192, seed=seed)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps,
                               weight_decay=1e-4)
    opt_state = opt_lib.init(ocfg, params)

    @jax.jit
    def step(p, o, batch):
        (l, m), g = jax.value_and_grad(
            lambda q: heads.dt_loss(q, bc, batch), has_aux=True)(p)
        p, o, om = opt_lib.apply(ocfg, o, p, g)
        return p, o, l

    us = 0.0
    for i in range(steps):
        batch = rl_proxy.rl_batch(data, seed, i, 64)
        if i == steps - 1:
            us = time_call(step, params, opt_state, batch, repeats=1,
                           warmup=0)
        params, opt_state, loss = step(params, opt_state, batch)

    apply_jit = jax.jit(lambda p, s, a, r: heads.dt_apply(p, bc, s, a, r))

    def act_fn(states, actions, rtg, t):
        pred = apply_jit(params, jnp.asarray(states), jnp.asarray(actions),
                         jnp.asarray(rtg))
        return np.asarray(pred)[0, t]

    expert = rl_proxy.expert_score()
    rand = rl_proxy.random_score()
    score = rl_proxy.evaluate_policy(act_fn, episodes=8,
                                     target_rtg=expert)
    return rl_proxy.normalized(score, rand, expert), us


def main(steps: int = 150) -> dict:
    header("table3_rl_proxy (DT-minRNN on point-mass control, proxy)")
    out = {}
    for dataset in ("medium", "medium-replay", "medium-expert"):
        for cell in ("minlstm", "mingru"):
            score, us = train_eval(cell, dataset, steps)
            row(f"rl_proxy/{dataset}/{cell}", us,
                f"normalized_score={score:.1f}")
            out[(dataset, cell)] = score
    return out


if __name__ == "__main__":
    main()
