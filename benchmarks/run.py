"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced budgets
    PYTHONPATH=src python -m benchmarks.run --only fig1_runtime

Output: ``name,us_per_call,derived`` CSV rows per bench.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (fig1_runtime, fig2_lm, fig3_inference,
                            fig5_forget_bias, kernel_bench, param_ratios,
                            roofline, table1_selective_copy, table3_rl_proxy,
                            table4_chomsky, train_throughput)

    steps = 60 if args.quick else 250
    suite = {
        "param_ratios": lambda: param_ratios.main(),
        "fig1_runtime": lambda: fig1_runtime.main(),
        "table1_selective_copy":
            lambda: table1_selective_copy.main(
                steps=120 if args.quick else 350),
        "table3_rl_proxy":
            lambda: table3_rl_proxy.main(steps=min(steps, 150)),
        "table4_chomsky": lambda: table4_chomsky.main(steps=steps),
        "fig2_lm": lambda: fig2_lm.main(steps=min(steps, 200)),
        "fig3_inference": lambda: fig3_inference.main(),
        "fig5_forget_bias":
            lambda: fig5_forget_bias.main(steps=150 if args.quick else 400),
        "kernel_bench": lambda: kernel_bench.main([]),
        # suite runs never clobber the tracked BENCH_train.json trajectory;
        # regenerate that deliberately via `python -m benchmarks.train_throughput`
        "train_throughput": lambda: train_throughput.main(
            ["--tiny"] if args.quick
            else ["--out", "BENCH_train.local.json"]),
        "roofline": lambda: roofline.main(),
    }
    failures = []
    for name, fn in suite.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures.append(name)
            print(f"# BENCH FAILED: {name}", file=sys.stderr)
            traceback.print_exc()
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
