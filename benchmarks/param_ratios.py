"""Paper §3.1.3 / §3.2.4: exact parameter-count ratios.

minGRU/GRU at alpha = 1..4 should be ~33/22/17/13 %; minLSTM/LSTM
~38/25/19/15 %.  Counted from actually-instantiated parameter trees.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_utils import header, row
from repro.core import gru, lstm, min_gru, min_lstm

PAPER_GRU = {1: 33, 2: 22, 3: 17, 4: 13}
PAPER_LSTM = {1: 38, 2: 25, 3: 19, 4: 15}


def _count(params):
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def main() -> dict:
    header("param_ratios (paper §3.1.3/§3.2.4)")
    key = jax.random.PRNGKey(0)
    dx = 128
    out = {}
    for alpha in (1, 2, 3, 4):
        dh = alpha * dx
        r_gru = 100 * _count(min_gru.init(key, dx, dh, use_bias=False)) / \
            _count(gru.init(key, dx, dh, use_bias=False))
        r_lstm = 100 * _count(min_lstm.init(key, dx, dh, use_bias=False)) / \
            _count(lstm.init(key, dx, dh, use_bias=False))
        row(f"param_ratio/minGRU_vs_GRU/alpha{alpha}", 0.0,
            f"{r_gru:.1f}%_paper_{PAPER_GRU[alpha]}%")
        row(f"param_ratio/minLSTM_vs_LSTM/alpha{alpha}", 0.0,
            f"{r_lstm:.1f}%_paper_{PAPER_LSTM[alpha]}%")
        out[alpha] = (r_gru, r_lstm)
        assert abs(r_gru - PAPER_GRU[alpha]) < 1.0
        assert abs(r_lstm - PAPER_LSTM[alpha]) < 1.0
    return out


if __name__ == "__main__":
    main()
