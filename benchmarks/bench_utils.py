"""Shared benchmark helpers.  Every bench emits ``name,us_per_call,derived``
CSV rows (assignment contract for benchmarks/run.py)."""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1
              ) -> float:
    """Median wall-time (microseconds) of a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


def header(title: str):
    print(f"# --- {title} ---", flush=True)
