"""Shared benchmark helpers.  Every bench emits ``name,us_per_call,derived``
CSV rows (assignment contract for benchmarks/run.py); benches that feed the
perf trajectory additionally dump machine-readable JSON via ``dump_json``
(kernel_bench -> BENCH_kernel.json, train_throughput -> BENCH_train.json,
engine_throughput -> BENCH_engine.json)."""

from __future__ import annotations

import json
import time
from typing import Callable, Optional

import jax


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1
              ) -> float:
    """Median wall-time (microseconds) of a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


def header(title: str):
    print(f"# --- {title} ---", flush=True)


def dump_json(path: str, payload: dict) -> str:
    """Write a benchmark result dict as pretty JSON; returns the path.

    Adds a ``backend`` key so downstream consumers can tell real-TPU
    numbers from CPU interpret-mode structural runs.
    """
    payload = dict(payload)
    payload.setdefault("backend", jax.default_backend())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    return path
