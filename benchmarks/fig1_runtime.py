"""Paper Fig. 1: training-step runtime scaling over sequence length.

minGRU/minLSTM train via the parallel scan (O(log T) DEPTH); GRU/LSTM via
BPTT (O(T) depth).  IMPORTANT CPU CAVEAT: this host has ONE core, so the
parallel scan's width cannot be exploited -- wall-clock here measures
WORK, not depth, and the paper's 175-1324x GPU speedups cannot reproduce
as wall-clock on a serial machine.  What does transfer: (1) the minRNN
step has NO sequential matmul chain (GRU/LSTM run T dependent (d,3d)
matmuls -- their per-token cost includes serialized BLAS dispatch);
(2) the log-mode scan costs extra transcendentals (visible below);
(3) the structural depth claim is validated separately by the HLO of the
compiled scan (log2(T) combine stages) and by the TPU-targeted Pallas
kernel.  derived: us/token and fitted work-scaling exponent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_utils import header, row, time_call
from repro.core import gru, lstm, min_gru, min_lstm

D = 64
BATCH = 16
SEQ_LENS = (64, 128, 256, 512, 1024)


def _grad_fn(model, params, mode=None):
    if mode is None:
        def loss(p, x):
            return jnp.mean(model.forward(p, x) ** 2)
    else:
        def loss(p, x):
            return jnp.mean(model.parallel(p, x, mode=mode) ** 2)
    return jax.jit(jax.grad(loss))


def main() -> dict:
    header("fig1_runtime (train-step scaling, fwd+bwd, CPU wall-clock)")
    key = jax.random.PRNGKey(0)
    results = {}
    models = {
        "minGRU": (min_gru, "log"),
        "minGRU-linear": (min_gru, "linear"),
        "minLSTM": (min_lstm, "log"),
        "GRU": (gru, None),
        "LSTM": (lstm, None),
    }
    for name, (model, mode) in models.items():
        params = model.init(key, D, D)
        fn = _grad_fn(model, params, mode)
        times = []
        for t in SEQ_LENS:
            x = jax.random.normal(jax.random.PRNGKey(t), (BATCH, t, D))
            us = time_call(fn, params, x, repeats=3)
            times.append(us)
            row(f"fig1/{name}/T{t}", us, f"{us / t:.2f}us_per_token")
        # fit log-log slope
        slope = np.polyfit(np.log(SEQ_LENS), np.log(times), 1)[0]
        results[name] = (times, slope)
        row(f"fig1/{name}/scaling_exponent", 0.0, f"{slope:.3f}")
    # single-core wall-clock ratio (NOT the paper's GPU speedup -- see
    # module docstring; the depth win needs parallel hardware)
    for a, b in (("minGRU", "GRU"), ("minGRU-linear", "GRU"),
                 ("minLSTM", "LSTM")):
        sp = results[b][0][-1] / results[a][0][-1]
        row(f"fig1/serial_work_ratio_{a}_vs_{b}_T{SEQ_LENS[-1]}", 0.0,
            f"{sp:.2f}x_single_core_wallclock")
    return {k: v[1] for k, v in results.items()}


if __name__ == "__main__":
    main()
