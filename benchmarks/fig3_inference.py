"""Paper Figs. 3/4 + App. D.2: inference runtime with context tokens.

Traditional GRU/LSTM must consume the prompt sequentially; minGRU/minLSTM
prefill it with one parallel scan.  We measure (prefill + 16 decode steps)
wall-clock across context lengths and batch sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.bench_utils import header, row, time_call
from repro.core import gru, lstm, min_gru, min_lstm

D = 64
DECODE_STEPS = 16


def _min_infer(model, mode):
    @jax.jit
    def run(params, prompt):
        h = model.parallel(params, prompt, mode=mode)[..., -1, :]
        outs = []
        x = prompt[..., -1, :]
        for _ in range(DECODE_STEPS):
            h = model.step(params, x, h, mode=mode)
            x = h[..., :D]
            outs.append(h)
        return jnp.stack(outs)

    return run


def _seq_infer(model, two_state):
    @jax.jit
    def run(params, prompt):
        hs = model.forward(params, prompt)
        h = hs[..., -1, :]
        state = (h, jnp.zeros_like(h)) if two_state else h
        outs = []
        x = prompt[..., -1, :]
        for _ in range(DECODE_STEPS):
            state = model.step(params, x, state)
            h = state[0] if two_state else state
            x = h[..., :D]
            outs.append(h)
        return jnp.stack(outs)

    return run


def main() -> dict:
    header("fig3_inference (prefill+decode vs context length)")
    key = jax.random.PRNGKey(0)
    out = {}
    runners = {
        "minGRU": (_min_infer(min_gru, "log"), min_gru),
        "minLSTM": (_min_infer(min_lstm, "log"), min_lstm),
        "GRU": (_seq_infer(gru, False), gru),
        "LSTM": (_seq_infer(lstm, True), lstm),
    }
    for batch in (8, 32):
        for ctx in (128, 512):
            for name, (run, model) in runners.items():
                params = model.init(key, D, D)
                prompt = jax.random.normal(jax.random.PRNGKey(1),
                                           (batch, ctx, D))
                us = time_call(run, params, prompt, repeats=3)
                row(f"fig3/{name}/b{batch}_ctx{ctx}", us,
                    f"{us / (ctx + DECODE_STEPS):.1f}us_per_token")
                out[(name, batch, ctx)] = us
    for batch in (8, 32):
        for ctx in (128, 512):
            sp = out[("GRU", batch, ctx)] / out[("minGRU", batch, ctx)]
            row(f"fig3/speedup_minGRU_vs_GRU/b{batch}_ctx{ctx}", 0.0,
                f"{sp:.1f}x")
    return out


if __name__ == "__main__":
    main()
