"""Kernel micro-bench: Pallas kernels (interpret off-TPU) vs jnp strategies.

On CPU the Pallas kernels run in interpret mode (python-level emulation),
so wall-clock is NOT the TPU story -- the derived column therefore reports
the structural quantities that determine TPU performance: HBM bytes moved
per element and the arithmetic-intensity estimate from DESIGN.md §3.
Emits CSV rows plus machine-readable JSON (``--out``, default
BENCH_kernel.json) through the shared ``bench_utils.dump_json``.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.bench_utils import dump_json, header, row, time_call
from repro.core import blocks as blocks_lib
from repro.core import scan as scan_lib
from repro.kernels.block_step import ops as block_ops
from repro.kernels.decode_step import ops as step_ops
from repro.kernels.decode_step import ref as step_ref
from repro.kernels.fused_mingru import ops as fg_ops
from repro.kernels.scan import ops as scan_ops

# nominal v5e peaks, shared convention with roofline.py (197 TFLOP/s
# bf16) and engine_throughput.py (819 GB/s HBM); the ridge point is
# where a kernel stops being memory-bound
PEAK_FLOPS = 197e12
HBM_BYTES_PER_S = 819e9


def roofline_cols(flops: float, bytes_moved: float) -> dict:
    """Bytes-moved / FLOPs roofline columns for a kernel row: arithmetic
    intensity vs the ridge point decides which roof binds, and the
    ideal time is the binding roof's."""
    ai = flops / max(bytes_moved, 1.0)
    ridge = PEAK_FLOPS / HBM_BYTES_PER_S
    bound = "compute" if ai >= ridge else "memory"
    ideal_s = (flops / PEAK_FLOPS if bound == "compute"
               else bytes_moved / HBM_BYTES_PER_S)
    return {
        "flops_per_call": flops,
        "hbm_bytes_per_call": bytes_moved,
        "arith_intensity_flops_per_byte": ai,
        "ridge_flops_per_byte": ridge,
        "roofline_bound": bound,
        "ideal_us_v5e": ideal_s * 1e6,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernel.json")
    args = ap.parse_args(argv)

    header("kernel_bench (scan strategies)")
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    shape = (4, 1024, 128)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape))
    b = jax.random.normal(k2, shape)
    h0 = jnp.zeros((shape[0], shape[2]))

    runners = {
        "sequential": jax.jit(lambda a, b: scan_lib.scan_sequential(a, b)),
        "associative": jax.jit(lambda a, b: scan_lib.scan_associative(a, b)),
        "chunked": jax.jit(
            lambda a, b: scan_lib.scan_chunked(a, b, chunk=256)),
        "log_space": jax.jit(
            lambda a, b: scan_lib.scan_log_space(
                jnp.log(a), jnp.log(jnp.abs(b) + 1e-6))),
    }
    out = {}
    for name, fn in runners.items():
        us = time_call(fn, a, b, repeats=3)
        out[name] = {"us_per_call": us}
        row(f"kernel/{name}", us, "")

    # pallas rows -- real kernels on TPU, interpret-mode timing elsewhere;
    # structural derived either way.
    interp = scan_ops.DEFAULT_INTERPRET
    n = a.size
    # linear chunked-scan kernel: read a,b + write h
    us = time_call(
        lambda a, b, h0: scan_ops.linear_scan(a, b, h0, 256, 128, interp),
        a, b, h0, repeats=1)
    bytes_moved = 3 * n * 4
    intensity = 2 * 8 / (3 * 4)                  # kogge-stone flops/byte
    out["pallas_linear"] = {
        "us_per_call": us,
        "hbm_bytes_per_elem": bytes_moved / n,
        **roofline_cols(intensity * bytes_moved, bytes_moved),
    }
    row("kernel/pallas_linear", us,
        f"hbm_bytes_per_elem={bytes_moved / n:.0f};"
        f"arith_intensity={intensity:.2f}flops_per_byte;"
        f"{out['pallas_linear']['roofline_bound']}-bound")

    # log-space scan kernel: same traffic, ~3x the VPU flops (logaddexp)
    la, lb = jnp.log(a), jnp.log(jnp.abs(b) + 1e-6)
    lh0 = jnp.full_like(h0, -jnp.inf)
    us = time_call(
        lambda la, lb, lh0: scan_ops.log_space_scan(la, lb, lh0, 256, 128,
                                                    interp),
        la, lb, lh0, repeats=1)
    out["pallas_log"] = {
        "us_per_call": us,
        "hbm_bytes_per_elem": bytes_moved / n,
        **roofline_cols(3 * intensity * bytes_moved, bytes_moved),
    }
    row("kernel/pallas_log", us,
        f"hbm_bytes_per_elem={bytes_moved / n:.0f};"
        f"arith_intensity={3 * intensity:.2f}flops_per_byte;"
        f"{out['pallas_log']['roofline_bound']}-bound")

    # fused minGRU: read x + weights + write/re-read h (no gate round-trip).
    # Activation traffic convention matches train_throughput.py's
    # structural model: fused 2*Dh vs unfused (2P+2)*Dh = 6*Dh per token
    # (write + downstream read of every materialised activation).
    bsz, t, dh = shape
    dx = 64
    x = jax.random.normal(k3, (bsz, t, dx))
    wz = jax.random.normal(k1, (dx, dh)) * 0.2
    wh = jax.random.normal(k2, (dx, dh)) * 0.2
    us = time_call(
        lambda x, wz, wh: fg_ops.fused_mingru(x, wz, None, wh, None,
                                              interpret=interp),
        x, wz, wh, repeats=1)
    fused_bytes = (x.size + 2 * dx * dh + 2 * bsz * t * dh) * 4
    unfused_bytes = (x.size + 2 * dx * dh + 6 * bsz * t * dh) * 4
    fg_flops = 2 * 2 * bsz * t * dx * dh + 8 * bsz * t * dh
    out["pallas_fused_mingru"] = {
        "us_per_call": us,
        "hbm_bytes_per_elem": fused_bytes / (bsz * t * dh),
        "unfused_bytes_ratio": unfused_bytes / fused_bytes,
        **roofline_cols(fg_flops, fused_bytes),
    }
    row("kernel/pallas_fused_mingru", us,
        f"hbm_bytes_per_elem={fused_bytes / (bsz * t * dh):.1f};"
        f"unfused_traffic={unfused_bytes / fused_bytes:.2f}x;"
        f"{out['pallas_fused_mingru']['roofline_bound']}-bound")

    # fused decode step: the single-token batched GEMV (serving hot path).
    # Weight-bound at decode batch sizes -- structural traffic per step is
    # weights (P*Dx*Dh) + x + h in/out; the unfused step additionally
    # round-trips the P gate pre-activations (B, Dh) through HBM and
    # splits the work across P+1 XLA fusions.
    b_dec, dx_dec = 8, 128
    x1 = jax.random.normal(k3, (b_dec, dx_dec))
    h_prev = jax.random.normal(k1, (b_dec, dh))
    wz1 = jax.random.normal(k1, (dx_dec, dh)) * 0.2
    wh1 = jax.random.normal(k2, (dx_dec, dh)) * 0.2
    us = time_call(
        lambda x, h: step_ops.fused_mingru_step(x, wz1, None, wh1, None, h,
                                                interpret=interp),
        x1, h_prev, repeats=3)
    us_ref = time_call(
        jax.jit(lambda x, h: step_ref.mingru_step_ref(
            x, wz1, jnp.zeros(dh), wh1, jnp.zeros(dh), h)),
        x1, h_prev, repeats=3)
    n_proj = 2
    weight_bytes = n_proj * dx_dec * dh * 4
    act_bytes = (x1.size + 2 * b_dec * dh) * 4          # x + h in/out
    fused_step_bytes = weight_bytes + act_bytes
    unfused_step_bytes = fused_step_bytes + 2 * n_proj * b_dec * dh * 4
    step_flops = 2 * n_proj * b_dec * dx_dec * dh + 8 * b_dec * dh
    out["pallas_decode_step_mingru"] = {
        "us_per_call": us,
        "us_per_call_jnp_ref": us_ref,
        "hbm_bytes_per_step": fused_step_bytes,
        "unfused_bytes_ratio": unfused_step_bytes / fused_step_bytes,
        **roofline_cols(step_flops, fused_step_bytes),
    }
    row("kernel/pallas_decode_step_mingru", us,
        f"hbm_bytes_per_step={fused_step_bytes};"
        f"unfused_traffic={unfused_step_bytes / fused_step_bytes:.2f}x;"
        f"jnp_ref_us={us_ref:.1f};"
        f"{out['pallas_decode_step_mingru']['roofline_bound']}-bound")

    # whole-block decode step: the PR 9 megakernel -- norm + conv step +
    # cell + down + MLP for one layer in ONE pallas_call.  Structural
    # traffic per step is the layer's full weight slab + x/h/window
    # in/out; the cell-fused tier additionally round-trips every
    # intermediate activation (normed y, conv out, h, down out, MLP
    # hidden) through HBM across its 7 fusion boundaries.
    bcfg = blocks_lib.MinRNNBlockConfig(d_model=dx_dec, expansion=2.0)
    bdh = bcfg.d_hidden
    bdm = bcfg.d_mlp
    bparams = blocks_lib.init(jax.random.PRNGKey(1), bcfg)
    bstate = blocks_lib.init_state(bcfg, (b_dec,))
    xb = jax.random.normal(k3, (b_dec, dx_dec))
    us = time_call(
        lambda x, st: block_ops.fused_block_step(
            bparams, x, st, cell=bcfg.cell, mode=bcfg.mode,
            use_conv=bcfg.use_conv, use_mlp=bcfg.use_mlp),
        xb, bstate, repeats=3)
    blk_weight_bytes = ((n_proj + 1) * dx_dec * bdh
                       + 2 * dx_dec * bdm
                       + bcfg.conv_kernel * dx_dec + 2 * dx_dec) * 4
    kw = bcfg.conv_kernel - 1
    blk_act_bytes = (2 * xb.size + 2 * b_dec * bdh
                     + 2 * b_dec * kw * dx_dec) * 4
    blk_bytes = blk_weight_bytes + blk_act_bytes
    cell_tier_bytes = blk_bytes + 2 * b_dec * (3 * dx_dec + bdh + bdm) * 4
    blk_flops = (2 * (n_proj + 1) * b_dec * dx_dec * bdh
                 + 2 * 2 * b_dec * dx_dec * bdm
                 + 2 * b_dec * bcfg.conv_kernel * dx_dec
                 + 20 * b_dec * dx_dec + 8 * b_dec * bdh)
    out["pallas_block_step_mingru"] = {
        "us_per_call": us,
        "hbm_bytes_per_step": blk_bytes,
        "cell_tier_bytes_ratio": cell_tier_bytes / blk_bytes,
        **roofline_cols(blk_flops, blk_bytes),
    }
    row("kernel/pallas_block_step_mingru", us,
        f"hbm_bytes_per_step={blk_bytes};"
        f"cell_tier_traffic={cell_tier_bytes / blk_bytes:.2f}x;"
        f"{out['pallas_block_step_mingru']['roofline_bound']}-bound")

    dump_json(args.out, {"shape": list(shape), "kernels": out})
    return out


if __name__ == "__main__":
    main()
