"""Kernel micro-bench: Pallas chunked scan (interpret) vs jnp strategies.

On CPU the Pallas kernel runs in interpret mode (python), so wall-clock is
NOT the TPU story -- the derived column therefore reports the structural
quantities that determine TPU performance: HBM bytes moved per element and
the arithmetic-intensity estimate from DESIGN.md §3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.bench_utils import header, row, time_call
from repro.core import scan as scan_lib
from repro.kernels.scan import ops as scan_ops


def main() -> dict:
    header("kernel_bench (scan strategies)")
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    shape = (4, 1024, 128)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape))
    b = jax.random.normal(k2, shape)
    h0 = jnp.zeros((shape[0], shape[2]))

    runners = {
        "sequential": jax.jit(lambda a, b: scan_lib.scan_sequential(a, b)),
        "associative": jax.jit(lambda a, b: scan_lib.scan_associative(a, b)),
        "chunked": jax.jit(
            lambda a, b: scan_lib.scan_chunked(a, b, chunk=256)),
        "log_space": jax.jit(
            lambda a, b: scan_lib.scan_log_space(
                jnp.log(a), jnp.log(jnp.abs(b) + 1e-6))),
    }
    out = {}
    for name, fn in runners.items():
        us = time_call(fn, a, b, repeats=3)
        out[name] = us
        row(f"kernel/{name}", us, "")

    # pallas (interpret) -- correctness-mode timing, structural derived
    us = time_call(
        lambda a, b, h0: scan_ops.linear_scan(a, b, h0, 256, 128, True),
        a, b, h0, repeats=1)
    n = a.size
    bytes_moved = 3 * n * 4                      # read a,b + write h
    intensity = 2 * 8 / (3 * 4)                  # kogge-stone flops/byte
    row("kernel/pallas_interpret", us,
        f"hbm_bytes_per_elem={bytes_moved / n:.0f};"
        f"arith_intensity={intensity:.2f}flops_per_byte")
    out["pallas_interpret"] = us
    return out


if __name__ == "__main__":
    main()
