"""Kernel micro-bench: Pallas kernels (interpret off-TPU) vs jnp strategies.

On CPU the Pallas kernels run in interpret mode (python-level emulation),
so wall-clock is NOT the TPU story -- the derived column therefore reports
the structural quantities that determine TPU performance: HBM bytes moved
per element and the arithmetic-intensity estimate from DESIGN.md §3.
Emits CSV rows plus machine-readable JSON (``--out``, default
BENCH_kernel.json) through the shared ``bench_utils.dump_json``.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.bench_utils import dump_json, header, row, time_call
from repro.core import scan as scan_lib
from repro.kernels.decode_step import ops as step_ops
from repro.kernels.decode_step import ref as step_ref
from repro.kernels.fused_mingru import ops as fg_ops
from repro.kernels.scan import ops as scan_ops


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernel.json")
    args = ap.parse_args(argv)

    header("kernel_bench (scan strategies)")
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    shape = (4, 1024, 128)
    a = jax.nn.sigmoid(jax.random.normal(k1, shape))
    b = jax.random.normal(k2, shape)
    h0 = jnp.zeros((shape[0], shape[2]))

    runners = {
        "sequential": jax.jit(lambda a, b: scan_lib.scan_sequential(a, b)),
        "associative": jax.jit(lambda a, b: scan_lib.scan_associative(a, b)),
        "chunked": jax.jit(
            lambda a, b: scan_lib.scan_chunked(a, b, chunk=256)),
        "log_space": jax.jit(
            lambda a, b: scan_lib.scan_log_space(
                jnp.log(a), jnp.log(jnp.abs(b) + 1e-6))),
    }
    out = {}
    for name, fn in runners.items():
        us = time_call(fn, a, b, repeats=3)
        out[name] = {"us_per_call": us}
        row(f"kernel/{name}", us, "")

    # pallas rows -- real kernels on TPU, interpret-mode timing elsewhere;
    # structural derived either way.
    interp = scan_ops.DEFAULT_INTERPRET
    n = a.size
    # linear chunked-scan kernel: read a,b + write h
    us = time_call(
        lambda a, b, h0: scan_ops.linear_scan(a, b, h0, 256, 128, interp),
        a, b, h0, repeats=1)
    bytes_moved = 3 * n * 4
    intensity = 2 * 8 / (3 * 4)                  # kogge-stone flops/byte
    out["pallas_linear"] = {
        "us_per_call": us,
        "hbm_bytes_per_elem": bytes_moved / n,
        "arith_intensity_flops_per_byte": intensity,
    }
    row("kernel/pallas_linear", us,
        f"hbm_bytes_per_elem={bytes_moved / n:.0f};"
        f"arith_intensity={intensity:.2f}flops_per_byte")

    # log-space scan kernel: same traffic, ~3x the VPU flops (logaddexp)
    la, lb = jnp.log(a), jnp.log(jnp.abs(b) + 1e-6)
    lh0 = jnp.full_like(h0, -jnp.inf)
    us = time_call(
        lambda la, lb, lh0: scan_ops.log_space_scan(la, lb, lh0, 256, 128,
                                                    interp),
        la, lb, lh0, repeats=1)
    out["pallas_log"] = {
        "us_per_call": us,
        "hbm_bytes_per_elem": bytes_moved / n,
        "arith_intensity_flops_per_byte": 3 * intensity,
    }
    row("kernel/pallas_log", us,
        f"hbm_bytes_per_elem={bytes_moved / n:.0f};"
        f"arith_intensity={3 * intensity:.2f}flops_per_byte")

    # fused minGRU: read x + weights + write/re-read h (no gate round-trip).
    # Activation traffic convention matches train_throughput.py's
    # structural model: fused 2*Dh vs unfused (2P+2)*Dh = 6*Dh per token
    # (write + downstream read of every materialised activation).
    bsz, t, dh = shape
    dx = 64
    x = jax.random.normal(k3, (bsz, t, dx))
    wz = jax.random.normal(k1, (dx, dh)) * 0.2
    wh = jax.random.normal(k2, (dx, dh)) * 0.2
    us = time_call(
        lambda x, wz, wh: fg_ops.fused_mingru(x, wz, None, wh, None,
                                              interpret=interp),
        x, wz, wh, repeats=1)
    fused_bytes = (x.size + 2 * dx * dh + 2 * bsz * t * dh) * 4
    unfused_bytes = (x.size + 2 * dx * dh + 6 * bsz * t * dh) * 4
    out["pallas_fused_mingru"] = {
        "us_per_call": us,
        "hbm_bytes_per_elem": fused_bytes / (bsz * t * dh),
        "unfused_bytes_ratio": unfused_bytes / fused_bytes,
    }
    row("kernel/pallas_fused_mingru", us,
        f"hbm_bytes_per_elem={fused_bytes / (bsz * t * dh):.1f};"
        f"unfused_traffic={unfused_bytes / fused_bytes:.2f}x")

    # fused decode step: the single-token batched GEMV (serving hot path).
    # Weight-bound at decode batch sizes -- structural traffic per step is
    # weights (P*Dx*Dh) + x + h in/out; the unfused step additionally
    # round-trips the P gate pre-activations (B, Dh) through HBM and
    # splits the work across P+1 XLA fusions.
    b_dec, dx_dec = 8, 128
    x1 = jax.random.normal(k3, (b_dec, dx_dec))
    h_prev = jax.random.normal(k1, (b_dec, dh))
    wz1 = jax.random.normal(k1, (dx_dec, dh)) * 0.2
    wh1 = jax.random.normal(k2, (dx_dec, dh)) * 0.2
    us = time_call(
        lambda x, h: step_ops.fused_mingru_step(x, wz1, None, wh1, None, h,
                                                interpret=interp),
        x1, h_prev, repeats=3)
    us_ref = time_call(
        jax.jit(lambda x, h: step_ref.mingru_step_ref(
            x, wz1, jnp.zeros(dh), wh1, jnp.zeros(dh), h)),
        x1, h_prev, repeats=3)
    n_proj = 2
    weight_bytes = n_proj * dx_dec * dh * 4
    act_bytes = (x1.size + 2 * b_dec * dh) * 4          # x + h in/out
    fused_step_bytes = weight_bytes + act_bytes
    unfused_step_bytes = fused_step_bytes + 2 * n_proj * b_dec * dh * 4
    out["pallas_decode_step_mingru"] = {
        "us_per_call": us,
        "us_per_call_jnp_ref": us_ref,
        "hbm_bytes_per_step": fused_step_bytes,
        "unfused_bytes_ratio": unfused_step_bytes / fused_step_bytes,
    }
    row("kernel/pallas_decode_step_mingru", us,
        f"hbm_bytes_per_step={fused_step_bytes};"
        f"unfused_traffic={unfused_step_bytes / fused_step_bytes:.2f}x;"
        f"jnp_ref_us={us_ref:.1f}")

    dump_json(args.out, {"shape": list(shape), "kernels": out})
    return out


if __name__ == "__main__":
    main()
