"""Roofline table builder: reads results/dryrun.jsonl -> §Roofline table.

Per (arch x shape x mesh): the three terms (compute / memory / collective,
seconds), the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, roofline
fraction, and HBM occupancy.  Also nominates the three hillclimb cells
(worst roofline fraction / most collective-bound / most paper-
representative).
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from benchmarks.bench_utils import header, row

HBM_PER_CHIP = 16e9      # v5e


def load(path: str = "results/dryrun.jsonl") -> List[dict]:
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return list(recs.values())


def fraction(r: dict) -> float:
    """Roofline fraction: ideal time on the BINDING roofline / dominant term.

    Train/prefill bind on compute (6/2 * N * D model FLOPs at peak);
    decode binds on memory (weights + cache must stream from HBM once per
    token -- argument_bytes is exactly that per-device minimum).
    """
    t = r["roofline"]
    dominant = max(t["t_compute"], t["t_memory"], t["t_collective"])
    if r["shape"].startswith(("decode", "long")):
        ideal = r["mem"]["argument_bytes"] / 819e9
    else:
        ideal = r["model_flops"] / (r["n_devices"] * 197e12)
    return ideal / dominant if dominant else 0.0


def table(recs: List[dict], mesh: str = "single") -> List[dict]:
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": True, "reason": r.get("reason", "")})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "skipped": False,
            "t_compute": t["t_compute"], "t_memory": t["t_memory"],
            "t_collective": t["t_collective"], "dominant": t["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "fraction": fraction(r),
            "hbm_gb": r["hbm_per_device"] / 1e9,
            "fits": r["hbm_per_device"] <= HBM_PER_CHIP,
        })
    return rows


def markdown(rows: List[dict]) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | MODEL/HLO | roofline frac | HBM GB/dev | fits |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"skipped | - | - | - | ({r['reason']}) |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} | "
            f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['fraction']:.3f} | {r['hbm_gb']:.1f} | "
            f"{'y' if r['fits'] else 'NO'} |")
    return "\n".join(out)


def nominate(rows: List[dict]) -> Dict[str, dict]:
    """The three hillclimb cells (EXPERIMENTS.md §Perf).

    worst_fraction considers train/prefill cells (decode cells' tiny
    compute fractions reflect batch size, not an optimizable inefficiency
    -- their binding metric is the memory fraction, reported separately);
    most_collective ranks by the absolute dominant collective term
    (seconds of ICI time to remove, not just its ratio).
    """
    live = [r for r in rows if not r.get("skipped")]
    steady = [r for r in live
              if not r["shape"].startswith(("decode", "long"))]
    worst = min(steady, key=lambda r: r["fraction"])
    coll = max(live, key=lambda r: r["t_collective"]
               if r["dominant"] == "collective" else 0.0)
    paper = [r for r in live if r["arch"] in ("mingru-lm", "minlstm-lm")
             and r["shape"] == "train_4k"]
    rep = min(paper, key=lambda r: r["fraction"]) if paper else worst
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": rep}


def main() -> dict:
    header("roofline (from dry-run artifacts)")
    recs = load()
    if not recs:
        row("roofline/missing", 0.0, "run dryrun --all first")
        return {}
    rows = table(recs, "single")
    for r in rows:
        if r.get("skipped"):
            row(f"roofline/{r['arch']}/{r['shape']}", 0.0, "skipped")
        else:
            row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                f"dom={r['dominant']};frac={r['fraction']:.3f};"
                f"hbm={r['hbm_gb']:.1f}GB")
    noms = nominate(rows)
    for k, r in noms.items():
        row(f"roofline/nominee/{k}", 0.0, f"{r['arch']}x{r['shape']}")
    return {"rows": rows, "nominees": noms}


if __name__ == "__main__":
    main()
