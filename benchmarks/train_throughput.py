"""Train-step throughput across minRNN scan strategies -> BENCH_train.json.

Measures one full optimiser step (forward + backward + AdamW) of the
paper's LMs under each scan execution strategy:

  * ``associative``  -- pure-jnp lax.associative_scan (the unfused baseline:
    gate activations round-trip through HBM between matmul and scan)
  * ``pallas``       -- XLA projections + Pallas chunked scan kernel
    (log-space kernel for mode="log")
  * ``auto``         -- the fused Pallas projection+scan kernel (default)

Two metrics per strategy:

  * **wall-clock** tokens/s and step time.  Only meaningful on a real TPU;
    on CPU the Pallas rows run in interpret mode (python-level emulation)
    and are expected to be *slower* -- reported anyway, honestly labeled.
  * **structural bytes/token** and the derived structural tokens/s: the
    HBM traffic model from DESIGN.md §3 / kernels/fused_mingru docs, which
    is backend-independent and is what determines TPU throughput for this
    bandwidth-bound layer.  Forward, per minRNN layer and token (P = n_proj
    gate projections: 2 for minGRU, 3 for minLSTM):

        unfused: read x (Dx) + write gates (P*Dh) + read gates (P*Dh)
                 + write h (Dh) + read h (Dh)          = Dx + (2P+2)*Dh
        pallas : gates still materialised for the kernel = same as unfused
        fused  : read x (Dx) + write h (Dh) + read h (Dh) = Dx + 2*Dh

    Backward is ~2x the *unfused* forward traffic for EVERY strategy: the
    fused custom_vjp rematerialises the gate activations through XLA
    matmuls (see kernels/fused_mingru/ops.py), so its HBM win is currently
    forward-only -- a fused backward kernel is the ROADMAP open item.  The
    model reflects that honestly; fused >= unfused on structural tokens/s
    still holds, just by the forward term, and this ratio is the quantity
    the BENCH_train.json trajectory tracks.

    PYTHONPATH=src python -m benchmarks.train_throughput --tiny   # CI smoke
    PYTHONPATH=src python -m benchmarks.train_throughput \
        --arch mingru-lm --seq-len 1024 --batch 8
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.bench_utils import dump_json, header, row
from repro.configs import archs
from repro.models import lm
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib

# nominal HBM bandwidth used to turn structural bytes into a tokens/s
# upper bound (TPU v5e: ~819 GB/s); the *ratio* between strategies is the
# tracked quantity and is bandwidth-independent.
NOMINAL_HBM_GBPS = 819.0

def structural_bytes_per_token(cfg, strategy: str) -> float:
    """HBM bytes moved per token per step (fwd+bwd) for the minRNN stack."""
    mr = cfg.minrnn
    n_proj = 2 if mr.cell == "mingru" else 3
    dx = cfg.d_model
    dh = int(cfg.d_model * mr.expansion)
    unfused_fwd = dx + (2 * n_proj + 2) * dh
    # all strategies' VJPs remat the gates through XLA matmuls, so the
    # backward moves ~2x the unfused forward traffic regardless of strategy
    bwd = 2 * unfused_fwd
    if strategy in ("auto", "fused"):
        per_layer = (dx + 2 * dh) + bwd
    else:                      # unfused: gate activations round-trip HBM
        per_layer = unfused_fwd + bwd
    itemsize = jnp.dtype(cfg.cdtype).itemsize
    return float(cfg.n_layers * per_layer * itemsize)


def bench_strategy(cfg, batch, steps: int) -> Dict[str, float]:
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(ocfg, params)
    step_fn = jax.jit(ts_lib.make_train_step(cfg, ocfg))

    params, opt_state, m = step_fn(params, opt_state, batch)   # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        params, opt_state, m = step_fn(params, opt_state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / max(steps, 1)
    n_tok = batch["tokens"].size
    return {
        "step_time_us": dt * 1e6,
        "tokens_per_s_wallclock": n_tok / dt,
        "loss": float(m["loss"]),
    }


def bench(arch: str, strategies: List[str], seq_len: int, batch_size: int,
          steps: int, out_path: str) -> dict:
    cfg = archs.smoke(arch)
    if cfg.minrnn is None:
        raise SystemExit(
            f"--arch {arch}: scan strategies only apply to minRNN archs "
            "(mingru-lm, minlstm-lm); this benchmark has no traffic model "
            "for attention/SSD mixers")
    header(f"train throughput {arch}: B={batch_size} T={seq_len} "
           f"steps={steps} backend={jax.default_backend()}")
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (batch_size, seq_len), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(k2, (batch_size, seq_len), 0,
                                     cfg.vocab_size),
    }

    results: Dict[str, dict] = {}
    for strat in strategies:
        r = bench_strategy(cfg.replace(scan_strategy=strat), batch, steps)
        sbpt = structural_bytes_per_token(cfg, strat)
        r["structural_bytes_per_token"] = sbpt
        r["tokens_per_s_structural"] = NOMINAL_HBM_GBPS * 1e9 / sbpt
        results[strat] = r
        row(f"train_{arch}_{strat}", r["step_time_us"],
            f"{r['tokens_per_s_wallclock']:.0f} tok/s wallclock;"
            f"{r['tokens_per_s_structural']:.0f} tok/s structural")

    # all strategies compute the same math (rounding aside), so a loss
    # mismatch means a dispatch/kernel regression -- fail loudly so the CI
    # smoke actually enforces cross-strategy numerics, not just liveness
    losses = [r["loss"] for r in results.values()]
    spread = (max(losses) - min(losses)) / max(abs(max(losses)), 1e-9)
    if spread > 1e-4:
        raise SystemExit(
            f"cross-strategy loss mismatch (rel spread {spread:.2e}): "
            + str({k: r["loss"] for k, r in results.items()}))

    payload = {
        "arch": arch,
        "batch": batch_size,
        "seq_len": seq_len,
        "steps": steps,
        "nominal_hbm_gbps": NOMINAL_HBM_GBPS,
        "loss_rel_spread": spread,
        "strategies": results,
    }
    fused = results.get("auto") or results.get("fused")
    unfused = results.get("associative")
    if fused and unfused:
        payload["fused_speedup_structural"] = (
            fused["tokens_per_s_structural"]
            / unfused["tokens_per_s_structural"])
        row("train_fused_speedup_structural", 0.0,
            f"{payload['fused_speedup_structural']:.2f}x fused/unfused")
    dump_json(out_path, payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mingru-lm")
    ap.add_argument("--strategies", nargs="*",
                    default=["associative", "pallas", "auto"])
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_train.json, or "
                         "BENCH_train.tiny.json under --tiny so smoke runs "
                         "never clobber the tracked perf trajectory)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny shapes, 1 timed step")
    args = ap.parse_args(argv)
    if args.tiny:
        args.seq_len, args.batch, args.steps = 64, 2, 1
    out = args.out or ("BENCH_train.tiny.json" if args.tiny
                       else "BENCH_train.json")
    bench(args.arch, args.strategies, args.seq_len, args.batch, args.steps,
          out)


if __name__ == "__main__":
    main()
