"""Paper Fig. 2: character-level LM on Shakespeare.

minGRU / minLSTM / mamba2 / transformer (smoke-scale on CPU; the paper's
exact hyperparameters -- 3 layers, dim 384, expansion 2 -- are kept as the
*full* config, exercised via the dry-run).  Reports loss curves and
steps-to-threshold; the paper's qualitative claims: all converge to
similar loss; the transformer needs ~2.5x more steps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_utils import header, row, time_call
from repro.configs import archs
from repro.configs.base import MinRNNConfig, ModelConfig, SSMConfig
from repro.data import lm_corpus
from repro.models import lm
from repro.training import optimizer as opt_lib
from repro.training import train_step as ts_lib

SEQ = 128
BATCH = 16


def _configs():
    minrnn = dict(d_model=64, d_ff=256, n_layers=3, vocab_size=256,
                  tie_embeddings=True)
    return {
        "mingru": ModelConfig(
            name="mingru", block_kind="minrnn",
            minrnn=MinRNNConfig(cell="mingru", expansion=2.0,
                                use_conv=True, use_mlp=True), **minrnn),
        "minlstm": ModelConfig(
            name="minlstm", block_kind="minrnn",
            minrnn=MinRNNConfig(cell="minlstm", expansion=2.0,
                                use_conv=True, use_mlp=True), **minrnn),
        "mamba2": ModelConfig(
            name="mamba2", block_kind="ssm", n_layers=3, d_model=64,
            d_ff=0, vocab_size=256, tie_embeddings=True,
            ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=32)),
        "transformer": ModelConfig(
            name="transformer", block_kind="attention", n_layers=3,
            d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256,
            tie_embeddings=True, rope=True),
    }


def train_curve(cfg, steps: int, seed: int = 0):
    train_data, test_data = lm_corpus.build_corpus()
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    opt_state = opt_lib.init(ocfg, params)
    step = jax.jit(ts_lib.make_train_step(cfg, ocfg))
    eval_loss = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b)[0])
    curve = []
    us = 0.0
    for i in range(steps):
        batch = lm_corpus.lm_batch(train_data, seed, i, BATCH, SEQ)
        if i == steps - 1:
            us = time_call(step, params, opt_state, batch, repeats=1,
                           warmup=0)
        params, opt_state, _ = step(params, opt_state, batch)
        if (i + 1) % 25 == 0:
            tb = lm_corpus.lm_batch(test_data, seed + 1, i, BATCH, SEQ)
            curve.append((i + 1, float(eval_loss(params, tb))))
    return curve, us


def main(steps: int = 200) -> dict:
    header("fig2_lm (char-level Shakespeare)")
    out = {}
    for name, cfg in _configs().items():
        curve, us = train_curve(cfg, steps)
        final = curve[-1][1]
        # steps to reach 1.25x of this model's final loss
        thresh = 1.25 * final
        to_thresh = next((s for s, l in curve if l <= thresh), steps)
        row(f"fig2/{name}", us,
            f"final_test_loss={final:.3f};steps_to_1.25x={to_thresh}")
        out[name] = dict(curve=curve, final=final, to_thresh=to_thresh)
    if "mingru" in out and "transformer" in out:
        ratio = out["transformer"]["to_thresh"] / max(
            out["mingru"]["to_thresh"], 1)
        row("fig2/transformer_vs_mingru_steps_ratio", 0.0, f"{ratio:.2f}x")
    return out


if __name__ == "__main__":
    main()
