"""Paper Fig. 5: forget-gate bias initialization improves minLSTM training.

Trains minLSTM on selective copy with forget-gate bias init 0 / 2 / 4 and
reports the loss after a fixed budget -- higher bias -> earlier retention
-> faster convergence, per the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_utils import header, row
from repro.core import min_lstm, nn
from repro.data import synthetic


def _recall_batch(seed, step, batch, seq, vocab):
    """Retention probe: output the FIRST token of the sequence at the end
    (pure long-range memory -- exactly what the forget gate controls)."""
    rng = np.random.default_rng(np.random.PCG64(seed * 7 + step))
    tokens = rng.integers(1, vocab, size=(batch, seq)).astype(np.int32)
    labels = np.full((batch, seq), -1, np.int32)
    labels[:, -1] = tokens[:, 0]
    return tokens, labels


def run(forget_bias: float, steps: int, seed: int = 0):
    d, dh, vocab, seq = 32, 64, 16, 10
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "embed": nn.normal_init(k1, (vocab, d), 0.02),
        "cell": min_lstm.init(k2, d, dh, forget_bias=forget_bias),
        "head": nn.dense_init(k3, dh, vocab),
    }

    def loss_fn(p, tokens, labels):
        x = p["embed"][tokens]
        h = min_lstm.parallel(p["cell"], x, mode="log")
        logits = nn.dense_apply(p["head"], h).astype(jnp.float32)
        mask = labels >= 0
        logp = jax.nn.log_softmax(logits)
        gold = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                                   axis=-1)[..., 0]
        return -jnp.sum(gold * mask) / jnp.maximum(mask.sum(), 1)

    from repro.training import optimizer as opt_lib
    ocfg = opt_lib.AdamWConfig(lr=1e-2, warmup_steps=10, total_steps=steps,
                               weight_decay=0.0)
    opt_state = opt_lib.init(ocfg, params)

    @jax.jit
    def step(p, o, tokens, labels):
        l, g = jax.value_and_grad(loss_fn)(p, tokens, labels)
        p, o, _ = opt_lib.apply(ocfg, o, p, g)
        return p, o, l

    losses = []
    for i in range(steps):
        tokens, labels = _recall_batch(seed, i, 64, seq, 16)
        params, opt_state, l = step(params, opt_state,
                                    jnp.asarray(tokens),
                                    jnp.asarray(labels))
        losses.append(float(l))
    return float(np.mean(losses[-10:]))


def main(steps: int = 400) -> dict:
    header("fig5_forget_bias (minLSTM retention init)")
    out = {}
    for bias in (0.0, 2.0, 4.0):
        final = run(bias, steps)
        row(f"fig5/forget_bias_{bias:g}", 0.0, f"loss_after_budget={final:.4f}")
        out[bias] = final
    return out


if __name__ == "__main__":
    main()
