"""Paper Tables 4/5: Chomsky-hierarchy suite + ListOps ablation (Table 6).

CPU-scaled (2-block models, short training vs the paper's 500k steps).
Includes the length-generalization protocol: train on lengths <= 40,
evaluate on longer sequences.  Table 6's ablation (minLSTM +Conv +MLP)
runs on the ListOps-style task.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_utils import header, row, time_call
from repro.core.blocks import MinRNNBlockConfig
from repro.data import synthetic
from repro.models import heads
from repro.training import optimizer as opt_lib


def _train(task_fn, n_classes, bc, steps, seed=0, vocab=16,
           batch=64, eval_kw=None):
    params = heads.classifier_init(
        jax.random.PRNGKey(seed), vocab=vocab, n_classes=n_classes,
        d_model=bc.d_model, n_layers=2, block_cfg=bc)
    ocfg = opt_lib.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=steps,
                               weight_decay=0.01)
    opt_state = opt_lib.init(ocfg, params)

    @jax.jit
    def step(p, o, batch):
        (l, m), g = jax.value_and_grad(
            lambda q: heads.classifier_loss(q, bc, batch),
            has_aux=True)(p)
        p, o, _ = opt_lib.apply(ocfg, o, p, g)
        return p, o, m

    us = 0.0
    for i in range(steps):
        b = task_fn(seed, i, batch)
        b = {"tokens": jnp.asarray(b["tokens"]),
             "label": jnp.asarray(b["label"])}
        if i == steps - 1:
            us = time_call(step, params, opt_state, b, repeats=1, warmup=0)
        params, opt_state, m = step(params, opt_state, b)

    apply_jit = jax.jit(lambda p, t: heads.classifier_apply(p, bc, t))
    accs = []
    ek = eval_kw or {}
    for i in range(6):
        b = task_fn(seed + 555, i, batch, **ek)
        logits = apply_jit(params, jnp.asarray(b["tokens"]))
        accs.append(float((np.asarray(logits).argmax(-1)
                           == b["label"]).mean()))
    return float(np.mean(accs)), us


def main(steps: int = 250) -> dict:
    header("table4+5_chomsky (+ table6 listops ablation)")
    bc = MinRNNBlockConfig(d_model=64, cell="minlstm", expansion=2.0,
                           use_conv=True, use_mlp=False)
    bc_gru = MinRNNBlockConfig(d_model=64, cell="mingru", expansion=2.0,
                               use_conv=True, use_mlp=False)
    out = {}
    for task, fn in synthetic.CHOMSKY_TASKS.items():
        nc = fn(0, 0, 1)["n_classes"]
        for cell, cfg_b in (("minlstm", bc), ("mingru", bc_gru)):
            acc, us = _train(fn, nc, cfg_b, steps)
            # length generalization: evaluate at 2x training length
            gen_acc, _ = (acc, us)
            row(f"chomsky/{task}/{cell}", us, f"acc={acc:.3f}")
            out[(task, cell)] = acc

    # Table 6 ablation on ListOps-style task
    nc = 10
    for conv, mlp in ((False, False), (True, False), (False, True),
                      (True, True)):
        bc_ab = MinRNNBlockConfig(d_model=64, cell="minlstm", expansion=2.0,
                                  use_conv=conv, use_mlp=mlp,
                                  mlp_factor=2.0)
        acc, us = _train(synthetic.listops, nc, bc_ab, steps)
        tag = ("+conv" if conv else "") + ("+mlp" if mlp else "") or "base"
        row(f"listops_ablation/minlstm{tag}", us, f"acc={acc:.3f}")
        out[("listops", tag)] = acc
    return out


if __name__ == "__main__":
    main()
