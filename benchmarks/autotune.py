"""Decode-path autotuner: sweep the serving knobs, persist the winner.

Sweeps the (block_dh, prompt-chunk C, decode-block K) grid for one model
config and writes the best point as a ``TUNE_<config>.json`` plan (see
``repro/serving/tuning.py`` for the discovery order the engine uses at
startup).  Two scoring modes, picked by backend:

  * real accelerator -- wall-clock: each grid point replays the mixed
    arrival trace on the REAL superstep engine with the candidate knobs
    and scores measured decode tokens/s.
  * interpret (CPU/GPU) -- structural: interpret-mode wall-clock is a
    simulation artifact, so the superstep round simulator scores each
    point instead, on the tier-aware structural model
    (weight stream + per-boundary dispatch + boundary activation
    traffic) extended with a per-tile term: every extra ``block_dh``
    tile of the whole-block kernel re-reads and re-writes the fp32
    (B, d_model) residual accumulator per layer.

Both modes score the SAME knobs the engine consumes, so a plan tuned
structurally on CPU is a valid (if conservative) starting point on TPU
-- regenerate there for the real ranking.  ``--points N`` truncates the
grid for CI smoke runs (the 2-point lane); the grid is ordered so the
truncation still crosses a packing boundary.

    PYTHONPATH=src python -m benchmarks.autotune --arch mingru-lm
    PYTHONPATH=src python -m benchmarks.autotune --arch minlstm-lm \
        --points 2 --out-dir /tmp/plans
    make bench-autotune          # both archs, plans at the repo root
"""

from __future__ import annotations

import argparse
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.bench_utils import header, row
from benchmarks.engine_throughput import (
    NOMINAL_HBM_GBPS, NOMINAL_ROUNDTRIP_US, make_trace, replay_real_engine,
    simulate_superstep, t_step_for_tier)
from repro.configs import archs
from repro.models import lm
from repro.serving import tuning

_LANES = 128
_MAX_BLOCK_DH = 512             # ops.py VMEM ceiling for the feature tile


def tile_candidates(cfg):
    """Feasible ``block_dh`` tiles for a config: lane multiples up to
    the smaller of the (padded) hidden width and the VMEM ceiling."""
    dh = int(cfg.d_model * cfg.minrnn.expansion)
    dh128 = -(-dh // _LANES) * _LANES
    cap = min(dh128, _MAX_BLOCK_DH)
    return [t for t in (128, 256, 384, 512) if t <= cap] or [cap]


def block_t_step(cfg, batch: int, block_dh: int) -> float:
    """Structural seconds per decode round for the block-fused tier at
    a given feature tile: the tier t_step plus the multi-tile
    accumulator revisits (one fp32 (B, d_model) read+write per extra
    tile per layer)."""
    dh = int(cfg.d_model * cfg.minrnn.expansion)
    n_tiles = -(-(-(-dh // _LANES) * _LANES) // block_dh)
    extra = cfg.n_layers * (n_tiles - 1) * 2 * batch * cfg.d_model * 4
    return (t_step_for_tier(cfg, "block-fused", batch)
            + extra / (NOMINAL_HBM_GBPS * 1e9))


def score_structural(cfg, trace, batch: int, bdh: int, c: int,
                     k: int) -> float:
    """Superstep-simulated decode tokens/s on the tier-aware model."""
    t_step = block_t_step(cfg, batch, bdh)
    rt = NOMINAL_ROUNDTRIP_US * 1e-6
    tok, t = simulate_superstep(trace, batch, k, t_step, rt,
                                prompt_chunk=c)
    return tok / t


def score_wallclock(cfg, params, trace, batch: int, bdh: int, c: int,
                    k: int) -> float:
    """Measured decode tokens/s of a real replay with the candidate
    knobs (real-accelerator mode only)."""
    snap, _ = replay_real_engine(
        cfg.replace(block_dh=bdh, fuse_block="on"), params, trace,
        batch, k, prompt_chunk=c, tune=None)
    return snap["decode_tokens_per_second"]


def sweep(arch: str, batch: int, n_requests: int, block_dhs=None,
          chunks=(1, 4, 16), ks=(4, 8, 16, 32), points: int = 0,
          out_dir=None, write: bool = True):
    cfg = archs.smoke(arch)
    mode = "wallclock" if jax.default_backend() == "tpu" else "structural"
    tiles = list(block_dhs) if block_dhs else tile_candidates(cfg)
    chunks = sorted({max(1, int(c)) for c in chunks})
    ks = sorted({max(1, int(k)) for k in ks})
    # order: tile-major then (C, K) interleaved so a truncated CI run
    # still compares packed vs unpacked rather than K-neighbours
    grid = [(bdh, c, k) for bdh in tiles
            for k in ks for c in sorted(chunks, reverse=True)]
    total = len(grid)
    if points:
        grid = grid[:max(1, int(points))]
    trace = make_trace(n_requests, batch)
    params = (lm.init_params(jax.random.PRNGKey(0), cfg)
              if mode == "wallclock" else None)
    header(f"autotune {arch} ({tuning.fingerprint(cfg)}): "
           f"{len(grid)}/{total} grid points, batch={batch}, mode={mode}, "
           f"backend={jax.default_backend()}")

    scored = []
    t0 = time.perf_counter()
    for bdh, c, k in grid:
        if mode == "wallclock":
            tps = score_wallclock(cfg, params, trace, batch, bdh, c, k)
        else:
            tps = score_structural(cfg, trace, batch, bdh, c, k)
        scored.append({"block_dh": bdh, "prompt_chunk": c,
                       "decode_block": k, "decode_tokens_per_s": tps})
        row(f"tune_{arch}_bdh{bdh}_c{c}_k{k}", 0.0, f"{tps:.0f} tok/s "
            f"{mode}")
    best = max(scored, key=lambda r: r["decode_tokens_per_s"])

    plan = {
        "config": tuning.config_stamp(cfg),
        "arch": arch,
        "fuse_block": "auto",
        "block_dh": best["block_dh"],
        "prompt_chunk": best["prompt_chunk"],
        "decode_block": best["decode_block"],
        "score_decode_tokens_per_s": best["decode_tokens_per_s"],
        "mode": mode,
        "backend": jax.default_backend(),
        "batch": batch,
        "n_requests": n_requests,
        "points_scored": len(grid),
        "grid_total": total,
        "sweep_s": time.perf_counter() - t0,
        "sweep": scored,
    }
    row(f"tune_{arch}_best", 0.0,
        f"bdh={best['block_dh']} C={best['prompt_chunk']} "
        f"K={best['decode_block']};{best['decode_tokens_per_s']:.0f} "
        f"tok/s {mode}")
    if write:
        out_dir = Path(out_dir) if out_dir else tuning._REPO_ROOT
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / tuning.tune_filename(cfg)
        tuning.save_plan(path, plan)
        print(f"# wrote {path}")
    return plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mingru-lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--block-dhs", type=int, nargs="*", default=None,
                    help="feature tiles to sweep (default: derived from "
                         "the config's hidden width, <= 512)")
    ap.add_argument("--chunks", type=int, nargs="*", default=[1, 4, 16],
                    help="prompt-packing chunk sizes C")
    ap.add_argument("--ks", type=int, nargs="*", default=[4, 8, 16, 32],
                    help="decode block sizes K")
    ap.add_argument("--points", type=int, default=0,
                    help="truncate the sweep grid to the first N points "
                         "(CI smoke; 0 = full grid)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for TUNE_<config>.json (default: "
                         "repo root, the checked-in location)")
    ap.add_argument("--dry-run", action="store_true",
                    help="sweep and report, write nothing")
    args = ap.parse_args(argv)
    if args.n_requests < 1:
        raise SystemExit("--n-requests must be >= 1")
    sweep(args.arch, args.batch, args.n_requests,
          block_dhs=args.block_dhs, chunks=args.chunks, ks=args.ks,
          points=args.points, out_dir=args.out_dir, write=not args.dry_run)


if __name__ == "__main__":
    main()
